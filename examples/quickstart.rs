//! Quickstart: load the AOT artifacts, build the OD-MoE engine with the
//! paper's default configuration, and serve one prompt.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::model::WeightStore;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    // 1. PJRT runtime over the HLO artifacts (`make artifacts` builds them;
    //    Python never runs after that point).
    let rt = odmoe::Runtime::load_default()?;
    println!("model: {} layers, {} experts/layer, top-{}",
             rt.cfg.n_layers, rt.cfg.n_experts, rt.cfg.top_k);

    // 2. Deterministic weights (the synthetic stand-in for Mixtral-8x7B).
    let ws = WeightStore::generate(&rt.cfg, 42);

    // 3. The paper's system: 8 workers in 4 groups, INT8 shadow model,
    //    token+KV alignment every iteration.
    let mut engine = OdMoeEngine::new(&rt, ws, OdMoeConfig::default())?;
    println!("engine: {}\n", engine.name());

    // 4. Serve a 16-token prompt for 32 output tokens.
    let prompt = &Corpus::generate(7, 1, 16, rt.cfg.vocab_size as u32).prompts[0];
    let result = engine.run_prompt(prompt, 32, false)?;

    println!("prompt tokens : {:?}", &prompt[..8.min(prompt.len())]);
    println!("output tokens : {:?}", &result.tokens[..8]);
    println!("TTFT          : {:.1} ms (virtual)", result.ttft_ms);
    println!("decode        : {:.2} tok/s (virtual)", result.decode_tps());
    println!("I/O stalls    : {:.1} ms total", result.stall_ms);

    // 5. SEP prediction quality over this run (Eq. 3).
    let correct: usize = result.correct_per_token.iter().flatten().sum();
    let total = result.correct_per_token.len() * rt.cfg.n_layers * rt.cfg.top_k;
    println!("SEP recall    : {:.4}", correct as f64 / total as f64);

    // 6. The cacheless property, straight from the memory ledger.
    let peak = engine.cluster.workers.iter().map(|w| w.gpu_bytes_peak).max().unwrap();
    println!("worker peak   : {:.2} GB (paper: < 1 GB)", peak as f64 / 1e9);
    Ok(())
}
