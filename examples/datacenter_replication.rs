//! Data-center extension (paper §1, Benefit 3): SEP's lookahead gives
//! per-expert demand for upcoming layers; this example aggregates *real*
//! routed traffic over a batch of sequences and compares single placement
//! vs prediction-driven replication (`coordinator::replication`).
//!
//! ```bash
//! cargo run --release --example datacenter_replication
//! ```

use odmoe::coordinator::replication::{demand_from_routes, place_replicated, place_single};
use odmoe::engine::ModelState;
use odmoe::model::WeightStore;
use odmoe::util::table::Table;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let rt = odmoe::Runtime::load_default()?;
    let cfg = rt.cfg.clone();
    let ws = WeightStore::generate(&cfg, 42);
    let mut state = ModelState::new(&rt, ws)?;

    // A "data center" batch: 16 concurrent sequences, one decode step each.
    let corpus = Corpus::generate(77, 16, 16, cfg.vocab_size as u32);
    let mut per_layer_routes: Vec<Vec<Vec<usize>>> = vec![Vec::new(); cfg.n_layers];
    for prompt in &corpus.prompts {
        state.reset();
        let rec = state.prefill(prompt)?;
        let step = state.decode_step(rec.token_out)?;
        for (l, route) in step.routes.iter().enumerate() {
            per_layer_routes[l].push(route.experts.clone());
        }
    }

    println!("# Expert replication from predicted demand (16 sequences, 8 workers)\n");
    let mut t = Table::new(&[
        "layer", "demand (per expert)", "imbalance single", "imbalance replicated", "replicas",
    ]);
    let (mut sum_s, mut sum_r) = (0.0, 0.0);
    for l in 0..cfg.n_layers {
        let demand = demand_from_routes(&per_layer_routes[l], cfg.n_experts);
        let single = place_single(&demand, 8);
        let repl = place_replicated(&demand, 8, 4);
        sum_s += single.imbalance();
        sum_r += repl.imbalance();
        t.row(&[
            l.to_string(),
            format!("{demand:?}"),
            format!("{:.2}", single.imbalance()),
            format!("{:.2}", repl.imbalance()),
            repl.replica_count().to_string(),
        ]);
    }
    t.print();
    println!(
        "\nmean imbalance (max/mean load): single {:.2} -> replicated {:.2}",
        sum_s / cfg.n_layers as f64,
        sum_r / cfg.n_layers as f64
    );
    println!("(1.00 = perfectly balanced; the paper cites Grace-MoE-style");
    println!(" replication as the consumer of exactly these predictions)");
    Ok(())
}
