//! Timing analysis: renders the paper's Fig. 2/4/5-style timeline diagrams
//! from the simulator's event trace, and checks the Eq. (1) feasibility
//! condition across hardware profiles and worker counts.
//!
//! ```bash
//! cargo run --release --example timing_analysis
//! ```

use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::{Engine, GroupSchedule, OdMoeConfig, OdMoeEngine};
use odmoe::model::WeightStore;
use odmoe::predictor::AlignmentConfig;
use odmoe::util::table::Table;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let rt = odmoe::Runtime::load_default()?;
    let ws = WeightStore::generate(&rt.cfg, 42);
    let prompt = &Corpus::generate(3, 1, 16, rt.cfg.vocab_size as u32).prompts[0];

    // ---- Eq. (1) feasibility table -------------------------------------
    println!("== Eq. (1): t_maxload = n_groups*t_M + (n_groups-1)*t_W ==\n");
    let mut t = Table::new(&[
        "profile", "workers", "groups", "t_M ms", "t_W ms", "window ms", "load ms", "bottleneck-free",
    ]);
    for profile in [HardwareProfile::rtx3090(), HardwareProfile::rtx3080_workers()] {
        for n_workers in [2usize, 4, 8, 16] {
            let s = GroupSchedule::new(n_workers, rt.cfg.top_k);
            let window = s.t_maxload(profile.t_main_ms(), profile.t_worker_ms());
            let load = profile.expert_load_ms(1.0);
            t.row(&[
                profile.name.to_string(),
                n_workers.to_string(),
                s.n_groups().to_string(),
                format!("{:.2}", profile.t_main_ms()),
                format!("{:.2}", profile.t_worker_ms()),
                format!("{window:.2}"),
                format!("{load:.2}"),
                if load <= window { "yes" } else { "NO" }.to_string(),
            ]);
        }
    }
    t.print();

    // ---- Fig. 2/4/5 timelines -------------------------------------------
    let names: Vec<String> = std::iter::once("main".to_string())
        .chain(std::iter::once("shadow".to_string()))
        .chain((0..8).map(|i| format!("worker{i}")))
        .collect();

    for (title, align) in [
        ("Fig. 4 analogue: no alignment (shadow free-runs)", AlignmentConfig::none()),
        ("Fig. 5 analogue: token+KV alignment (late departure)", AlignmentConfig::every_iteration()),
    ] {
        let cfg = OdMoeConfig { align, ..OdMoeConfig::default() };
        let mut engine = OdMoeEngine::new(&rt, ws.clone(), cfg)?;
        engine.enable_trace();
        let res = engine.run_prompt(prompt, 4, false)?;
        // Render the window right after prefill (the first decode token).
        let t0 = res.ttft_ms;
        let t1 = res.ttft_ms + res.decode_ms / 3.0 * 1.2;
        println!("\n== {title} ==");
        println!("{}", engine.cluster.trace.render_timeline(t0, t1, 100, &names));
        println!("decode {:.2} tok/s | stall {:.1} ms", res.decode_tps(), res.stall_ms);
    }
    Ok(())
}
