//! Multi-tenant load test: sweep arrival rates over OD-MoE and the
//! fully-cached Transformers baseline through the continuous scheduler,
//! and write `BENCH_serve.json` with throughput, goodput and exact
//! p50/p95/p99 TTFT per (system, rate) point.
//!
//! ```bash
//! cargo run --release --example load_test -- --rates 0.5,2,8 --policy fcfs
//! ```
//!
//! Everything runs in virtual time from seeded generators, so the same
//! seed produces a byte-identical `BENCH_serve.json`. Flags:
//!
//! * `--rates R1,R2,..`  arrival rates in req/s (default `0.5,2,8`)
//! * `--policy P`        `fcfs` | `sjf` | `edf` (default `fcfs`)
//! * `--replicas N`      engine replica slots per system (default 1)
//! * `--max-batch N`     sessions co-scheduled per replica dispatch
//!   (default 1 = sequential; see `od-moe serve --batch-sweep` for the
//!   dedicated batch-size sweep writing `BENCH_batch.json`)
//! * `--requests N`      requests per point (default 24)
//! * `--out-tokens N`    output tokens per request (default 16)
//! * `--tenants N`       1 = single class, 2 = interactive + batch
//! * `--preempt-ms MS`   per-session service budget (over-budget
//!   sessions are truncated at a token boundary)
//! * `--slo-ttft-ms MS` / `--slo-tpot-ms MS`  goodput SLO, raw virtual ms
//! * `--out PATH`        output path (default `BENCH_serve.json`)

use std::path::Path;

use odmoe::coordinator::baselines::FullyCachedEngine;
use odmoe::coordinator::{OdMoeConfig, OdMoeEngine};
use odmoe::model::WeightStore;
use odmoe::serve::{
    config_from_args, parse_rates, rate_sweep, sweep_json, write_bench, BatchEngineService,
    ServiceModel,
};
use odmoe::util::cli::Args;
use odmoe::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let seed = args.u64_or("seed", 42)?;
    let rates = parse_rates(args.get_or("rates", "0.5,2,8"))?;

    let rt = odmoe::Runtime::load_default()?;
    // Same flag set as `od-moe serve` (the builder is shared).
    let (spec, sched, _) = config_from_args(&args, rt.cfg.vocab_size as u32)?;

    let ws = WeightStore::generate(&rt.cfg, seed);
    let mut od = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default())?;
    let mut reference = FullyCachedEngine::new(&rt, ws)?;
    let mut od_svc = BatchEngineService::new(&mut od);
    let mut ref_svc = BatchEngineService::new(&mut reference);
    let mut systems: Vec<(String, &mut dyn ServiceModel)> =
        vec![("od-moe".into(), &mut od_svc), ("transformers".into(), &mut ref_svc)];

    let results = rate_sweep(&mut systems, &spec, &rates, &sched, seed)?;

    let mut t = Table::new(&[
        "system", "rate req/s", "served", "tok/s", "goodput tok/s", "slo %", "ttft p50 ms",
        "ttft p95 ms", "ttft p99 ms", "mean q-depth",
    ]);
    for (name, points) in &results {
        for p in points {
            t.row(&[
                name.clone(),
                format!("{:.2}", p.rate_per_s),
                format!("{}/{}", p.completed, p.offered),
                format!("{:.2}", p.throughput_tok_s),
                format!("{:.2}", p.goodput_tok_s),
                format!("{:.0}", p.slo_attainment * 100.0),
                format!("{:.0}", p.ttft.p50),
                format!("{:.0}", p.ttft.p95),
                format!("{:.0}", p.ttft.p99),
                format!("{:.2}", p.mean_queue_depth),
            ]);
        }
    }
    t.print();

    let path_s = args.get_or("out", "BENCH_serve.json").to_string();
    let path = Path::new(&path_s);
    write_bench(path, &sweep_json(&results, &spec, &rates, &sched, seed))?;
    println!(
        "\nwrote {} ({} systems x {} rates, policy {}, {} replica(s), seed {seed})",
        path.display(),
        results.len(),
        rates.len(),
        sched.policy.label(),
        sched.n_replicas,
    );
    println!("same seed -> byte-identical file (all virtual time, seeded arrivals)");
    Ok(())
}
