//! Prefill mini-batching pipeline (paper §3.3 / Fig. 7): sweep the number
//! of mini-batches per worker transfer and report TTFT + worker idle time,
//! plus the §3.3 footnote's expert-activation counts during prefill.
//!
//! ```bash
//! cargo run --release --example prefill_pipeline
//! ```

use odmoe::cluster::{Cluster, HardwareProfile};
use odmoe::coordinator::prefill::simulate_odmoe_prefill;
use odmoe::engine::ModelState;
use odmoe::model::WeightStore;
use odmoe::util::table::Table;
use odmoe::workload::Corpus;

fn main() -> anyhow::Result<()> {
    let rt = odmoe::Runtime::load_default()?;
    let cfg = rt.cfg.clone();

    // ---- Fig. 7: TTFT vs mini-batch count -------------------------------
    println!("== Fig. 7: prefill TTFT vs mini-batches per worker ==\n");
    let mut t = Table::new(&["prompt len", "mini-batches", "TTFT ms", "worker wait ms"]);
    for &len in &[16usize, 128] {
        for &b in &[1usize, 2, 4, 8, 16] {
            let mut cluster = Cluster::new(HardwareProfile::rtx3090(), 8);
            let timing = simulate_odmoe_prefill(&mut cluster, &cfg, len, b);
            t.row(&[
                len.to_string(),
                b.to_string(),
                format!("{:.1}", timing.ttft_ms),
                format!("{:.1}", timing.worker_wait_ms),
            ]);
        }
    }
    t.print();
    println!("\n(paper Fig. 7: one large batch leaves workers idle during LAN");
    println!(" transfer; mini-batches pipeline transfer with compute)\n");

    // ---- §3.3 footnote: experts activated during prefill ----------------
    println!("== §3.3: experts activated per layer during prefill ==\n");
    let ws = WeightStore::generate(&cfg, 42);
    let mut state = ModelState::new(&rt, ws)?;
    let mut t2 = Table::new(&["prompt len", "avg experts/layer", "all-8 layers", "paper"]);
    for &len in &[16usize, 128] {
        let corpus = Corpus::generate(11, 4, len, cfg.vocab_size as u32);
        let mut sum = 0.0;
        let mut full = 0usize;
        let mut layers = 0usize;
        for prompt in &corpus.prompts {
            state.reset();
            let acts = state.prefill_activations(prompt)?;
            for layer in &acts {
                let n = layer.iter().filter(|&&b| b).count();
                sum += n as f64;
                if n == cfg.n_experts {
                    full += 1;
                }
                layers += 1;
            }
        }
        let paper = if len == 16 { "7.6 / 8" } else { "~8 (99.8%)" };
        t2.row(&[
            len.to_string(),
            format!("{:.2}", sum / layers as f64),
            format!("{full}/{layers}"),
            paper.to_string(),
        ]);
    }
    t2.print();
    Ok(())
}
