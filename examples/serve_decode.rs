//! End-to-end serving driver (the DESIGN.md validation run): serve a real
//! batched workload — the paper's speed-test corpus shape (short + long
//! prompts) — through OD-MoE *and* the fully-cached reference, verify the
//! token streams agree bit-exactly, and report latency/throughput.
//!
//! ```bash
//! cargo run --release --example serve_decode -- [--prompts 3] [--out-tokens 64]
//! ```
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used the defaults.

use odmoe::coordinator::baselines::FullyCachedEngine;
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine};
use odmoe::metrics::SpeedStats;
use odmoe::model::WeightStore;
use odmoe::util::cli::Args;
use odmoe::util::table::Table;
use odmoe::workload::speed::PAPER_LAYER_SCALE;
use odmoe::workload::Corpus;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let prompts = args.usize_or("prompts", 3)?;
    let out_tokens = args.usize_or("out-tokens", 64)?;
    let seed = args.u64_or("seed", 42)?;

    let rt = odmoe::Runtime::load_default()?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let (short, long) = Corpus::speed_set(seed, prompts, rt.cfg.vocab_size as u32);

    let mut od = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default())?;
    let mut reference = FullyCachedEngine::new(&rt, ws)?;

    let mut table = Table::new(&[
        "corpus", "prompt", "ttft ms*", "decode tok/s*", "stall ms", "exact",
    ]);
    let mut od_stats = SpeedStats::default();
    let mut ref_stats = SpeedStats::default();
    let wall = Instant::now();
    let mut served = 0usize;

    for (name, corpus) in [("short-16", &short), ("long-128", &long)] {
        for (i, prompt) in corpus.prompts.iter().enumerate() {
            od.reset()?;
            reference.reset()?;
            let r_od = od.run_prompt(prompt, out_tokens, false)?;
            let r_ref = reference.run_prompt(prompt, out_tokens, false)?;
            let exact = r_od.tokens == r_ref.tokens;
            assert!(exact, "OD-MoE must serve the full-precision stream");
            let n = r_od.tokens.len() - 1;
            od_stats.record(
                r_od.ttft_ms * PAPER_LAYER_SCALE,
                r_od.decode_ms * PAPER_LAYER_SCALE,
                n,
            );
            ref_stats.record(
                r_ref.ttft_ms * PAPER_LAYER_SCALE,
                r_ref.decode_ms * PAPER_LAYER_SCALE,
                n,
            );
            served += r_od.tokens.len();
            table.row(&[
                name.into(),
                format!("#{i}"),
                format!("{:.0}", r_od.ttft_ms * PAPER_LAYER_SCALE),
                format!("{:.3}", n as f64 / (r_od.decode_ms * PAPER_LAYER_SCALE / 1000.0)),
                format!("{:.1}", r_od.stall_ms),
                if exact { "yes" } else { "NO" }.into(),
            ]);
        }
    }
    table.print();

    let ratio = od_stats.decode_tps() / ref_stats.decode_tps();
    println!("\n== summary (paper-scale virtual time, * = 32-layer equivalent) ==");
    println!("od-moe   : TTFT {:.0} ms | decode {:.3} tok/s | output {:.3} tok/s",
             od_stats.mean_ttft_ms(), od_stats.decode_tps(), od_stats.output_tps());
    println!("reference: TTFT {:.0} ms | decode {:.3} tok/s | output {:.3} tok/s",
             ref_stats.mean_ttft_ms(), ref_stats.decode_tps(), ref_stats.output_tps());
    println!("decode ratio od-moe/fully-cached: {:.1}% (paper: ~75%)", ratio * 100.0);
    println!("tokens served: {served} | wall-clock: {:.1}s | PJRT executions: {}",
             wall.elapsed().as_secs_f64(), rt.stats.executions.get());
    Ok(())
}
