//! Perf probe (EXPERIMENTS.md §Perf, iteration 3): isolate the PJRT
//! host->device upload cost from execute dispatch, to decide whether
//! buffer-chaining the KV caches was worth pursuing. Verdict: a 64 KB
//! cache upload costs ~2 µs of an ~86 µs main-block call — dispatch and
//! interpret-mode HLO execution dominate, so no further buffer work.

fn main() -> anyhow::Result<()> {
    let rt = odmoe::Runtime::load_default()?;
    let cache = vec![0f32; 512 * 2 * 16];
    let t0 = std::time::Instant::now();
    for _ in 0..1000 {
        std::hint::black_box(rt.upload_f32(&cache, &[512, 2, 16])?);
    }
    println!("upload 64KB f32: {:.1} µs", t0.elapsed().as_micros() as f64 / 1000.0);
    let small = vec![0f32; 64];
    let t0 = std::time::Instant::now();
    for _ in 0..1000 {
        std::hint::black_box(rt.upload_f32(&small, &[1, 64])?);
    }
    println!("upload 256B f32: {:.1} µs", t0.elapsed().as_micros() as f64 / 1000.0);
    Ok(())
}
