//! SEP recall curves (the Fig. 3 experiment, interactively sized).
//!
//! Prints recall-vs-token-index series for each shadow precision and
//! alignment setup, plus sparkline shapes: aligned curves stay flat at
//! ~1.0, unaligned curves decay as autoregressive drift accumulates.
//!
//! ```bash
//! cargo run --release --example recall_curves -- [--prompts 4] [--out-tokens 48]
//! ```

use odmoe::model::{Precision, WeightStore};
use odmoe::predictor::AlignmentConfig;
use odmoe::util::cli::Args;
use odmoe::util::table::{print_series, sparkline};
use odmoe::workload::{recall, Corpus};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let prompts = args.usize_or("prompts", 4)?;
    let out_tokens = args.usize_or("out-tokens", 48)?;
    let seed = args.u64_or("seed", 42)?;
    let series = args.has("series"); // print full numeric series too

    let rt = odmoe::Runtime::load_default()?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 1, prompts, 16, rt.cfg.vocab_size as u32);

    for p in [Precision::Fp16, Precision::Int8, Precision::Nf4] {
        println!("== shadow precision: {} ==", p.label());
        for (label, align) in [
            ("unaligned        ", AlignmentConfig::none()),
            ("token-aligned    ", AlignmentConfig::token_only()),
            ("token+KV aligned ", AlignmentConfig::every_iteration()),
        ] {
            let stats = recall::sep_recall(&rt, &ws, p, align, &corpus, out_tokens)?;
            let curve = stats.curve();
            println!(
                "  {label} overall={:.4}  {}",
                stats.recall(),
                sparkline(&curve)
            );
            if series {
                let xs: Vec<f64> = (0..curve.len()).map(|i| i as f64).collect();
                print_series(&format!("{} {label}", p.label()), &xs, &curve);
            }
        }
        println!();
    }
    println!("paper Fig. 3: with token+KV alignment every iteration, recall is");
    println!("0.9994 (fp16), 0.9734 (int8), 0.9567 (nf4); unaligned curves decay");
    println!("toward ~0.3 by token 256.");
    Ok(())
}
