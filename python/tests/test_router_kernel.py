"""Fused RMSNorm+matmul / router kernel vs oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, router

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    t=st.sampled_from([1, 2, 7, 16, 128]),
    d=st.sampled_from([8, 64]),
    out=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**16),
)
def test_rms_norm_matmul_matches_ref(t, d, out, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    g = jnp.asarray((1 + 0.1 * rng.standard_normal(d)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((d, out)).astype(np.float32) * 0.2)
    got = router.rms_norm_matmul(x, g, w)
    want = ref.rms_norm(x, g) @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**16))
def test_router_topk_consistency(k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
    g = jnp.asarray(np.ones(64, np.float32))
    wg = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32) * 0.3)
    w, idx, logits = router.router(x, g, wg, k)
    lref = ref.router_logits(ref.rms_norm(x, g), wg)
    wref, iref = ref.router_topk(lref, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(iref))
    np.testing.assert_allclose(np.asarray(w), np.asarray(wref), rtol=1e-5, atol=1e-6)
    # Routing weights are a valid distribution over the k selected experts.
    np.testing.assert_allclose(np.asarray(w).sum(-1), np.ones(5), rtol=1e-5)


def test_router_weights_sorted_descending():
    # top_k returns values in descending order; softmax preserves order.
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((9, 64)).astype(np.float32))
    g = jnp.asarray(np.ones(64, np.float32))
    wg = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    w, _, _ = router.router(x, g, wg, 2)
    w = np.asarray(w)
    assert (w[:, 0] >= w[:, 1]).all()


def test_rms_norm_scale_invariance():
    # rmsnorm(c*x) == rmsnorm(x) for c > 0 (up to eps effects).
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((3, 64)).astype(np.float32))
    g = jnp.asarray(np.ones(64, np.float32))
    w = jnp.asarray(np.eye(64, dtype=np.float32))
    a = np.asarray(router.rms_norm_matmul(x, g, w))
    b = np.asarray(router.rms_norm_matmul(x * 10.0, g, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
