"""L2 model graphs: pallas-backed blocks vs pure-jnp reference model,
plus shape/contract checks for everything aot.py exports."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import DEFAULT as CFG
from compile.kernels import ref


def _decode_inputs(seed, pos):
    rng = np.random.default_rng(seed)
    d, q, kv, e = CFG.d_model, CFG.q_dim, CFG.kv_dim, CFG.n_experts
    mk = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.15)
    k_cache = np.zeros((CFG.max_seq_len, CFG.n_kv_heads, CFG.head_dim), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[:pos] = rng.standard_normal(k_cache[:pos].shape) * 0.3
    v_cache[:pos] = rng.standard_normal(v_cache[:pos].shape) * 0.3
    return [
        mk(1, d), 1.0 + 0.1 * mk(d), mk(d, q), mk(d, kv), mk(d, kv), mk(q, d),
        1.0 + 0.1 * mk(d), mk(d, e),
        jnp.asarray(k_cache), jnp.asarray(v_cache), jnp.asarray([pos], jnp.int32),
    ]


@pytest.mark.parametrize("pos", [0, 1, 7, 100])
def test_main_block_decode_matches_ref(pos):
    args = _decode_inputs(42 + pos, pos)
    got = jax.jit(model.main_block_decode(CFG))(*args)
    want = model.ref_main_block_decode(CFG)(*args)
    names = ["x_resid", "h_norm", "route_w", "route_idx", "k_new", "v_new"]
    for n, g, w in zip(names, got, want):
        if g.dtype == jnp.int32:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=n)
        else:
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=5e-5, atol=5e-5, err_msg=n
            )


def test_main_block_decode_output_shapes():
    out = jax.jit(model.main_block_decode(CFG))(*_decode_inputs(0, 3))
    assert out[0].shape == (1, CFG.d_model)
    assert out[1].shape == (1, CFG.d_model)
    assert out[2].shape == (1, CFG.top_k)
    assert out[3].shape == (1, CFG.top_k) and out[3].dtype == jnp.int32
    assert out[4].shape == (1, CFG.n_kv_heads, CFG.head_dim)
    assert out[5].shape == (1, CFG.n_kv_heads, CFG.head_dim)


def test_route_idx_in_range():
    out = jax.jit(model.main_block_decode(CFG))(*_decode_inputs(5, 2))
    idx = np.asarray(out[3])
    assert ((idx >= 0) & (idx < CFG.n_experts)).all()
    assert idx[0, 0] != idx[0, 1], "top-2 must select distinct experts"


@pytest.mark.parametrize("T", [16, 128])
def test_prefill_consistent_with_decode(T):
    """Running the prefill graph must agree with T sequential decode steps —
    the cross-check that the two attention paths implement one model."""
    rng = np.random.default_rng(100 + T)
    d, q, kv, e = CFG.d_model, CFG.q_dim, CFG.kv_dim, CFG.n_experts
    mk = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.15)
    w = [1.0 + 0.1 * mk(d), mk(d, q), mk(d, kv), mk(d, kv), mk(q, d),
         1.0 + 0.1 * mk(d), mk(d, e)]
    x = mk(T, d)
    pre = jax.jit(model.main_block_prefill(CFG, T))(x, *w)

    dec_fn = jax.jit(model.main_block_decode(CFG))
    k_cache = jnp.zeros((CFG.max_seq_len, CFG.n_kv_heads, CFG.head_dim))
    v_cache = jnp.zeros_like(k_cache)
    for t in range(T):
        out = dec_fn(x[t : t + 1], *w, k_cache, v_cache, jnp.asarray([t], jnp.int32))
        k_cache = jax.lax.dynamic_update_slice(k_cache, out[4], (t, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, out[5], (t, 0, 0))
        np.testing.assert_allclose(
            np.asarray(pre[0][t]), np.asarray(out[0][0]), rtol=2e-4, atol=2e-4,
            err_msg=f"x_resid token {t}",
        )
    # Router decisions for the last token must agree.
    np.testing.assert_array_equal(np.asarray(pre[3][-1]), np.asarray(out[3][0]))


def test_lm_head_greedy_argmax():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((1, CFG.d_model)).astype(np.float32))
    g = jnp.asarray(np.ones(CFG.d_model, np.float32))
    w_out = jnp.asarray(rng.standard_normal((CFG.d_model, CFG.vocab_size)).astype(np.float32))
    logits, tok = jax.jit(model.lm_head(CFG))(x, g, w_out)
    assert logits.shape == (1, CFG.vocab_size)
    assert int(tok[0]) == int(np.argmax(np.asarray(logits)[0]))


def test_expert_ffn_graph_matches_ref():
    rng = np.random.default_rng(9)
    h = jnp.asarray(rng.standard_normal((4, CFG.d_model)).astype(np.float32))
    w1 = jnp.asarray(rng.standard_normal((CFG.d_model, CFG.d_ff)).astype(np.float32) * 0.2)
    w3 = jnp.asarray(rng.standard_normal((CFG.d_model, CFG.d_ff)).astype(np.float32) * 0.2)
    w2 = jnp.asarray(rng.standard_normal((CFG.d_ff, CFG.d_model)).astype(np.float32) * 0.2)
    (got,) = jax.jit(model.expert_ffn(CFG))(h, w1, w3, w2)
    want = ref.swiglu_ffn(h, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_quantized_routing_agreement_rate():
    """The SEP premise (paper §2.3/§3.2): a fake-quantized block selects the
    same experts as the full-precision block almost always."""
    agree = {"fp16": 0, "int8": 0, "nf4": 0}
    trials = 40
    fn = jax.jit(model.main_block_decode(CFG))
    for t in range(trials):
        args = _decode_inputs(1000 + t, 3)
        full_idx = np.sort(np.asarray(fn(*args)[3])[0])
        for mode in agree:
            qargs = list(args)
            # Quantize every weight matrix (indices 1..7).
            for i in range(1, 8):
                qargs[i] = ref.fake_quant(args[i], mode)
            q_idx = np.sort(np.asarray(fn(*qargs)[3])[0])
            agree[mode] += int((full_idx == q_idx).all())
    assert agree["fp16"] >= trials * 0.95, agree
    assert agree["int8"] >= trials * 0.85, agree
    assert agree["nf4"] >= trials * 0.70, agree
