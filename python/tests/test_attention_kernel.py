"""Decode-attention Pallas kernel vs oracle + attention invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _case(rng, max_seq, n_heads, n_kv, hd):
    q = jnp.asarray(rng.standard_normal((n_heads, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((max_seq, n_kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((max_seq, n_kv, hd)).astype(np.float32))
    return q, k, v


@settings(**SETTINGS)
@given(
    max_seq=st.sampled_from([8, 32, 512]),
    heads=st.sampled_from([(4, 2), (4, 4), (8, 2)]),
    hd=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
    frac=st.floats(0.01, 1.0),
)
def test_decode_attention_matches_ref(max_seq, heads, hd, seed, frac):
    n_heads, n_kv = heads
    rng = np.random.default_rng(seed)
    q, k, v = _case(rng, max_seq, n_heads, n_kv, hd)
    seq_len = max(1, int(max_seq * frac))
    got = attention.decode_attention(q, k, v, jnp.asarray([seq_len], jnp.int32))
    want = ref.gqa_attention_decode(q, k, v, jnp.asarray(seq_len))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_masking_ignores_padded_tail():
    # Garbage past seq_len must not affect the output.
    rng = np.random.default_rng(7)
    q, k, v = _case(rng, 32, 4, 2, 16)
    seq_len = jnp.asarray([5], jnp.int32)
    base = np.asarray(attention.decode_attention(q, k, v, seq_len))
    k2 = k.at[5:].set(1e6)
    v2 = v.at[5:].set(-1e6)
    poisoned = np.asarray(attention.decode_attention(q, k2, v2, seq_len))
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_single_valid_token_returns_its_value():
    # With seq_len=1 softmax collapses to the first cached V row.
    rng = np.random.default_rng(8)
    q, k, v = _case(rng, 16, 4, 2, 16)
    out = np.asarray(attention.decode_attention(q, k, v, jnp.asarray([1], jnp.int32)))
    expect = np.repeat(np.asarray(v[0]), 2, axis=0)  # kv head -> 2 q heads each
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_prefill_ref_matches_decode_ref_last_token():
    # Causal prefill's last row == decode attention over the same cache.
    rng = np.random.default_rng(9)
    T, n_heads, n_kv, hd = 12, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((T, n_heads, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((T, n_kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((T, n_kv, hd)).astype(np.float32))
    pre = ref.gqa_attention_prefill(q, k, v)
    dec = ref.gqa_attention_decode(q[-1], k, v, jnp.asarray(T))
    np.testing.assert_allclose(np.asarray(pre[-1]), np.asarray(dec), rtol=1e-5, atol=1e-5)
