"""Quantization oracles + dequant-matmul Pallas kernels (shadow path)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(rows=st.sampled_from([8, 64]), cols=st.sampled_from([16, 128]),
       seed=st.integers(0, 2**16))
def test_int8_roundtrip_error_bound(rows, cols, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    q, s = ref.quantize_int8(jnp.asarray(w))
    back = np.asarray(ref.dequantize_int8(q, s))
    # Max quantization error is half a step: absmax/127/2 per row.
    step = np.abs(w).max(axis=1, keepdims=True) / 127.0
    assert (np.abs(back - w) <= step * 0.5 + 1e-7).all()


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_nf4_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    c, s = ref.quantize_nf4(jnp.asarray(w))
    back = np.asarray(ref.dequantize_nf4(c, s, w.shape))
    # NF4 error bounded by largest inter-level gap (~0.30 of blockwise absmax).
    blocks = w.reshape(-1, 64)
    absmax = np.abs(blocks).max(axis=1, keepdims=True)
    err = np.abs(back.reshape(-1, 64) - blocks)
    assert (err <= 0.16 * absmax + 1e-7).all()


def test_nf4_levels_are_sorted_and_symmetric_endpoints():
    lv = np.asarray(ref.NF4_LEVELS)
    assert (np.diff(lv) > 0).all()
    assert lv[0] == -1.0 and lv[-1] == 1.0 and lv[7] == 0.0


@settings(**SETTINGS)
@given(t=st.sampled_from([1, 4, 16]), seed=st.integers(0, 2**16))
def test_int8_matmul_kernel_matches_dequant_ref(t, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, 64)).astype(np.float32))
    w = rng.standard_normal((64, 128)).astype(np.float32) * 0.2
    q, s = ref.quantize_int8(jnp.asarray(w))
    got = quant.int8_matmul(x, q, s)
    want = x @ ref.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(t=st.sampled_from([1, 4, 16]), seed=st.integers(0, 2**16))
def test_nf4_matmul_kernel_matches_dequant_ref(t, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, 64)).astype(np.float32))
    w = rng.standard_normal((64, 128)).astype(np.float32) * 0.2
    c, s = ref.quantize_nf4(jnp.asarray(w))
    got = quant.nf4_matmul(x, c, s, d=64, out=128)
    want = x @ ref.dequantize_nf4(c, s, (64, 128))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_int8_swiglu_close_to_full_precision():
    # The quantized expert must track the full-precision expert closely —
    # this is the phenomenon SEP relies on (paper §3.2).
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32) * 0.3)
    w1 = rng.standard_normal((64, 128)).astype(np.float32) * 0.15
    w3 = rng.standard_normal((64, 128)).astype(np.float32) * 0.15
    w2 = rng.standard_normal((128, 64)).astype(np.float32) * 0.15
    q1, s1 = ref.quantize_int8(jnp.asarray(w1))
    q3, s3 = ref.quantize_int8(jnp.asarray(w3))
    q2, s2 = ref.quantize_int8(jnp.asarray(w2))
    approx = np.asarray(quant.int8_swiglu_ffn(x, q1, s1, q3, s3, q2, s2))
    exact = np.asarray(ref.swiglu_ffn(x, jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2)))
    rel = np.linalg.norm(approx - exact) / np.linalg.norm(exact)
    assert rel < 0.02, f"int8 expert diverges from fp32: rel={rel:.4f}"


def test_fake_quant_modes():
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    assert np.asarray(ref.fake_quant(w, "fp32") == w).all()
    errs = {}
    for m in ("fp16", "int8", "nf4"):
        errs[m] = float(np.abs(np.asarray(ref.fake_quant(w, m)) - np.asarray(w)).max())
    # Error ordering must reflect precision: fp16 < int8 < nf4.
    assert errs["fp16"] < errs["int8"] < errs["nf4"]
