"""AOT path: HLO-text artifacts exist, parse, and checks.json is
self-consistent (known-answer inputs reproduce recorded outputs)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.config import DEFAULT as CFG

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "checks.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _checks():
    with open(os.path.join(ART, "checks.json")) as fh:
        return json.load(fh)


def test_all_expected_artifacts_present():
    names = {"main_block_decode", "lm_head"}
    names |= {f"expert_ffn_t{t}" for t in aot.EXPERT_FFN_SIZES}
    names |= {f"main_block_prefill_t{t}" for t in aot.PREFILL_SIZES}
    for n in names:
        path = os.path.join(ART, f"{n}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {n}"
        head = open(path).read(200)
        assert head.startswith("HloModule"), f"{n} is not HLO text"


def test_config_json_matches_default():
    with open(os.path.join(ART, "config.json")) as fh:
        cfg = json.load(fh)
    assert cfg["d_model"] == CFG.d_model
    assert cfg["n_experts"] == CFG.n_experts
    assert cfg["top_k"] == CFG.top_k
    assert cfg["n_layers"] == CFG.n_layers


def test_checks_cover_all_artifacts():
    checks = _checks()
    hlo_files = [f for f in os.listdir(ART) if f.endswith(".hlo.txt")]
    assert len(checks) == len(hlo_files)


def test_known_answers_reproduce():
    """Re-executing each graph on the recorded inputs reproduces the
    recorded outputs — guards against checks.json going stale."""
    checks = _checks()
    fns = {"main_block_decode": model.main_block_decode(CFG),
           "lm_head": model.lm_head(CFG)}
    for t in aot.EXPERT_FFN_SIZES:
        fns[f"expert_ffn_t{t}"] = model.expert_ffn(CFG)
    for t in aot.PREFILL_SIZES:
        fns[f"main_block_prefill_t{t}"] = model.main_block_prefill(CFG, t)
    for name, c in checks.items():
        args = [
            jnp.asarray(np.array(v, dtype=dt).reshape(s))
            for v, s, dt in zip(c["inputs"], c["input_shapes"], c["input_dtypes"])
        ]
        outs = jax.jit(fns[name])(*args)
        for i, (o, want, shape) in enumerate(
            zip(outs, c["outputs"], c["output_shapes"])
        ):
            np.testing.assert_allclose(
                np.asarray(o).ravel(), np.array(want, np.float64), rtol=1e-5,
                atol=1e-5, err_msg=f"{name} output {i}",
            )
            assert list(o.shape) == shape


def test_hlo_text_stable_under_relower():
    """Lowering the decode block twice yields identical HLO text — the
    artifact build is deterministic."""
    checks = _checks()
    c = checks["main_block_decode"]
    args = [
        jax.ShapeDtypeStruct(tuple(s), np.dtype(dt))
        for s, dt in zip(c["input_shapes"], c["input_dtypes"])
    ]
    t1 = aot.to_hlo_text(jax.jit(model.main_block_decode(CFG)).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(model.main_block_decode(CFG)).lower(*args))
    assert t1 == t2
