"""Pallas fused-SwiGLU kernel vs the pure-jnp oracle.

Hypothesis sweeps token counts and dimension combinations; this is the
core correctness signal for the L1 hot-spot (DESIGN.md §3).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moe_ffn, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _mats(rng, t, d, f):
    mk = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.2)
    return mk(t, d), mk(d, f), mk(d, f), mk(f, d)


@settings(**SETTINGS)
@given(
    t=st.sampled_from([1, 2, 3, 5, 8, 16, 33, 64, 128, 200]),
    d=st.sampled_from([8, 16, 64]),
    f=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_swiglu_matches_ref(t, d, f, seed):
    rng = np.random.default_rng(seed)
    x, w1, w3, w2 = _mats(rng, t, d, f)
    got = moe_ffn.swiglu_ffn(x, w1, w3, w2)
    want = ref.swiglu_ffn(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_zero_input_gives_zero():
    x = jnp.zeros((4, 64))
    rng = np.random.default_rng(1)
    _, w1, w3, w2 = _mats(rng, 4, 64, 128)
    out = moe_ffn.swiglu_ffn(x, w1, w3, w2)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 64), np.float32))


def test_large_magnitude_stable():
    # silu saturates; kernel must not produce nan/inf for large activations.
    rng = np.random.default_rng(2)
    x, w1, w3, w2 = _mats(rng, 8, 64, 128)
    out = np.asarray(moe_ffn.swiglu_ffn(x * 100.0, w1, w3, w2))
    assert np.isfinite(out).all()


@pytest.mark.parametrize("t,expect", [(1, 1), (16, 16), (64, 64), (128, 64), (96, 32), (100, 4)])
def test_pick_block_t(t, expect):
    bt = moe_ffn.pick_block_t(t)
    assert bt == expect
    assert t % bt == 0


@pytest.mark.parametrize("t", [1, 64, 128])
def test_vmem_budget(t):
    # DESIGN.md §7: per-grid-step VMEM must stay far below ~16 MiB.
    assert moe_ffn.vmem_bytes(t, 64, 128) < 1 << 20


def test_rows_independent():
    # Token rows must not interact: FFN is position-wise.
    rng = np.random.default_rng(3)
    x, w1, w3, w2 = _mats(rng, 6, 16, 32)
    full = np.asarray(moe_ffn.swiglu_ffn(x, w1, w3, w2))
    for i in range(6):
        row = np.asarray(moe_ffn.swiglu_ffn(x[i : i + 1], w1, w3, w2))
        np.testing.assert_allclose(full[i : i + 1], row, rtol=1e-5, atol=1e-6)
