"""Model configuration shared by all compile-path modules.

This is the single source of truth for the Tiny-Mixtral architecture used
throughout the repo. The Rust side mirrors these defaults in
`rust/src/model/config.rs`; `aot.py` additionally embeds the config as JSON
next to the HLO artifacts so the Rust loader can verify it is running
against artifacts built for the same shapes.
"""

from dataclasses import dataclass, asdict, field
import json


@dataclass(frozen=True)
class ModelConfig:
    """Tiny-Mixtral: architecturally faithful, scale-reduced Mixtral-8x7B.

    Same component structure as the paper's base model (RMSNorm, rotary
    GQA attention, softmax top-k router, SwiGLU experts); reduced
    dimensions so the full stack runs on a CPU-only PJRT client.
    """

    vocab_size: int = 256
    d_model: int = 64
    n_layers: int = 12
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 16
    d_ff: int = 128           # per-expert SwiGLU hidden size
    n_experts: int = 8
    top_k: int = 2
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    max_seq_len: int = 512    # KV-cache capacity baked into decode graphs

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def expert_param_count(self) -> int:
        # w1 (gate), w3 (up): d_model x d_ff; w2 (down): d_ff x d_model.
        return 3 * self.d_model * self.d_ff

    @property
    def expert_bytes_f32(self) -> int:
        return self.expert_param_count * 4

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=2, sort_keys=True)


DEFAULT = ModelConfig()
