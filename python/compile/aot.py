"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run via `make artifacts` (no-op if artifacts are newer than inputs).
Python appears ONLY here — the Rust binary is self-contained afterwards.

Interchange format is HLO text, NOT `lowered.compile().serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
image's xla_extension 0.5.1 (behind the `xla` crate) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Alongside the artifacts this writes:
  * config.json  — the ModelConfig the graphs were specialized for; the
    Rust loader refuses to run against a mismatched config.
  * checks.json  — known-answer tests: for each artifact, a deterministic
    seeded input set and the jit-executed outputs. Rust integration tests
    execute the artifact through PJRT and assert allclose, validating the
    whole python->HLO-text->rust round trip numerically.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import DEFAULT, ModelConfig
from . import model

# Token counts the expert-FFN executable is specialized for: 1 for decode,
# the rest for prefill mini-batches (Fig. 7 sweep) and full batches.
EXPERT_FFN_SIZES = (1, 4, 8, 16, 32, 64, 128)
# Prompt lengths the prefill main-block is specialized for (paper's speed
# corpus uses 16- and 128-token prompts).
PREFILL_SIZES = (16, 128)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, matching load_hlo.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _rng(seed: int):
    return np.random.default_rng(seed)


def _weights_decode(cfg: ModelConfig, rng):
    """Deterministic example weights for checks.json (NOT the model weights
    used at runtime — Rust generates those itself)."""
    d, q, kv, e, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.n_experts, cfg.d_ff
    mk = lambda *s: rng.standard_normal(s).astype(np.float32) * 0.15
    return dict(
        x=mk(1, d),
        attn_g=1.0 + 0.1 * mk(d).reshape(d),
        wq=mk(d, q), wk=mk(d, kv), wv=mk(d, kv), wo=mk(q, d),
        ffn_g=1.0 + 0.1 * mk(d).reshape(d),
        w_gate=mk(d, e),
    )


def build_artifacts(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    checks = {}

    def emit(name: str, fn, example_args: list):
        specs = [jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
                 for a in example_args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        outs = jax.jit(fn)(*[jnp.asarray(a) for a in example_args])
        checks[name] = {
            "inputs": [np.asarray(a).ravel().tolist() for a in example_args],
            "input_shapes": [list(np.asarray(a).shape) for a in example_args],
            "input_dtypes": [str(np.asarray(a).dtype) for a in example_args],
            "outputs": [np.asarray(o).ravel().tolist() for o in outs],
            "output_shapes": [list(np.asarray(o).shape) for o in outs],
            "output_dtypes": [str(np.asarray(o).dtype) for o in outs],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  {name}: {len(text)} chars -> {path}")

    # --- decode main block -------------------------------------------------
    rng = _rng(0xD0)
    w = _weights_decode(cfg, rng)
    pos = 3  # example: cache already holds 3 tokens
    k_cache = np.zeros((cfg.max_seq_len, cfg.n_kv_heads, cfg.head_dim), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[:pos] = rng.standard_normal(k_cache[:pos].shape).astype(np.float32) * 0.3
    v_cache[:pos] = rng.standard_normal(v_cache[:pos].shape).astype(np.float32) * 0.3
    emit(
        "main_block_decode",
        model.main_block_decode(cfg),
        [w["x"], w["attn_g"], w["wq"], w["wk"], w["wv"], w["wo"],
         w["ffn_g"], w["w_gate"], k_cache, v_cache,
         np.array([pos], np.int32)],
    )

    # --- prefill main blocks -----------------------------------------------
    for T in PREFILL_SIZES:
        rng = _rng(0xF0 + T)
        w = _weights_decode(cfg, rng)
        x = rng.standard_normal((T, cfg.d_model)).astype(np.float32) * 0.15
        emit(
            f"main_block_prefill_t{T}",
            model.main_block_prefill(cfg, T),
            [x, w["attn_g"], w["wq"], w["wk"], w["wv"], w["wo"],
             w["ffn_g"], w["w_gate"]],
        )

    # --- expert FFN (the pallas hot-spot), one executable per batch size ----
    for T in EXPERT_FFN_SIZES:
        rng = _rng(0xE0 + T)
        h = rng.standard_normal((T, cfg.d_model)).astype(np.float32) * 0.3
        w1 = rng.standard_normal((cfg.d_model, cfg.d_ff)).astype(np.float32) * 0.15
        w3 = rng.standard_normal((cfg.d_model, cfg.d_ff)).astype(np.float32) * 0.15
        w2 = rng.standard_normal((cfg.d_ff, cfg.d_model)).astype(np.float32) * 0.15
        emit(f"expert_ffn_t{T}", model.expert_ffn(cfg), [h, w1, w3, w2])

    # --- LM head -------------------------------------------------------------
    rng = _rng(0x1A)
    x = rng.standard_normal((1, cfg.d_model)).astype(np.float32) * 0.3
    g = (1.0 + 0.1 * rng.standard_normal(cfg.d_model)).astype(np.float32)
    w_out = rng.standard_normal((cfg.d_model, cfg.vocab_size)).astype(np.float32) * 0.15
    emit("lm_head", model.lm_head(cfg), [x, g, w_out])

    return checks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    cfg = DEFAULT
    print(f"lowering Tiny-Mixtral graphs (d={cfg.d_model}, L={cfg.n_layers}, "
          f"E={cfg.n_experts}, top-{cfg.top_k}) -> {args.out}")
    checks = build_artifacts(cfg, args.out)
    with open(os.path.join(args.out, "config.json"), "w") as fh:
        fh.write(cfg.to_json())
    with open(os.path.join(args.out, "checks.json"), "w") as fh:
        json.dump(checks, fh)
    # Sentinel consumed by the Makefile's up-to-date check.
    with open(os.path.join(args.out, ".stamp"), "w") as fh:
        fh.write("ok\n")
    print(f"wrote {len(checks)} artifacts + config.json + checks.json")


if __name__ == "__main__":
    main()
