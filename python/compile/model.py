"""Layer-2: Tiny-Mixtral compute graphs in JAX, calling the L1 kernels.

Every function here is a *pure* graph over explicit weight arguments — no
parameter capture — so the Rust coordinator owns all weights (full
precision AND fake-quantized shadow variants) and feeds them as runtime
inputs to the AOT-compiled executables. One HLO artifact therefore serves
both the full-precision model and every shadow quantization level.

Graphs exported by aot.py:
  main_block_decode    non-expert per-layer work for ONE token: fused
                       norm+QKV (pallas), RoPE, cache update, decode
                       attention (pallas), output proj, fused norm+router
                       (pallas), top-k.
  main_block_prefill   same for a T-token prompt with causal attention.
  expert_ffn           fused SwiGLU expert (pallas) for a given T.
  lm_head              final RMSNorm + logits + greedy argmax.

The decode KV cache is a fixed-capacity padded buffer owned by Rust; the
graph receives the cache *before* the new token, computes the new K/V row,
attends over the updated cache, and returns the new row for Rust to commit
(outputs stay small: no full-cache round-trip per layer).
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import attention as attn_k
from .kernels import moe_ffn as ffn_k
from .kernels import ref
from .kernels import router as router_k


def rope_decode(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """RoPE for one token. x: [n_heads, head_dim], pos: [1] i32."""
    return ref.rope(x[None, ...], pos, theta)[0]


def main_block_decode(cfg: ModelConfig):
    """Returns fn(x, attn_g, wq, wk, wv, wo, ffn_g, w_gate,
                  k_cache, v_cache, pos) ->
         (x_resid [1,d], h_norm [1,d], route_w [1,k], route_idx [1,k] i32,
          k_new [1,n_kv,hd], v_new [1,n_kv,hd])

    x: [1, d_model] residual stream entering the layer.
    k_cache/v_cache: [max_seq, n_kv, hd] padded, valid length == pos.
    h_norm is the post-attention normalized hidden state the main node
    ships to worker nodes (the "embedding" of Fig. 2 step c/d).
    """

    def fn(x, attn_g, wq, wk, wv, wo, ffn_g, w_gate, k_cache, v_cache, pos):
        d = cfg.d_model
        # Fused RMSNorm + QKV projection (single pallas kernel over the
        # concatenated [d, q+kv+kv] weight keeps x resident in VMEM once).
        wqkv = jnp.concatenate([wq, wk, wv], axis=1)
        qkv = router_k.rms_norm_matmul(x, attn_g, wqkv, eps=cfg.rms_eps)  # [1, q+2kv]
        q = qkv[0, : cfg.q_dim].reshape(cfg.n_heads, cfg.head_dim)
        k = qkv[0, cfg.q_dim : cfg.q_dim + cfg.kv_dim].reshape(cfg.n_kv_heads, cfg.head_dim)
        v = qkv[0, cfg.q_dim + cfg.kv_dim :].reshape(cfg.n_kv_heads, cfg.head_dim)
        q = rope_decode(q, pos, cfg.rope_theta)
        k = rope_decode(k, pos, cfg.rope_theta)
        # Commit the new row into the padded cache, then attend over it.
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, ...], (pos[0], 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, ...], (pos[0], 0, 0))
        o = attn_k.decode_attention(q, k_cache, v_cache, pos + 1)  # [n_heads, hd]
        x_resid = x + o.reshape(1, cfg.q_dim) @ wo
        # Fused RMSNorm + router logits, then top-k softmax.
        route_w, route_idx, _ = router_k.router(
            x_resid, ffn_g, w_gate, cfg.top_k, eps=cfg.rms_eps
        )
        # h_norm (what workers consume) via plain jnp RMSNorm: XLA fuses
        # this into a couple of elementwise ops — the earlier
        # rms_norm_matmul-against-identity spent a whole pallas matmul on
        # it (EXPERIMENTS.md §Perf, L2 iteration 1).
        h_norm = ref.rms_norm(x_resid, ffn_g, cfg.rms_eps)
        _ = d
        return x_resid, h_norm, route_w, route_idx, k[None, ...], v[None, ...]

    return fn


def main_block_prefill(cfg: ModelConfig, seq_len: int):
    """Prefill (batched) variant over a fixed T-token prompt.

    fn(x [T,d], attn_g, wq, wk, wv, wo, ffn_g, w_gate) ->
      (x_resid [T,d], h_norm [T,d], route_w [T,k], route_idx [T,k] i32,
       k_all [T,n_kv,hd], v_all [T,n_kv,hd])
    """

    def fn(x, attn_g, wq, wk, wv, wo, ffn_g, w_gate):
        d = cfg.d_model
        T = seq_len
        positions = jnp.arange(T, dtype=jnp.int32)
        wqkv = jnp.concatenate([wq, wk, wv], axis=1)
        qkv = router_k.rms_norm_matmul(x, attn_g, wqkv, eps=cfg.rms_eps)
        q = qkv[:, : cfg.q_dim].reshape(T, cfg.n_heads, cfg.head_dim)
        k = qkv[:, cfg.q_dim : cfg.q_dim + cfg.kv_dim].reshape(T, cfg.n_kv_heads, cfg.head_dim)
        v = qkv[:, cfg.q_dim + cfg.kv_dim :].reshape(T, cfg.n_kv_heads, cfg.head_dim)
        q = ref.rope(q, positions, cfg.rope_theta)
        k = ref.rope(k, positions, cfg.rope_theta)
        o = ref.gqa_attention_prefill(q, k, v)  # [T, n_heads, hd]
        x_resid = x + o.reshape(T, cfg.q_dim) @ wo
        route_w, route_idx, _ = router_k.router(
            x_resid, ffn_g, w_gate, cfg.top_k, eps=cfg.rms_eps
        )
        h_norm = ref.rms_norm(x_resid, ffn_g, cfg.rms_eps)
        _ = d
        return x_resid, h_norm, route_w, route_idx, k, v

    return fn


def expert_ffn(cfg: ModelConfig):
    """fn(h [T,d], w1 [d,f], w3 [d,f], w2 [f,d]) -> (y [T,d],).

    The worker-node computation: the fused SwiGLU pallas kernel. The
    router weight is applied by the caller (main node combines
    `sum_k route_w[k] * y_k` on the residual stream).
    """

    def fn(h, w1, w3, w2):
        return (ffn_k.swiglu_ffn(h, w1, w3, w2),)

    return fn


def lm_head(cfg: ModelConfig):
    """fn(x [1,d], final_g [d], w_out [d,V]) -> (logits [1,V], tok [1] i32).

    Greedy decoding (paper §4.1): argmax over logits, no sampling.
    """

    def fn(x, final_g, w_out):
        logits = router_k.rms_norm_matmul(x, final_g, w_out, eps=cfg.rms_eps)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, tok

    return fn


# ---------------------------------------------------------------------------
# Pure-jnp reference model (oracle for integration tests + checks.json).
# ---------------------------------------------------------------------------


def ref_main_block_decode(cfg: ModelConfig):
    """Same contract as main_block_decode but built only from ref.* ops."""

    def fn(x, attn_g, wq, wk, wv, wo, ffn_g, w_gate, k_cache, v_cache, pos):
        xn = ref.rms_norm(x, attn_g, cfg.rms_eps)
        q = (xn @ wq).reshape(cfg.n_heads, cfg.head_dim)
        k = (xn @ wk).reshape(cfg.n_kv_heads, cfg.head_dim)
        v = (xn @ wv).reshape(cfg.n_kv_heads, cfg.head_dim)
        q = rope_decode(q, pos, cfg.rope_theta)
        k = rope_decode(k, pos, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, ...], (pos[0], 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, ...], (pos[0], 0, 0))
        o = ref.gqa_attention_decode(q, k_cache, v_cache, pos[0] + 1)
        x_resid = x + o.reshape(1, cfg.q_dim) @ wo
        h_norm = ref.rms_norm(x_resid, ffn_g, cfg.rms_eps)
        logits = ref.router_logits(h_norm, w_gate)
        route_w, route_idx = ref.router_topk(logits, cfg.top_k)
        return x_resid, h_norm, route_w, route_idx, k[None, ...], v[None, ...]

    return fn
