"""Fused SwiGLU expert-FFN Pallas kernel — the paper's compute hot-spot.

The paper executes each expert's FFN (`w2 @ (silu(w1 x) * w3 x)`) on a
worker GPU's CUDA cores. TPU adaptation (DESIGN.md §3): the three matmuls
are fused into ONE kernel so the [T, d_ff] intermediates (gate, up) live
entirely in VMEM and never round-trip to HBM, and every matmul requests
`preferred_element_type=float32` to target the MXU systolic array.

Blocking: the full per-expert weight set (w1, w3: [d_model, d_ff],
w2: [d_ff, d_model]) is mapped into VMEM once (index_map pins them to
block (0, 0) for every grid step) while the token axis is tiled with
`block_t` rows per grid step. VMEM footprint per grid step:

    3 * d_model * d_ff * 4 B   (weights, 96 KiB at 64x128)
  + block_t * (2*d_ff + 2*d_model) * 4 B   (x, gate/up, out)

which stays far below the ~16 MiB VMEM budget for every configuration we
ship — see `vmem_bytes()` used by tests and DESIGN.md §7.

interpret=True always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One grid step: [block_t, d_model] tokens through the fused FFN."""
    x = x_ref[...]
    # Gate and up projections hit the MXU back-to-back while x is hot in VMEM.
    gate = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    up = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    # SiLU on the VPU; the [block_t, d_ff] intermediate never leaves VMEM.
    act = gate * jax.lax.logistic(gate) * up
    o_ref[...] = jnp.dot(act, w2_ref[...], preferred_element_type=jnp.float32)


def pick_block_t(t: int) -> int:
    """Token-axis tile: whole batch if small, else the largest power-of-two
    divisor of t capped at 64 (keeps the activation tile ~64 KiB)."""
    if t <= 64:
        return t
    bt = 64
    while t % bt != 0:
        bt //= 2
    return max(bt, 1)


def vmem_bytes(t: int, d_model: int, d_ff: int) -> int:
    """Estimated VMEM footprint of one grid step (see module docstring)."""
    bt = pick_block_t(t)
    weights = 3 * d_model * d_ff * 4
    acts = bt * (2 * d_ff + 2 * d_model) * 4
    return weights + acts


@functools.partial(jax.jit, static_argnames=())
def swiglu_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Fused expert FFN. x: [T, d_model] -> [T, d_model]. Matches
    `ref.swiglu_ffn` to ~1e-5 (fp32 accumulation in both)."""
    t, d_model = x.shape
    d_ff = w1.shape[1]
    bt = pick_block_t(t)
    grid = (t // bt,)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_model), lambda i: (i, 0)),       # x: tile tokens
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),     # w1: resident
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),     # w3: resident
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),     # w2: resident
        ],
        out_specs=pl.BlockSpec((bt, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_model), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2)
