"""Fused RMSNorm + router-logits Pallas kernel.

The Mixtral router is `softmax(top_k((rmsnorm(x) * g) @ w_gate))`. The
norm and the gating matmul are fused so the normalized activations stay
in VMEM; top-k itself stays in plain XLA (`jax.lax.top_k`) because it is
O(T*E) scalar work with no MXU benefit.

The same kernel also serves the attention-input norm (pass w_gate = I to
get just the normalized activations — model.py instead calls
`rms_norm_matmul` with the QKV weight, fusing norm+projection).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_matmul_kernel(x_ref, g_ref, w_ref, o_ref, *, eps):
    """o = rmsnorm(x; g) @ w, all in one VMEM residency."""
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * g_ref[...][None, :]
    o_ref[...] = jnp.dot(xn, w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("eps",))
def rms_norm_matmul(
    x: jax.Array, g: jax.Array, w: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """Fused `rmsnorm(x; g) @ w`. x: [T, d], g: [d], w: [d, out]."""
    t, d = x.shape
    out = w.shape[1]
    return pl.pallas_call(
        functools.partial(_rms_matmul_kernel, eps=eps),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, out), jnp.float32),
        interpret=True,
    )(x, g, w)


def topk_small(logits: jax.Array, k: int):
    """Top-k by iterated argmax (k is 2 for Mixtral; E is 8).

    Functionally identical to `jax.lax.top_k` (first-occurrence tie-break),
    but lowers to argmax/select ops only: the `topk(..., largest=true)` HLO
    custom-call emitted by recent JAX is rejected by the image's
    xla_extension 0.5.1 text parser (see DESIGN.md §AOT notes).
    Returns (vals [T, k], idx [T, k] i32).
    """
    e = logits.shape[-1]
    masked = logits
    vals, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)  # [T]
        val = jnp.max(masked, axis=-1)
        idxs.append(idx)
        vals.append(val)
        hit = jax.nn.one_hot(idx, e, dtype=bool)
        masked = jnp.where(hit, -jnp.inf, masked)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1).astype(jnp.int32)


def router(x: jax.Array, g: jax.Array, w_gate: jax.Array, k: int, eps: float = 1e-5):
    """Full router: fused norm+logits kernel, then top-k softmax.

    Returns (weights [T, k] f32, indices [T, k] i32, logits [T, E]).
    """
    logits = rms_norm_matmul(x, g, w_gate, eps=eps)
    vals, idx = topk_small(logits, k)
    weights = jax.nn.softmax(vals, axis=-1)
    return weights, idx, logits
