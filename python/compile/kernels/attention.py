"""Single-token GQA decode-attention Pallas kernel.

Decode attention on the main node reads the whole padded KV cache for one
new query token. TPU adaptation: the cache for all KV heads of one layer
(max_seq x n_kv x head_dim, 64 KiB at the default config) is staged into
VMEM in one block; scores/softmax/weighted-sum all happen in-register per
head. Positions >= seq_len are masked (the cache is a fixed-capacity ring
buffer owned by the Rust coordinator).

The valid-length scalar rides in as a [1] i32 array (interpret-mode
friendly stand-in for scalar prefetch).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # [n_heads, head_dim]
    k = k_ref[...].astype(jnp.float32)            # [max_seq, n_kv, head_dim]
    v = v_ref[...].astype(jnp.float32)
    seq_len = len_ref[0]
    n_heads, head_dim = q.shape
    max_seq, n_kv, _ = k.shape
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))
    # GQA via grouped einsum: fold the query-head groups into the einsum
    # instead of materializing a repeated [max_seq, n_heads, head_dim]
    # cache — the cache (the biggest tensor here) is read once, not
    # `group` times (EXPERIMENTS.md §Perf, L1 iteration 2).
    qg = q.reshape(n_kv, group, head_dim)
    scores = jnp.einsum("kgd,skd->kgs", qg, k) * scale   # [n_kv, group, S]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, 1, max_seq), 2) < seq_len
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("kgs,skd->kgd", probs, v)
    o_ref[...] = out.reshape(n_heads, head_dim)


def decode_attention(
    q: jax.Array,        # [n_heads, head_dim]
    k_cache: jax.Array,  # [max_seq, n_kv_heads, head_dim]
    v_cache: jax.Array,
    seq_len: jax.Array,  # [1] i32 — valid length INCLUDING the new token
) -> jax.Array:
    """Matches `ref.gqa_attention_decode`. Returns [n_heads, head_dim]."""
    n_heads, head_dim = q.shape
    max_seq, n_kv, _ = k_cache.shape
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n_heads, head_dim), lambda i: (0, 0)),
            pl.BlockSpec((max_seq, n_kv, head_dim), lambda i: (0, 0, 0)),
            pl.BlockSpec((max_seq, n_kv, head_dim), lambda i: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_heads, head_dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, head_dim), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, seq_len)
