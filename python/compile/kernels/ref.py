"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/` asserts each
Pallas kernel (run in interpret mode) matches its oracle to tight
tolerances across randomized shapes and dtypes (hypothesis sweeps).
Nothing in here is performance-tuned — clarity over speed.
"""

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis. x: [..., d], weight: [d]."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x.astype(jnp.float32) * inv * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Mixtral expert FFN: w2 @ (silu(w1 x) * (w3 x)).

    x: [T, d_model]; w1, w3: [d_model, d_ff]; w2: [d_ff, d_model].
    """
    gate = jax.nn.silu(x @ w1)
    up = x @ w3
    return (gate * up) @ w2


def router_logits(x: jax.Array, w_gate: jax.Array) -> jax.Array:
    """Router logits. x: [T, d_model], w_gate: [d_model, n_experts]."""
    return x @ w_gate


def router_topk(logits: jax.Array, k: int):
    """Top-k softmax routing as in Mixtral: softmax over the selected
    logits only. Returns (weights [T, k], indices [T, k] int32)."""
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    return w, idx.astype(jnp.int32)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [T, n_heads, head_dim], positions: [T] int32."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def gqa_attention_decode(
    q: jax.Array,        # [n_heads, head_dim] — single new token
    k_cache: jax.Array,  # [max_seq, n_kv_heads, head_dim]
    v_cache: jax.Array,  # [max_seq, n_kv_heads, head_dim]
    seq_len: jax.Array,  # scalar int32: valid cache length INCLUDING new token
) -> jax.Array:
    """Single-token GQA decode attention against a padded KV cache.

    Entries at positions >= seq_len are masked out. Returns
    [n_heads, head_dim].
    """
    n_heads, head_dim = q.shape
    max_seq, n_kv, _ = k_cache.shape
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))
    # Expand kv heads to query heads.
    k = jnp.repeat(k_cache, group, axis=1)  # [max_seq, n_heads, head_dim]
    v = jnp.repeat(v_cache, group, axis=1)
    scores = jnp.einsum("hd,shd->hs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.arange(max_seq) < seq_len
    scores = jnp.where(mask[None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hs,shd->hd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gqa_attention_prefill(
    q: jax.Array,  # [T, n_heads, head_dim]
    k: jax.Array,  # [T, n_kv_heads, head_dim]
    v: jax.Array,  # [T, n_kv_heads, head_dim]
) -> jax.Array:
    """Causal GQA attention over a full prompt. Returns [T, n_heads, head_dim]."""
    T, n_heads, head_dim = q.shape
    group = n_heads // k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("thd,shd->hts", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,shd->thd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Quantization oracles (mirror rust/src/quant/).
# ---------------------------------------------------------------------------

# The 16 NF4 levels (QLoRA, Dettmers et al. 2023): quantiles of N(0,1)
# normalized to [-1, 1]. Index 7 is exactly 0.
NF4_LEVELS = jnp.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.7229568362236023, 1.0,
    ],
    dtype=jnp.float32,
)


def quantize_int8(w: jax.Array):
    """Per-row absmax symmetric INT8. w: [rows, cols] -> (q int8, scale [rows])."""
    absmax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[:, None]


def quantize_nf4(w: jax.Array, block: int = 64):
    """Blockwise NF4: flatten, split into blocks, absmax-scale, nearest
    NF4 level. Returns (codes uint8 [n_blocks, block], scales [n_blocks])."""
    flat = w.reshape(-1)
    assert flat.shape[0] % block == 0, "weight size must be divisible by block"
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    normed = blocks / scale
    # Nearest level (ties resolved toward the lower index, matching rust).
    dist = jnp.abs(normed[..., None] - NF4_LEVELS[None, None, :])
    codes = jnp.argmin(dist, axis=-1).astype(jnp.uint8)
    return codes, scale[:, 0]


def dequantize_nf4(codes: jax.Array, scales: jax.Array, shape) -> jax.Array:
    vals = NF4_LEVELS[codes.astype(jnp.int32)] * scales[:, None]
    return vals.reshape(shape)


def fake_quant(w: jax.Array, mode: str) -> jax.Array:
    """Quantize-dequantize round trip ("fake quant") used to build shadow
    weights. mode in {fp32, fp16, int8, nf4}."""
    if mode == "fp32":
        return w
    if mode == "fp16":
        return w.astype(jnp.float16).astype(jnp.float32)
    if w.ndim == 1:
        # Norm gains / biases: quantize as a single row.
        return fake_quant(w.reshape(1, -1), mode).reshape(w.shape)
    if mode == "int8":
        q, s = quantize_int8(w)
        return dequantize_int8(q, s)
    if mode == "nf4":
        c, s = quantize_nf4(w)
        return dequantize_nf4(c, s, w.shape)
    raise ValueError(f"unknown quant mode {mode!r}")
