"""Dequantize-into-matmul Pallas kernels for the shadow (SEP) path.

The paper's shadow model is an INT8/NF4-quantized Mixtral. The bandwidth
win that quantization buys on PCIe translates on TPU to streaming the
compressed weights HBM->VMEM and dequantizing *inside* the kernel, fused
with the matmul, so full-precision weights never exist in HBM.

Two kernels:
  * `int8_matmul`   — x @ (q * row_scale), q: int8 per-row absmax.
  * `nf4_matmul`    — x @ dequant_nf4(codes, block_scales), codebook
                      lookup fused via a VMEM-resident 16-entry table.

Both are validated against `ref.dequantize_* + matmul` oracles in
python/tests/test_quant_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _int8_matmul_kernel(x_ref, q_ref, s_ref, o_ref):
    # Dequantize in VMEM: int8 codes * per-row scale, then straight to MXU.
    w = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@jax.jit
def int8_matmul(x: jax.Array, q: jax.Array, scale: jax.Array) -> jax.Array:
    """x: [T, d] f32, q: [d, out] int8, scale: [d] f32 -> [T, out] f32."""
    t, d = x.shape
    out = q.shape[1]
    return pl.pallas_call(
        _int8_matmul_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((d, out), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, out), jnp.float32),
        interpret=True,
    )(x, q, scale)


def _nf4_matmul_kernel(x_ref, codes_ref, scales_ref, table_ref, o_ref, *, d, out, block):
    # Codebook lookup: 16-entry NF4 table resident in VMEM.
    codes = codes_ref[...]                      # [n_blocks, block] uint8
    table = table_ref[...]                      # [16]
    vals = table[codes.astype(jnp.int32)]       # [n_blocks, block]
    w = (vals * scales_ref[...][:, None]).reshape(d, out)
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("d", "out", "block"))
def nf4_matmul(
    x: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    d: int,
    out: int,
    block: int = 64,
) -> jax.Array:
    """x: [T, d] f32; codes: [n_blocks, block] uint8 (row-major flattening
    of the [d, out] weight); scales: [n_blocks] f32 -> [T, out] f32."""
    t = x.shape[0]
    n_blocks = codes.shape[0]
    return pl.pallas_call(
        functools.partial(_nf4_matmul_kernel, d=d, out=out, block=block),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((n_blocks, block), lambda i: (0, 0)),
            pl.BlockSpec((n_blocks,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t, out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, out), jnp.float32),
        interpret=True,
    )(x, codes, scales, ref.NF4_LEVELS)


def int8_swiglu_ffn(x, q1, s1, q3, s3, q2, s2):
    """Quantized expert FFN for the shadow model: all three projections
    run through the fused int8 dequant-matmul kernel."""
    gate = int8_matmul(x, q1, s1)
    up = int8_matmul(x, q3, s3)
    act = gate * jax.lax.logistic(gate) * up
    return int8_matmul(act, q2, s2)
