//! Property and equivalence tests for multi-session batched decode
//! (DESIGN.md §7).
//!
//! The pure route-merge invariants run anywhere; the engine equivalence
//! tests execute real numerics and need the AOT artifacts (same
//! convention as `engine_integration.rs`: they panic with a pointer to
//! `make artifacts` when the artifacts are absent).

use odmoe::cache::{CacheConfig, TierPolicy};
use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::batch::merge_distinct;
use odmoe::coordinator::baselines::FullyCachedEngine;
use odmoe::coordinator::{
    BatchEngine, Engine, FailureSpec, OdMoeConfig, OdMoeEngine, PredictorMode,
};
use odmoe::metrics::memory as memaudit;
use odmoe::model::rng::Rng;
use odmoe::model::WeightStore;
use odmoe::util::prop::check;
use odmoe::Runtime;

// ---------------------------------------------------------------------
// Pure merge invariants (no runtime needed).
// ---------------------------------------------------------------------

#[test]
fn prop_distinct_loads_bounded_by_per_session_sum() {
    check("distinct <= sum of per-session loads", 64, 201, |rng| {
        let b = 1 + rng.below(8);
        let top_k = 1 + rng.below(3);
        let n_experts = top_k + 1 + rng.below(8);
        let sessions: Vec<Vec<usize>> = (0..b)
            .map(|_| {
                let mut route = Vec::new();
                while route.len() < top_k {
                    let e = rng.below(n_experts);
                    if !route.contains(&e) {
                        route.push(e);
                    }
                }
                route
            })
            .collect();
        let merged = merge_distinct(sessions.iter().map(|s| s.as_slice()));
        let total = b * top_k;
        if merged.len() > total {
            return Err(format!("{} distinct loads for {total} selections", merged.len()));
        }
        let conserved: usize = merged.iter().map(|&(_, n)| n).sum();
        if conserved != total {
            return Err(format!("counts sum to {conserved}, expected {total}"));
        }
        // Every expert appears at most once (truly distinct).
        for (i, &(e, _)) in merged.iter().enumerate() {
            if merged[i + 1..].iter().any(|&(x, _)| x == e) {
                return Err(format!("expert {e} merged twice"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merge_of_single_session_is_identity() {
    check("batch of one merges to its own route", 32, 202, |rng| {
        let top_k = 1 + rng.below(4);
        let mut route = Vec::new();
        while route.len() < top_k {
            let e = rng.below(8);
            if !route.contains(&e) {
                route.push(e);
            }
        }
        let merged = merge_distinct([route.as_slice()]);
        let back: Vec<usize> = merged.iter().map(|&(e, _)| e).collect();
        if back != route || merged.iter().any(|&(_, n)| n != 1) {
            return Err(format!("{merged:?} is not the identity of {route:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine equivalence (real numerics; needs `make artifacts`).
// ---------------------------------------------------------------------

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn prompt(seed: u64, len: usize, vocab: u32) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as usize) as u32).collect()
}

/// `run_batch` over one session must reproduce `run_prompt` exactly:
/// tokens, TTFT, decode time, stalls, and per-layer prediction recall.
#[test]
fn batch_of_one_matches_sequential_odmoe() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    for predictor in [PredictorMode::Sep, PredictorMode::None] {
        let cfg = OdMoeConfig { predictor, ..OdMoeConfig::default() };
        let mut engine = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();

        engine.reset().unwrap();
        let solo = engine.run_prompt(&p, 8, false).unwrap();
        engine.reset().unwrap();
        let batched = engine.run_batch(&[(p.as_slice(), 8)]).unwrap();
        let b = &batched.sessions[0];

        assert_eq!(solo.tokens, b.tokens, "{predictor:?}: token stream must match");
        assert_eq!(solo.ttft_ms, b.ttft_ms, "{predictor:?}: ttft must match exactly");
        assert_eq!(solo.decode_ms, b.decode_ms, "{predictor:?}: decode time must match exactly");
        assert_eq!(solo.stall_ms, b.stall_ms, "{predictor:?}: stalls must match exactly");
        assert_eq!(
            solo.correct_per_token, b.correct_per_token,
            "{predictor:?}: per-layer recall must match"
        );
        assert_eq!(batched.decode_tokens, 7);
    }
}

#[test]
fn batch_of_one_matches_sequential_fully_cached() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(9, 16, rt.cfg.vocab_size as u32);
    let mut engine = FullyCachedEngine::new(&rt, ws).unwrap();

    engine.reset().unwrap();
    let solo = engine.run_prompt(&p, 6, false).unwrap();
    engine.reset().unwrap();
    let batched = engine.run_batch(&[(p.as_slice(), 6)]).unwrap();
    let b = &batched.sessions[0];

    assert_eq!(solo.tokens, b.tokens);
    assert_eq!(solo.ttft_ms, b.ttft_ms);
    assert_eq!(solo.decode_ms, b.decode_ms);
    assert_eq!(batched.expert_loads, 0, "fully cached never loads");
}

/// Numerics stay per-session exact inside a mixed batch: every member's
/// token stream equals its own sequential decode.
#[test]
fn batched_token_streams_are_per_session_exact() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let pa = prompt(1, 16, vocab);
    let pb = prompt(2, 16, vocab);
    let mut engine =
        OdMoeEngine::new(&rt, ws, OdMoeConfig { predictor: PredictorMode::None, ..OdMoeConfig::default() })
            .unwrap();

    engine.reset().unwrap();
    let solo_a = engine.run_prompt(&pa, 6, false).unwrap();
    engine.reset().unwrap();
    let solo_b = engine.run_prompt(&pb, 9, false).unwrap();

    engine.reset().unwrap();
    let batched = engine.run_batch(&[(pa.as_slice(), 6), (pb.as_slice(), 9)]).unwrap();
    assert_eq!(batched.sessions[0].tokens, solo_a.tokens);
    assert_eq!(batched.sessions[1].tokens, solo_b.tokens);
    // The batch shrinks at a token boundary when the short session ends.
    assert_eq!(batched.decode_tokens, 5 + 8);
    assert_eq!(batched.decode_iterations, 8, "long session decodes alone after the short one");
}

/// Fault tolerance must not break the batch-of-one contract: with the
/// same failure plan injected, `run_batch` over one session reproduces
/// sequential decode bookings exactly — both paths share the failover
/// helpers (DESIGN.md §8), and this pins that they stay in lockstep.
#[test]
fn batch_of_one_matches_sequential_under_failures() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    let healthy = {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        e.run_prompt(&p, 8, false).unwrap()
    };
    let mid = healthy.ttft_ms + healthy.decode_ms / 2.0;

    let plans: Vec<Vec<FailureSpec>> = vec![
        vec![FailureSpec::Worker { worker: 2, at_ms: 0.0 }],
        vec![FailureSpec::Worker { worker: 5, at_ms: mid }],
        vec![FailureSpec::Shadow { at_ms: mid }],
        vec![
            FailureSpec::Worker { worker: 0, at_ms: mid },
            FailureSpec::Shadow { at_ms: 0.0 },
        ],
    ];
    for plan in &plans {
        let mut engine = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        for &f in plan {
            engine.inject_failure(f);
        }
        engine.reset().unwrap();
        let solo = engine.run_prompt(&p, 8, false).unwrap();
        engine.reset().unwrap();
        let batched = engine.run_batch(&[(p.as_slice(), 8)]).unwrap();
        let b = &batched.sessions[0];

        assert_eq!(solo.tokens, b.tokens, "{plan:?}: token stream must match");
        assert_eq!(solo.tokens, healthy.tokens, "{plan:?}: failures never change the stream");
        assert_eq!(solo.ttft_ms, b.ttft_ms, "{plan:?}: ttft must match exactly");
        assert_eq!(solo.decode_ms, b.decode_ms, "{plan:?}: decode time must match exactly");
        assert_eq!(solo.stall_ms, b.stall_ms, "{plan:?}: stalls must match exactly");
        assert!(b.decode_ms.is_finite() && b.decode_ms >= healthy.decode_ms - 1e-6);
    }
}

/// Chunked streaming must not break the batch-of-one contract: with
/// expert transfers split into chunks and speculative staging enabled,
/// `run_batch` over one session still reproduces sequential decode
/// bookings exactly (both paths share the chunk-aware failover helpers,
/// DESIGN.md §9).
#[test]
fn batch_of_one_matches_sequential_under_chunking() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    for (chunks, depth) in [(4usize, 0usize), (4, 1), (8, 2)] {
        let cfg = OdMoeConfig { chunks, prefetch_depth: depth, ..OdMoeConfig::default() };
        let mut engine = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();

        engine.reset().unwrap();
        let solo = engine.run_prompt(&p, 8, false).unwrap();
        engine.reset().unwrap();
        let batched = engine.run_batch(&[(p.as_slice(), 8)]).unwrap();
        let b = &batched.sessions[0];

        assert_eq!(solo.tokens, b.tokens, "chunks {chunks}/depth {depth}: tokens must match");
        assert_eq!(solo.ttft_ms, b.ttft_ms, "chunks {chunks}/depth {depth}: ttft");
        assert_eq!(solo.decode_ms, b.decode_ms, "chunks {chunks}/depth {depth}: decode time");
        assert_eq!(solo.stall_ms, b.stall_ms, "chunks {chunks}/depth {depth}: stalls");
    }
}

/// Chunk count 1 at depth 0 is the seed engine, bit-identically: tokens
/// AND timings equal an engine built with the default (monolithic)
/// config — the contract `BENCH_overlap.json`'s baseline row rests on.
#[test]
fn chunk_count_one_reproduces_monolithic_engine_exactly() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    let mut mono = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let m = mono.run_prompt(&p, 8, false).unwrap();
    let cfg = OdMoeConfig { chunks: 1, prefetch_depth: 0, ..OdMoeConfig::default() };
    let mut one = OdMoeEngine::new(&rt, ws, cfg).unwrap();
    let o = one.run_prompt(&p, 8, false).unwrap();
    assert_eq!(m.tokens, o.tokens);
    assert_eq!(m.ttft_ms, o.ttft_ms);
    assert_eq!(m.decode_ms, o.decode_ms, "chunk count 1 must book identically");
    assert_eq!(m.stall_ms, o.stall_ms);
    assert_eq!(m.correct_per_token, o.correct_per_token);
}

/// Chunking with overlap strictly improves decode on the default
/// profile (the BENCH_overlap acceptance bar): more chunks hide more of
/// each stalled load behind compute, and the token stream never moves.
#[test]
fn chunked_decode_strictly_improves_over_monolithic() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(5, 16, rt.cfg.vocab_size as u32);
    let mut tokens_ref: Option<Vec<u32>> = None;
    let mut last = f64::INFINITY;
    for chunks in [1usize, 2, 4, 8] {
        let cfg = OdMoeConfig { chunks, ..OdMoeConfig::default() };
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
        let r = e.run_prompt(&p, 12, false).unwrap();
        assert!(
            r.decode_ms < last,
            "chunks {chunks}: decode {} must beat {last}",
            r.decode_ms
        );
        last = r.decode_ms;
        match &tokens_ref {
            None => tokens_ref = Some(r.tokens),
            Some(t) => assert_eq!(t, &r.tokens, "chunks {chunks}: stream must never change"),
        }
    }
}

/// The memory audit vs the engine's byte ledger: sequential decode keeps
/// strict single-expert residency per worker (the `metrics::memory::odmoe`
/// row), while batched decode transiently holds every expert a worker
/// loads for a layer — bounded by `metrics::memory::odmoe_batched`'s
/// honest `ceil(distinct / group_size)` worst case, NOT the old "two
/// experts" folklore.
#[test]
fn ledger_peaks_reconcile_with_memory_audit() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let hp = HardwareProfile::rtx3090();
    let act = hp.activation_bytes as u64;
    let expert = hp.expert_bytes as u64;

    // Sequential: every worker's peak is exactly one expert + workspace.
    let mut engine = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    engine.run_prompt(&prompt(3, 16, vocab), 6, false).unwrap();
    let audit = memaudit::odmoe(&hp, 8);
    for (i, w) in engine.cluster.workers.iter().enumerate() {
        assert_eq!(
            w.gpu_bytes_peak,
            act + expert,
            "worker {i}: sequential peak must match the audit row"
        );
        let (_, audited) = &audit.per_node[2 + i];
        assert_eq!(w.gpu_bytes_peak, *audited as u64);
    }

    // Batched (4 distinct sessions): the peak may exceed one expert but
    // never the batched audit's bound.
    let prompts: Vec<Vec<u32>> = (1..=4).map(|s| prompt(s, 16, vocab)).collect();
    let sessions: Vec<(&[u32], usize)> = prompts.iter().map(|p| (p.as_slice(), 6)).collect();
    engine.reset().unwrap();
    engine.run_batch(&sessions).unwrap();
    let batched = memaudit::odmoe_batched(&hp, 8, 2, 4);
    for (i, w) in engine.cluster.workers.iter().enumerate() {
        let (_, bound) = &batched.per_node[2 + i];
        assert!(
            w.gpu_bytes_peak <= *bound as u64,
            "worker {i}: batched peak {} exceeds the audited bound {bound}",
            w.gpu_bytes_peak
        );
        assert!(w.gpu_bytes_peak >= act + expert, "worker {i} never loaded?");
    }
}

/// The §7 amortization, end to end on the engine: identical sessions
/// route identically, so expert loads per decode token fall strictly as
/// the batch grows, while decode throughput rises.
#[test]
fn shared_routing_amortizes_loads_and_raises_throughput() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(5, 16, rt.cfg.vocab_size as u32);
    let mut engine = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();

    let mut prev_lpt = f64::INFINITY;
    let mut prev_tps = 0.0;
    for b in [1usize, 2, 4] {
        let sessions: Vec<(&[u32], usize)> = vec![(p.as_slice(), 8); b];
        engine.reset().unwrap();
        let res = engine.run_batch(&sessions).unwrap();
        let lpt = res.loads_per_token();
        let tps = res.decode_tokens as f64 / (res.decode_span_ms / 1000.0);
        assert!(
            lpt < prev_lpt,
            "batch {b}: loads/token {lpt} must fall below {prev_lpt}"
        );
        assert!(
            tps > prev_tps,
            "batch {b}: decode throughput {tps} must rise above {prev_tps}"
        );
        // All members decode the same stream.
        for s in &res.sessions[1..] {
            assert_eq!(s.tokens, res.sessions[0].tokens);
        }
        prev_lpt = lpt;
        prev_tps = tps;
    }
}

// ---------------------------------------------------------------------
// Tiered expert cache (DESIGN.md §12): budget-0 pins, warm-tier timing
// neutrality, eviction-storm ledger reconciliation, and convergence
// toward the fully-cached ceiling.
// ---------------------------------------------------------------------

/// Budget 0 is the seed engine, bit-for-bit: an explicit all-zero
/// [`CacheConfig`] (under every eviction policy — the policy must be
/// inert when no tier has capacity) reproduces the default engine's
/// tokens AND timings on the sequential, batched, chunked, and
/// failure-injection paths.
#[test]
fn budget_zero_cache_is_bit_identical_across_all_paths() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    let zero = |policy| CacheConfig { hot: 0, warm: 0, cold: 0, policy };

    let variants: Vec<(&str, OdMoeConfig)> = vec![
        ("sequential/sep", OdMoeConfig::default()),
        (
            "sequential/no-prefetch",
            OdMoeConfig { predictor: PredictorMode::None, ..OdMoeConfig::default() },
        ),
        (
            "chunked+staged",
            OdMoeConfig { chunks: 4, prefetch_depth: 1, ..OdMoeConfig::default() },
        ),
    ];
    for policy in [TierPolicy::Lru, TierPolicy::Sieve, TierPolicy::ReuseDistance] {
        for (what, cfg) in &variants {
            let mut base = OdMoeEngine::new(&rt, ws.clone(), cfg.clone()).unwrap();
            let zeroed = OdMoeConfig { cache: zero(policy), ..cfg.clone() };
            let mut z = OdMoeEngine::new(&rt, ws.clone(), zeroed).unwrap();
            let a = base.run_prompt(&p, 8, false).unwrap();
            let b = z.run_prompt(&p, 8, false).unwrap();
            assert_eq!(a.tokens, b.tokens, "{what}/{policy:?}: tokens");
            assert_eq!(a.ttft_ms, b.ttft_ms, "{what}/{policy:?}: ttft");
            assert_eq!(a.decode_ms, b.decode_ms, "{what}/{policy:?}: decode time");
            assert_eq!(a.stall_ms, b.stall_ms, "{what}/{policy:?}: stalls");
            assert_eq!(a.correct_per_token, b.correct_per_token, "{what}/{policy:?}: recall");
            let (h, w, c, m) = z.cache_stats();
            assert_eq!((h, w, c, m), (0, 0, 0, 0), "{what}/{policy:?}: cache never consulted");
        }
    }

    // Batched + failure injection, load tallies included.
    let pa = prompt(1, 16, rt.cfg.vocab_size as u32);
    let pb = prompt(2, 16, rt.cfg.vocab_size as u32);
    let sessions: Vec<(&[u32], usize)> = vec![(pa.as_slice(), 6), (pb.as_slice(), 9)];
    let zeroed = OdMoeConfig { cache: zero(TierPolicy::Lru), ..OdMoeConfig::default() };
    let mut base = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let mut z = OdMoeEngine::new(&rt, ws.clone(), zeroed.clone()).unwrap();
    let x = base.run_batch(&sessions).unwrap();
    let y = z.run_batch(&sessions).unwrap();
    assert_eq!(x.expert_loads, y.expert_loads, "batched: load tallies");
    assert_eq!(x.aborted_loads, y.aborted_loads, "batched: abort tallies");
    assert_eq!(x.decode_span_ms, y.decode_span_ms, "batched: span");
    for (s, t) in x.sessions.iter().zip(&y.sessions) {
        assert_eq!(s.tokens, t.tokens, "batched: tokens");
        assert_eq!(s.decode_ms, t.decode_ms, "batched: decode time");
    }
    let mid = x.sessions[1].ttft_ms + x.sessions[1].decode_ms / 2.0;
    let mut base = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    base.inject_failure(FailureSpec::Worker { worker: 2, at_ms: mid });
    let mut z = OdMoeEngine::new(&rt, ws.clone(), zeroed).unwrap();
    z.inject_failure(FailureSpec::Worker { worker: 2, at_ms: mid });
    let a = base.run_prompt(&pb, 9, false).unwrap();
    let b = z.run_prompt(&pb, 9, false).unwrap();
    assert_eq!(a.tokens, b.tokens, "failure: tokens");
    assert_eq!(a.decode_ms, b.decode_ms, "failure: decode time");
    assert_eq!(a.stall_ms, b.stall_ms, "failure: stalls");
    assert_eq!(base.failovers(), z.failovers(), "failure: failover counts");
}

/// A CPU-warm hit re-streams the standard PCIe chunk train (DESIGN.md
/// §12), so a warm-only cache changes NOTHING observable in virtual
/// time: tokens, timings, and load tallies all equal the cacheless
/// engine — only the hit counters move.
#[test]
fn warm_only_cache_is_timing_neutral_by_construction() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(7, 16, rt.cfg.vocab_size as u32);
    let sessions: Vec<(&[u32], usize)> = vec![(p.as_slice(), 8)];

    let mut base = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let u = base.run_batch(&sessions).unwrap();

    let warm_cfg = OdMoeConfig {
        cache: CacheConfig { hot: 0, warm: 8, cold: 0, policy: TierPolicy::Lru },
        ..OdMoeConfig::default()
    };
    let mut warm = OdMoeEngine::new(&rt, ws.clone(), warm_cfg).unwrap();
    let w = warm.run_batch(&sessions).unwrap();

    assert_eq!(u.sessions[0].tokens, w.sessions[0].tokens);
    assert_eq!(u.sessions[0].ttft_ms, w.sessions[0].ttft_ms, "warm hits book the miss train");
    assert_eq!(u.sessions[0].decode_ms, w.sessions[0].decode_ms);
    assert_eq!(u.sessions[0].stall_ms, w.sessions[0].stall_ms);
    assert_eq!(u.expert_loads, w.expert_loads, "warm hits still count as loads");
    assert_eq!(u.decode_span_ms, w.decode_span_ms);
    let (hot, warm_hits, _cold, misses) = warm.cache_stats();
    assert_eq!(hot, 0, "no hot tier to hit");
    assert!(warm_hits + misses > 0, "the cache was consulted");
}

/// Eviction storm under a one-slot hot tier: the byte ledger reconciles
/// exactly after every install displaces the previous resident —
/// steady-state usage ends at workspace + residents, and peaks stay
/// within the batched audit bound plus the hot budget's payloads.
#[test]
fn ledger_reconciles_through_eviction_storms() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let hp = HardwareProfile::rtx3090();
    let act = hp.activation_bytes as u64;
    let expert = hp.expert_bytes as u64;

    let mut base = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let prompts: Vec<Vec<u32>> = (1..=4).map(|s| prompt(s, 16, vocab)).collect();
    let sessions: Vec<(&[u32], usize)> = prompts.iter().map(|p| (p.as_slice(), 6)).collect();
    let u = base.run_batch(&sessions).unwrap();

    for policy in [TierPolicy::Lru, TierPolicy::Sieve, TierPolicy::ReuseDistance] {
        let cfg = OdMoeConfig {
            cache: CacheConfig { hot: 1, warm: 2, cold: 2, policy },
            ..OdMoeConfig::default()
        };
        let mut engine = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
        let c = engine.run_batch(&sessions).unwrap();
        for (s, t) in u.sessions.iter().zip(&c.sessions) {
            assert_eq!(s.tokens, t.tokens, "{policy:?}: cache state never moves tokens");
        }
        let audit = memaudit::odmoe_batched(&hp, 8, 2, 4);
        for (i, w) in engine.cluster.workers.iter().enumerate() {
            let resident = engine.cache_hot_resident(i) as u64;
            assert!(resident <= 1, "{policy:?}: worker {i} exceeded its one-slot budget");
            assert_eq!(
                w.gpu_bytes_used,
                act + resident * expert,
                "{policy:?}: worker {i} ledger must settle at workspace + residents"
            );
            let (_, bound) = &audit.per_node[2 + i];
            assert!(
                w.gpu_bytes_peak <= *bound as u64 + expert,
                "{policy:?}: worker {i} peak {} exceeds audited bound + hot budget",
                w.gpu_bytes_peak
            );
        }
    }
}

/// Convergence bracket: a saturating hot budget can never beat the
/// fully-cached ceiling nor lose to the cacheless floor — its decode
/// time lands between them, with the same token stream as both.
#[test]
fn saturating_budget_lands_between_cacheless_and_fully_cached() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(5, 16, rt.cfg.vocab_size as u32);
    let sessions: Vec<(&[u32], usize)> = vec![(p.as_slice(), 12)];

    let mut cacheless = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let u = cacheless.run_batch(&sessions).unwrap();

    let cfg = OdMoeConfig {
        cache: CacheConfig {
            hot: rt.cfg.n_layers * rt.cfg.n_experts,
            warm: 0,
            cold: 0,
            policy: TierPolicy::Lru,
        },
        ..OdMoeConfig::default()
    };
    let mut cached = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
    let c = cached.run_batch(&sessions).unwrap();

    let mut full = FullyCachedEngine::new(&rt, ws).unwrap();
    let f = full.run_batch(&sessions).unwrap();

    assert_eq!(u.sessions[0].tokens, c.sessions[0].tokens);
    assert_eq!(u.sessions[0].tokens, f.sessions[0].tokens, "baselines share numerics");
    assert!(
        c.decode_span_ms <= u.decode_span_ms + 1e-6,
        "saturating cache cannot lose to cacheless: {} vs {}",
        c.decode_span_ms,
        u.decode_span_ms
    );
    assert!(
        f.decode_span_ms <= c.decode_span_ms + 1e-6,
        "nothing beats the fully-cached ceiling: {} vs {}",
        f.decode_span_ms,
        c.decode_span_ms
    );
    assert!(c.expert_loads < u.expert_loads, "repeats must be served hot");
    assert_eq!(f.expert_loads, 0, "fully cached never loads");
}
