//! Property tests for critical-path attribution (DESIGN.md §11): the two
//! conservation invariants — phase decompositions partition every token
//! window (A) and the critical path partitions the makespan (B) — plus
//! chunks=1 equivalence with the monolithic load path, over randomized
//! decode-shaped booking pipelines on uniform and mixed fleets and under
//! injected fail-stop worker failures. Runtime-free: everything runs at
//! the [`Cluster`] level.

use odmoe::cluster::{Cluster, HardwareProfile, NodeClass};
use odmoe::model::rng::Rng;
use odmoe::telemetry::{attribute, critical_path, decompose, Phase};
use odmoe::trace::EventKind;
use odmoe::util::prop::check;

const CASES: usize = 64;
const EMBED_BYTES: f64 = 16.0 * 1024.0;

fn random_class(rng: &mut Rng) -> NodeClass {
    if rng.uniform() < 0.5 {
        NodeClass::rtx3090()
    } else {
        NodeClass::jetson()
    }
}

/// A uniform RTX-3090 fleet or a mixed 3090 + Jetson fleet, trace on.
fn random_fleet(rng: &mut Rng) -> Cluster {
    let mut c = if rng.uniform() < 0.5 {
        Cluster::new(HardwareProfile::rtx3090(), 2 + rng.below(3))
    } else {
        let mut classes = vec![NodeClass::rtx3090(), NodeClass::jetson()];
        for _ in 0..rng.below(3) {
            classes.push(random_class(rng));
        }
        Cluster::with_classes(HardwareProfile::rtx3090(), classes)
    };
    c.trace.enabled = true;
    c
}

/// First alive worker at or after `pref` (wrapping).
fn alive_worker(c: &Cluster, pref: usize) -> usize {
    let n = c.workers.len();
    for i in 0..n {
        let w = (pref + i) % n;
        if c.workers[w].is_alive() {
            return w;
        }
    }
    panic!("no alive worker");
}

/// Book a decode-shaped pipeline: per token, a few expert layers (embed
/// broadcast -> expert stream -> FFN -> embed-back) plus main/shadow
/// work and engine-style stall markers. With `inject_failure`, one
/// worker fail-stops at a random token boundary and later layers route
/// around it. Returns the recorded per-token spans.
fn book_decode(c: &mut Cluster, rng: &mut Rng, inject_failure: bool) -> Vec<(f64, f64)> {
    let n = c.workers.len();
    let tokens = 2 + rng.below(3);
    let layers = 2 + rng.below(3);
    let mut fail_after_token = None;
    if inject_failure && n > 1 {
        fail_after_token = Some(rng.below(tokens));
    }
    let mut spans = Vec::with_capacity(tokens);
    let mut t = 0.0_f64;
    for tok in 0..tokens {
        let t0 = t;
        if fail_after_token == Some(tok) && c.alive_workers() > 1 {
            c.fail_worker(rng.below(n), t);
        }
        if rng.uniform() < 0.7 {
            let dur = 0.05 + rng.uniform() * 0.5;
            c.trace.push(EventKind::ShadowCompute, c.shadow.id, t, t + dur, "sep");
        }
        for _ in 0..layers {
            let w = alive_worker(c, rng.below(n));
            let arrival = c.lan_send(t, EMBED_BYTES, "embed");
            let bytes = c.profile.expert_bytes * (0.3 + rng.uniform());
            let done = if rng.uniform() < 0.5 {
                let chunks = 1 + rng.below(4);
                c.expert_load_chunked(w, arrival, bytes, chunks, EventKind::ExpertLoad).done()
            } else {
                c.expert_load(w, arrival, bytes).1
            };
            if done > arrival {
                c.trace.push(EventKind::Stall, c.workers[w].id, arrival, done, "stall");
            }
            let (_, fin) = c.expert_compute(w, done, 0.3 + rng.uniform() * 1.5);
            t = c.lan_send(fin, EMBED_BYTES, "embed-back");
        }
        let head = 0.05 + rng.uniform() * 0.3;
        c.trace.push(EventKind::MainCompute, c.main.id, t, t + head, "lm-head");
        t += head;
        spans.push((t0, t));
    }
    spans
}

/// Invariant A: per-token phase buckets are non-negative and sum to the
/// measured iteration latency, for every token and every layer slice, on
/// uniform and mixed fleets with random failure injection.
#[test]
fn prop_token_decomposition_sums_to_latency() {
    check("phase buckets partition each token", CASES, 601, |rng| {
        let mut c = random_fleet(rng);
        let inject = rng.uniform() < 0.4;
        let spans = book_decode(&mut c, rng, inject);
        let a = attribute(&c.trace, &spans);
        for tok in &a.tokens {
            if tok.phase_ms.iter().any(|&ms| ms < 0.0) {
                return Err(format!("negative bucket in token {}: {:?}", tok.index, tok.phase_ms));
            }
            let (sum, lat) = (tok.phases_total(), tok.latency());
            if (sum - lat).abs() > 1e-9 {
                return Err(format!("token {}: phases {sum} != latency {lat}", tok.index));
            }
            for l in &tok.layers {
                let lsum: f64 = l.phase_ms.iter().sum();
                if (lsum - (l.end - l.start)).abs() > 1e-9 {
                    return Err(format!("layer slice {:?}: {lsum} != span", l.layer));
                }
            }
        }
        // The totals row of the rendered table obeys the same invariant.
        let grand: f64 = a.phase_totals().iter().sum();
        if (grand - a.total_ms()).abs() > 1e-9 {
            return Err(format!("phase totals {grand} != total {}", a.total_ms()));
        }
        Ok(())
    });
}

/// Invariant B: the critical path is a contiguous partition of
/// `[t0, t1]` — segments abut exactly, the first starts at the window
/// start, the last ends at the makespan instant, and the lengths sum to
/// the makespan. Failure markers never appear on the chain.
#[test]
fn prop_critical_path_partitions_the_makespan() {
    check("critical path == makespan", CASES, 602, |rng| {
        let mut c = random_fleet(rng);
        let inject = rng.uniform() < 0.4;
        let spans = book_decode(&mut c, rng, inject);
        let t0 = spans.first().expect("tokens").0;
        let t1 = spans.last().expect("tokens").1;
        let cp = critical_path(&c.trace, t0, t1);
        if cp.is_empty() {
            return Err("empty critical path over a non-empty decode".into());
        }
        for w in cp.windows(2) {
            if w[0].end != w[1].start {
                return Err(format!("gap in chain: {} -> {}", w[0].end, w[1].start));
            }
        }
        let first = cp.first().expect("non-empty");
        let last = cp.last().expect("non-empty");
        if first.start != t0 || last.end != t1 {
            return Err(format!("chain [{}, {}] != window [{t0}, {t1}]", first.start, last.end));
        }
        let total: f64 = cp.iter().map(|s| s.dur()).sum();
        if (total - (t1 - t0)).abs() > 1e-9 {
            return Err(format!("critical total {total} != makespan {}", t1 - t0));
        }
        if cp.iter().any(|s| s.label == "fail") {
            return Err("zero-width failure marker on the critical path".into());
        }
        // Idle gaps carry no node; booked segments always do.
        for s in &cp {
            if (s.phase == Phase::Idle) != s.node.is_none() {
                return Err(format!("node/phase mismatch: {:?} on {:?}", s.node, s.phase));
            }
        }
        Ok(())
    });
}

/// One random per-layer booking op, shared by both sides of the chunks=1
/// equivalence: (worker, dispatch gap, bytes, FFN ms).
type LayerOp = (usize, f64, f64, f64);

fn random_plan(rng: &mut Rng, n_workers: usize) -> Vec<Vec<LayerOp>> {
    let mut plan = Vec::new();
    for _ in 0..2 + rng.below(2) {
        let mut ops = Vec::new();
        for _ in 0..2 + rng.below(3) {
            let w = rng.below(n_workers);
            let gap = rng.uniform() * 2.0;
            let bytes = 1e6 + rng.uniform() * 1e8;
            let base = 0.3 + rng.uniform() * 1.5;
            ops.push((w, gap, bytes, base));
        }
        plan.push(ops);
    }
    plan
}

fn apply_plan(c: &mut Cluster, plan: &[Vec<LayerOp>], chunked: bool) -> Vec<(f64, f64)> {
    let mut spans = Vec::with_capacity(plan.len());
    let mut t = 0.0_f64;
    for tok in plan {
        let t0 = t;
        for &(w, gap, bytes, base) in tok {
            let arrival = c.lan_send(t + gap, EMBED_BYTES, "embed");
            let done = if chunked {
                c.expert_load_chunked(w, arrival, bytes, 1, EventKind::ExpertLoad).done()
            } else {
                c.expert_load(w, arrival, bytes).1
            };
            let (_, fin) = c.expert_compute(w, done, base);
            t = c.lan_send(fin, EMBED_BYTES, "embed-back");
        }
        spans.push((t0, t));
    }
    spans
}

/// Chunk count 1 must attribute bit-identically to the monolithic load
/// path: same token spans, same phase buckets, same critical path — on
/// uniform and mixed fleets, with random stragglers.
#[test]
fn prop_chunks_one_attribution_matches_monolithic() {
    check("chunks=1 attribution == monolithic", CASES, 603, |rng| {
        let mut classes = vec![random_class(rng), random_class(rng)];
        if rng.uniform() < 0.5 {
            classes.push(random_class(rng));
        }
        let mut a = Cluster::with_classes(HardwareProfile::rtx3090(), classes.clone());
        let mut b = Cluster::with_classes(HardwareProfile::rtx3090(), classes);
        a.trace.enabled = true;
        b.trace.enabled = true;
        if rng.uniform() < 0.5 {
            let w = rng.below(a.workers.len());
            let slow = 1.0 + rng.uniform() * 4.0;
            a.inject_straggler(w, slow);
            b.inject_straggler(w, slow);
        }
        let plan = random_plan(rng, a.workers.len());
        let sa = apply_plan(&mut a, &plan, false);
        let sb = apply_plan(&mut b, &plan, true);
        if sa != sb {
            return Err(format!("token spans diverge: {sa:?} vs {sb:?}"));
        }
        let t0 = sa.first().expect("tokens").0;
        let t1 = sa.last().expect("tokens").1;
        let (da, db) = (decompose(&a.trace, t0, t1), decompose(&b.trace, t0, t1));
        if da != db {
            return Err(format!("phase buckets diverge: {da:?} vs {db:?}"));
        }
        let (aa, ab) = (attribute(&a.trace, &sa), attribute(&b.trace, &sb));
        for (ta, tb) in aa.tokens.iter().zip(&ab.tokens) {
            if ta.phase_ms != tb.phase_ms {
                return Err(format!("token {} buckets diverge", ta.index));
            }
        }
        if aa.critical.len() != ab.critical.len() {
            let (la, lb) = (aa.critical.len(), ab.critical.len());
            return Err(format!("chain lengths diverge: {la} vs {lb}"));
        }
        for (x, y) in aa.critical.iter().zip(ab.critical.iter()) {
            if x.phase != y.phase || x.start != y.start || x.end != y.end {
                return Err(format!("chain segment diverges: {x:?} vs {y:?}"));
            }
        }
        Ok(())
    });
}

/// Both conservation invariants survive a mid-decode fail-stop with
/// rerouting: a dead worker's frozen bookings still decompose exactly,
/// and the makespan stays fully attributed.
#[test]
fn prop_conservation_survives_fail_stop() {
    check("conservation under fail-stop", CASES, 604, |rng| {
        let mut c = random_fleet(rng);
        let spans = book_decode(&mut c, rng, true);
        let a = attribute(&c.trace, &spans);
        for tok in &a.tokens {
            if (tok.phases_total() - tok.latency()).abs() > 1e-9 {
                return Err(format!("token {} leaks time after fail-stop", tok.index));
            }
        }
        let makespan = a.t1 - a.t0;
        if (a.critical_total() - makespan).abs() > 1e-9 {
            return Err(format!("critical {} != makespan {makespan}", a.critical_total()));
        }
        let by_phase: f64 = a.critical_by_phase().iter().sum();
        if (by_phase - makespan).abs() > 1e-9 {
            return Err(format!("per-phase chain split {by_phase} != makespan {makespan}"));
        }
        Ok(())
    });
}
