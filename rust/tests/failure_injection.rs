//! Failure/straggler injection over the OD-MoE pipeline: degraded links,
//! slow workers and fail-stopped nodes must degrade *throughput only* —
//! numerics (the served token stream) must be bit-identical, because the
//! scheduler's fallback paths (reactive loads, slot rerouting) preserve
//! correctness by construction (DESIGN.md §8).

use odmoe::cache::{CacheConfig, TierPolicy};
use odmoe::cluster::{Cluster, HardwareProfile};
use odmoe::coordinator::{
    Engine, FailureSpec, OdMoeConfig, OdMoeEngine, PredictorMode, Request, Server,
};
use odmoe::model::WeightStore;
use odmoe::workload::Corpus;
use odmoe::Runtime;

/// Every resource in the cluster must carry finite, non-negative time
/// accounting — the invariant the old "infinite slowdown ~ dead link"
/// hack violated.
fn assert_virtual_time_sane(c: &Cluster) {
    let nodes = c.workers.iter().chain([&c.main, &c.shadow]);
    for n in nodes {
        for r in [&n.gpu, &n.pcie] {
            assert!(r.free_at().is_finite(), "node {}: free_at diverged", n.id);
            assert!(
                r.busy_total().is_finite() && r.busy_total() >= 0.0,
                "node {}: busy_total corrupted: {}",
                n.id,
                r.busy_total()
            );
        }
    }
    assert!(c.lan.busy_total().is_finite() && c.lan.busy_total() >= 0.0);
}

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn prompt() -> Vec<u32> {
    Corpus::generate(31, 1, 16, 256).prompts.pop().unwrap()
}

#[test]
fn straggler_slows_but_never_corrupts() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 10;

    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = healthy.run_prompt(&p, out, false).unwrap();

    let mut degraded = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    degraded.cluster.inject_straggler(3, 4.0); // one worker 4x slower
    let d = degraded.run_prompt(&p, out, false).unwrap();

    assert_eq!(h.tokens, d.tokens, "straggler must not change the stream");
    assert!(
        d.decode_ms > h.decode_ms,
        "a 4x straggler must cost time: {} vs {}",
        d.decode_ms,
        h.decode_ms
    );
    assert!(d.stall_ms > h.stall_ms);
}

#[test]
fn degradation_is_monotone_in_straggler_severity() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let mut last = 0.0f64;
    for factor in [1.0, 2.0, 4.0, 8.0] {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        e.cluster.inject_straggler(0, factor);
        let r = e.run_prompt(&p, 8, false).unwrap();
        assert!(
            r.decode_ms >= last - 1e-6,
            "decode time must grow with severity: {} after {last} (factor {factor})",
            r.decode_ms
        );
        last = r.decode_ms;
    }
}

#[test]
fn straggler_on_idle_worker_count_is_cheaper_than_on_hot_path() {
    // With 8 workers / 4 groups, every group is on the hot path, but a
    // straggler hurts only the layers its group owns — the other three
    // groups' slack absorbs part of it. Slowing TWO workers in different
    // groups must cost at least as much as one.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let run = |stragglers: &[usize]| {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        for &w in stragglers {
            e.cluster.inject_straggler(w, 4.0);
        }
        e.run_prompt(&p, 8, false).unwrap().decode_ms
    };
    let one = run(&[0]);
    let two = run(&[0, 2]);
    assert!(two >= one - 1e-6, "two stragglers {two} vs one {one}");
}

#[test]
fn killing_any_single_worker_mid_decode_reroutes_without_corruption() {
    // The acceptance bar for the failure model: a dead worker yields a
    // finite decode time, finite non-negative per-resource accounting,
    // and a token stream bit-identical to the healthy run — for EVERY
    // choice of victim.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 10;
    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = healthy.run_prompt(&p, out, false).unwrap();
    let mid = h.ttft_ms + h.decode_ms / 2.0;

    for victim in 0..8 {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        e.inject_failure(FailureSpec::Worker { worker: victim, at_ms: mid });
        let d = e.run_prompt(&p, out, false).unwrap();
        assert_eq!(h.tokens, d.tokens, "worker {victim} death must not change the stream");
        assert!(
            d.decode_ms.is_finite() && d.decode_ms > 0.0,
            "worker {victim}: decode_ms = {}",
            d.decode_ms
        );
        assert!(
            d.decode_ms >= h.decode_ms - 1e-6,
            "worker {victim}: rerouting cannot beat the healthy run ({} vs {})",
            d.decode_ms,
            h.decode_ms
        );
        assert_virtual_time_sane(&e.cluster);
        assert_eq!(e.cluster.alive_workers(), 7, "worker {victim} must be dead");
        assert!(!e.slots.is_alive(victim));
        // Every slot routes to a survivor.
        for g in 0..e.slots.n_groups() {
            for w in e.slots.workers_of(g) {
                assert!(e.slots.is_alive(w), "group {g} routed to dead worker {w}");
            }
        }
    }
}

#[test]
fn decode_slowdown_is_monotone_in_failed_worker_count() {
    // The acceptance criterion behind `--failover-sweep`: killing workers
    // 0..k (from the first decode iteration) yields a decode time that
    // never decreases as k grows — each extra death only concentrates
    // load on the survivors — while the stream stays bit-identical.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let mut tokens_ref: Option<Vec<u32>> = None;
    let mut last = 0.0f64;
    for k in 0..=3 {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        for w in 0..k {
            e.inject_failure(FailureSpec::Worker { worker: w, at_ms: 0.0 });
        }
        let r = e.run_prompt(&p, 8, false).unwrap();
        assert!(r.decode_ms.is_finite(), "k={k}: decode_ms = {}", r.decode_ms);
        assert!(
            r.decode_ms >= last - 1e-6,
            "slowdown must be monotone: k={k} took {} after {last}",
            r.decode_ms
        );
        last = r.decode_ms;
        match &tokens_ref {
            None => tokens_ref = Some(r.tokens),
            Some(t) => assert_eq!(t, &r.tokens, "k={k}: stream must never change"),
        }
        assert_virtual_time_sane(&e.cluster);
    }
}

#[test]
fn dead_from_start_worker_concentrates_load_but_stays_exact() {
    // at_ms = 0: the worker is gone from the first decode iteration; its
    // slots live on a survivor for the whole run.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = healthy.run_prompt(&p, 8, false).unwrap();
    let mut e = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    e.inject_failure(FailureSpec::Worker { worker: 3, at_ms: 0.0 });
    let d = e.run_prompt(&p, 8, false).unwrap();
    assert_eq!(h.tokens, d.tokens);
    assert!(d.decode_ms.is_finite() && d.decode_ms >= h.decode_ms - 1e-6);
    assert_virtual_time_sane(&e.cluster);
}

#[test]
fn shadow_death_falls_back_to_no_prefetch_timing_with_identical_tokens() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 8;

    let mut sep = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = sep.run_prompt(&p, out, false).unwrap();

    let mut none = OdMoeEngine::new(
        &rt,
        ws.clone(),
        OdMoeConfig { predictor: PredictorMode::None, ..OdMoeConfig::default() },
    )
    .unwrap();
    let n = none.run_prompt(&p, out, false).unwrap();

    // Shadow dead before decode starts: every iteration must book the
    // exact no-prefetch timing path.
    let mut dead = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    dead.inject_failure(FailureSpec::Shadow { at_ms: 0.0 });
    let d = dead.run_prompt(&p, out, false).unwrap();
    assert_eq!(d.tokens, h.tokens, "shadow death must not change the stream");
    assert_eq!(d.ttft_ms, n.ttft_ms, "prefill is predictor-independent");
    assert_eq!(d.decode_ms, n.decode_ms, "dead shadow == no-prefetch timing");
    assert!(d.decode_ms >= h.decode_ms - 1e-6, "losing prediction cannot speed decode");
    assert!(!dead.cluster.shadow.is_alive());

    // Shadow dying mid-decode: prefix predicted, suffix reactive.
    let mut mid = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    mid.inject_failure(FailureSpec::Shadow { at_ms: h.ttft_ms + h.decode_ms / 2.0 });
    let m = mid.run_prompt(&p, out, false).unwrap();
    assert_eq!(m.tokens, h.tokens);
    assert!(m.decode_ms.is_finite());
    assert!(m.decode_ms >= h.decode_ms - 1e-6);
    assert!(m.decode_ms <= n.decode_ms + 1e-6, "partial prediction beats none");
    assert_virtual_time_sane(&mid.cluster);
}

#[test]
fn killing_workers_under_chunked_streaming_stays_exact() {
    // The §9 x §8 interaction: with chunked transfers and speculative
    // staging, a worker death mid-decode re-books only the undelivered
    // chunks on the replacement — the stream stays bit-identical, the
    // accounting finite, and rerouting never beats the healthy run.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 10;
    let cfg = OdMoeConfig { chunks: 4, prefetch_depth: 1, ..OdMoeConfig::default() };
    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), cfg.clone()).unwrap();
    let h = healthy.run_prompt(&p, out, false).unwrap();
    let mid = h.ttft_ms + h.decode_ms / 2.0;

    for victim in [0usize, 3, 7] {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg.clone()).unwrap();
        e.inject_failure(FailureSpec::Worker { worker: victim, at_ms: mid });
        let d = e.run_prompt(&p, out, false).unwrap();
        assert_eq!(h.tokens, d.tokens, "worker {victim}: chunked stream must not change");
        assert!(d.decode_ms.is_finite() && d.decode_ms >= h.decode_ms - 1e-6);
        assert_virtual_time_sane(&e.cluster);
        assert_eq!(e.cluster.alive_workers(), 7);
    }

    // Shadow death under chunking: degrades to the reactive path with
    // identical tokens, like the monolithic engine.
    let mut dead = OdMoeEngine::new(&rt, ws, cfg).unwrap();
    dead.inject_failure(FailureSpec::Shadow { at_ms: mid });
    let d = dead.run_prompt(&p, out, false).unwrap();
    assert_eq!(d.tokens, h.tokens);
    assert!(d.decode_ms.is_finite() && d.decode_ms >= h.decode_ms - 1e-6);
    assert_virtual_time_sane(&dead.cluster);
}

#[test]
fn worker_and_shadow_failures_compose() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = healthy.run_prompt(&p, 8, false).unwrap();
    let mid = h.ttft_ms + h.decode_ms / 3.0;

    let mut e = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    e.inject_failure(FailureSpec::Worker { worker: 0, at_ms: mid });
    e.inject_failure(FailureSpec::Worker { worker: 5, at_ms: mid * 1.2 });
    e.inject_failure(FailureSpec::Shadow { at_ms: mid });
    let d = e.run_prompt(&p, 8, false).unwrap();
    assert_eq!(h.tokens, d.tokens, "composed failures must not change the stream");
    assert!(d.decode_ms.is_finite() && d.decode_ms >= h.decode_ms - 1e-6);
    assert_eq!(e.cluster.alive_workers(), 6);
    assert_virtual_time_sane(&e.cluster);
    // reset resurrects the cluster and re-arms the same plan: the replay
    // is deterministic (what the serve layer's memoization relies on).
    e.reset().unwrap();
    let d2 = e.run_prompt(&p, 8, false).unwrap();
    assert_eq!(d.tokens, d2.tokens);
    assert_eq!(d.decode_ms, d2.decode_ms, "failure replay must be deterministic");
    assert_eq!(d.stall_ms, d2.stall_ms);
}

#[test]
fn worker_death_drops_its_hot_tier_and_ledger_reconciles() {
    // Tiered cache x fail-stop (DESIGN.md §12 x §8): a dead worker's
    // GPU-hot tier dies with the node — its ledger zeroes, the reroute
    // serves the same stream as the cacheless cold-start, and every
    // survivor's ledger settles at workspace + its hot residents.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 10;
    let hp = HardwareProfile::rtx3090();
    let act = hp.activation_bytes as u64;
    let expert = hp.expert_bytes as u64;

    let mut cacheless = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = cacheless.run_prompt(&p, out, false).unwrap();
    let mid = h.ttft_ms + h.decode_ms / 2.0;

    for victim in [0usize, 3, 7] {
        let cfg = OdMoeConfig {
            cache: CacheConfig { hot: 4, warm: 4, cold: 4, policy: TierPolicy::Lru },
            ..OdMoeConfig::default()
        };
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
        e.inject_failure(FailureSpec::Worker { worker: victim, at_ms: mid });
        let d = e.run_prompt(&p, out, false).unwrap();
        assert_eq!(
            h.tokens, d.tokens,
            "worker {victim}: cache + failure must never change the stream"
        );
        assert!(d.decode_ms.is_finite() && d.decode_ms > 0.0);
        // The hot tier died with the node: no residents, no bytes.
        assert_eq!(e.cache_hot_resident(victim), 0, "worker {victim}: hot tier must drop");
        assert_eq!(
            e.cluster.workers[victim].gpu_bytes_used, 0,
            "worker {victim}: dead ledger must zero"
        );
        // Survivors reconcile exactly after the eviction churn the
        // rerouted load concentration causes.
        for (i, w) in e.cluster.workers.iter().enumerate() {
            if i == victim {
                continue;
            }
            assert_eq!(
                w.gpu_bytes_used,
                act + e.cache_hot_resident(i) as u64 * expert,
                "worker {i}: ledger must settle at workspace + residents"
            );
        }
        assert_virtual_time_sane(&e.cluster);

        // Failure replay with cache state is deterministic: reset clears
        // the tiers and re-arms the plan, reproducing the run exactly.
        e.reset().unwrap();
        let d2 = e.run_prompt(&p, out, false).unwrap();
        assert_eq!(d.tokens, d2.tokens, "worker {victim}: replay tokens");
        assert_eq!(d.decode_ms, d2.decode_ms, "worker {victim}: replay must be deterministic");
        assert_eq!(d.stall_ms, d2.stall_ms);
    }
}

#[test]
fn server_drains_queue_over_degraded_cluster() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let mut engine = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    engine.cluster.inject_straggler(1, 3.0);
    let corpus = Corpus::generate(33, 3, 16, 256);
    let mut server = Server::new(&mut engine);
    for (i, prompt) in corpus.prompts.iter().enumerate() {
        server.submit(Request {
            id: i as u64,
            prompt: prompt.clone(),
            out_tokens: 6,
            arrival_ms: i as f64 * 50.0,
        });
    }
    let (done, stats) = server.run().unwrap();
    assert_eq!(done.len(), 3);
    assert_eq!(stats.served, 3);
    assert_eq!(stats.total_tokens, 18);
    assert!(stats.tokens_per_s() > 0.0);
    // FCFS: later arrivals queue behind the degraded engine.
    assert!(done[1].queued_ms > 0.0 || done[2].queued_ms > 0.0);
}
