//! Failure/straggler injection over the OD-MoE pipeline: degraded links
//! and slow workers must degrade *throughput only* — numerics (the served
//! token stream) must be bit-identical, because the scheduler's fallback
//! path (reactive loads) preserves correctness by construction.

use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine, Request, Server};
use odmoe::model::WeightStore;
use odmoe::workload::Corpus;
use odmoe::Runtime;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn prompt() -> Vec<u32> {
    Corpus::generate(31, 1, 16, 256).prompts.pop().unwrap()
}

#[test]
fn straggler_slows_but_never_corrupts() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let out = 10;

    let mut healthy = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let h = healthy.run_prompt(&p, out, false).unwrap();

    let mut degraded = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    degraded.cluster.inject_straggler(3, 4.0); // one worker 4x slower
    let d = degraded.run_prompt(&p, out, false).unwrap();

    assert_eq!(h.tokens, d.tokens, "straggler must not change the stream");
    assert!(
        d.decode_ms > h.decode_ms,
        "a 4x straggler must cost time: {} vs {}",
        d.decode_ms,
        h.decode_ms
    );
    assert!(d.stall_ms > h.stall_ms);
}

#[test]
fn degradation_is_monotone_in_straggler_severity() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let mut last = 0.0f64;
    for factor in [1.0, 2.0, 4.0, 8.0] {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        e.cluster.inject_straggler(0, factor);
        let r = e.run_prompt(&p, 8, false).unwrap();
        assert!(
            r.decode_ms >= last - 1e-6,
            "decode time must grow with severity: {} after {last} (factor {factor})",
            r.decode_ms
        );
        last = r.decode_ms;
    }
}

#[test]
fn straggler_on_idle_worker_count_is_cheaper_than_on_hot_path() {
    // With 8 workers / 4 groups, every group is on the hot path, but a
    // straggler hurts only the layers its group owns — the other three
    // groups' slack absorbs part of it. Slowing TWO workers in different
    // groups must cost at least as much as one.
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt();
    let run = |stragglers: &[usize]| {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        for &w in stragglers {
            e.cluster.inject_straggler(w, 4.0);
        }
        e.run_prompt(&p, 8, false).unwrap().decode_ms
    };
    let one = run(&[0]);
    let two = run(&[0, 2]);
    assert!(two >= one - 1e-6, "two stragglers {two} vs one {one}");
}

#[test]
fn server_drains_queue_over_degraded_cluster() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let mut engine = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    engine.cluster.inject_straggler(1, 3.0);
    let corpus = Corpus::generate(33, 3, 16, 256);
    let mut server = Server::new(&mut engine);
    for (i, prompt) in corpus.prompts.iter().enumerate() {
        server.submit(Request {
            id: i as u64,
            prompt: prompt.clone(),
            out_tokens: 6,
            arrival_ms: i as f64 * 50.0,
        });
    }
    let (done, stats) = server.run().unwrap();
    assert_eq!(done.len(), 3);
    assert_eq!(stats.served, 3);
    assert_eq!(stats.total_tokens, 18);
    assert!(stats.tokens_per_s() > 0.0);
    // FCFS: later arrivals queue behind the degraded engine.
    assert!(done[1].queued_ms > 0.0 || done[2].queued_ms > 0.0);
}
