//! End-to-end integration over the serving engines: OD-MoE and every
//! baseline serve real prompts, produce identical-or-expected token
//! streams, and their virtual-time results have the paper's shape.

use odmoe::coordinator::baselines::{
    CpuEngine, FullyCachedEngine, OffloadConfig, OffloadEngine,
};
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine, PredictorMode};
use odmoe::model::WeightStore;
use odmoe::predictor::AlignmentConfig;
use odmoe::workload::Corpus;
use odmoe::Runtime;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn weights(rt: &Runtime) -> WeightStore {
    WeightStore::generate(&rt.cfg, 42)
}

fn prompt() -> Vec<u32> {
    Corpus::generate(5, 1, 16, 256).prompts.pop().unwrap()
}

#[test]
fn odmoe_serves_and_matches_reference_tokens() {
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();

    let mut reference = FullyCachedEngine::new(&rt, ws.clone()).unwrap();
    let ref_res = reference.run_prompt(&p, 8, false).unwrap();

    let mut od = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    let od_res = od.run_prompt(&p, 8, false).unwrap();

    // OD-MoE serves the full-precision model: token streams are identical.
    assert_eq!(od_res.tokens, ref_res.tokens);
    assert_eq!(od_res.tokens.len(), 8);
    assert!(od_res.ttft_ms > 0.0 && od_res.decode_ms > 0.0);
}

#[test]
fn odmoe_runs_at_large_fraction_of_fully_cached_speed() {
    // Paper headline: ~75% of the fully GPU-cached decoding speed.
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();
    let out = 12;

    let mut full = FullyCachedEngine::new(&rt, ws.clone()).unwrap();
    let f = full.run_prompt(&p, out, false).unwrap();

    let mut od = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    let o = od.run_prompt(&p, out, false).unwrap();

    let ratio = o.decode_tps() / f.decode_tps();
    assert!(
        ratio > 0.5 && ratio < 1.05,
        "OD-MoE/fully-cached decode ratio {ratio:.3} out of plausible band"
    );
}

#[test]
fn ablation_ordering_matches_fig8() {
    // Fig. 8: full alignment >= no alignment >= random prefetch >= none.
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();
    let out = 10;

    let run = |predictor: PredictorMode, align: AlignmentConfig| {
        let cfg = OdMoeConfig { predictor, align, ..OdMoeConfig::default() };
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
        e.run_prompt(&p, out, false).unwrap().decode_tps()
    };

    let case1 = run(PredictorMode::Sep, AlignmentConfig::every_iteration());
    let case4 = run(PredictorMode::Sep, AlignmentConfig::none());
    let case5 = run(PredictorMode::Random, AlignmentConfig::none());
    let case6 = run(PredictorMode::None, AlignmentConfig::none());

    assert!(case1 >= case4 * 0.98, "aligned {case1} vs unaligned {case4}");
    assert!(case4 > case5 * 0.95, "sep-unaligned {case4} vs random {case5}");
    assert!(case5 >= case6 * 0.98, "random {case5} vs none {case6}");
    assert!(case1 > case6 * 1.2, "full system must clearly beat no-prefetch");
}

#[test]
fn offload_engines_produce_tokens_and_hit_rates() {
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();

    for cfg in [
        OffloadConfig::mixtral_offloading(rt.cfg.n_layers),
        OffloadConfig::moe_infinity(rt.cfg.n_layers),
        OffloadConfig::hobbit(rt.cfg.n_layers),
        OffloadConfig::adapmoe(rt.cfg.n_layers),
    ] {
        let name = cfg.system;
        let mut e = OffloadEngine::new(&rt, ws.clone(), cfg).unwrap();
        let r = e.run_prompt(&p, 6, false).unwrap();
        assert_eq!(r.tokens.len(), 6, "{name}");
        assert!(r.ttft_ms > 0.0 && r.decode_ms > 0.0, "{name}");
        let hr = e.hit_rate();
        assert!((0.0..=1.0).contains(&hr), "{name} hit rate {hr}");
        if name == "adapmoe" {
            // Bypass engine must actually skip sometimes on a cold cache.
            assert!(e.skipped_experts > 0, "adapmoe never skipped");
        }
    }
}

#[test]
fn speed_ordering_matches_table2() {
    // Who-wins ordering from Table 2(i):
    //   transformers > od-moe > mixtral-offloading > llama.cpp-ish
    //   > hobbit/moe-infinity.
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();
    let out = 8;

    let tps = |r: &odmoe::coordinator::PromptResult| r.decode_tps();

    let mut full = FullyCachedEngine::new(&rt, ws.clone()).unwrap();
    let t_full = tps(&full.run_prompt(&p, out, false).unwrap());

    let mut od = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let t_od = tps(&od.run_prompt(&p, out, false).unwrap());

    let mut mx =
        OffloadEngine::new(&rt, ws.clone(), OffloadConfig::mixtral_offloading(12)).unwrap();
    let t_mx = tps(&mx.run_prompt(&p, out, false).unwrap());

    let mut inf = OffloadEngine::new(&rt, ws.clone(), OffloadConfig::moe_infinity(12)).unwrap();
    let t_inf = tps(&inf.run_prompt(&p, out, false).unwrap());

    let mut cpu = CpuEngine::new(&rt, ws.clone()).unwrap();
    let t_cpu = tps(&cpu.run_prompt(&p, out, false).unwrap());

    assert!(t_full > t_od, "full {t_full} > od {t_od}");
    assert!(t_od > t_mx, "od {t_od} > mxoff {t_mx}");
    assert!(t_mx > t_cpu, "mxoff {t_mx} > cpu {t_cpu}");
    assert!(t_mx > t_inf, "mxoff {t_mx} > moe-infinity {t_inf}");
}

#[test]
fn adapmoe_degrades_fidelity_odmoe_does_not() {
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();
    let out = 8;

    let mut reference = FullyCachedEngine::new(&rt, ws.clone()).unwrap();
    let ref_res = reference.run_prompt(&p, out, true).unwrap();

    let mut od = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let od_res = od.run_prompt(&p, out, true).unwrap();
    assert_eq!(od_res.tokens, ref_res.tokens, "OD-MoE must be exact");

    let mut ad = OffloadEngine::new(&rt, ws, OffloadConfig::adapmoe(12)).unwrap();
    let ad_res = ad.run_prompt(&p, out, true).unwrap();
    // AdapMoE skips experts -> logits must differ from reference.
    let same = ad_res
        .step_logits
        .iter()
        .zip(&ref_res.step_logits)
        .all(|(a, b)| a == b);
    assert!(!same, "adapmoe with skipping cannot be bit-exact");
}

#[test]
fn memory_ledger_peaks_match_audit() {
    let rt = runtime();
    let ws = weights(&rt);
    let p = prompt();
    let mut od = OdMoeEngine::new(&rt, ws, OdMoeConfig::default()).unwrap();
    let _ = od.run_prompt(&p, 6, false).unwrap();
    // Every worker held at most one expert + workspace at any time.
    let prof = od.cluster.profile.clone();
    for w in &od.cluster.workers {
        assert!(
            (w.gpu_bytes_peak as f64) <= prof.expert_bytes + prof.activation_bytes + 1.0,
            "worker peak {} exceeds cacheless bound",
            w.gpu_bytes_peak
        );
    }
    let total_gb = od.cluster.total_gpu_peak_bytes() as f64 / 1e9;
    assert!(total_gb < 62.0, "total {total_gb} GB exceeds paper budget");
}
