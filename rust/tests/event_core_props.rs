//! Equivalence properties for the event-driven scheduler core
//! (DESIGN.md §13): the retired round loop is the executable spec, and
//! the heap-based event core must reproduce its `ServeOutcome` bit for
//! bit — same records in the same order, same bookings, same queue-depth
//! timeline, same makespan — across every scheduling feature the round
//! loop supports. Comparison is on the full `Debug` rendering, so any
//! new `ServeOutcome` field is automatically under test.
//!
//! Runtime-free: everything here drives the synthetic service model.

use odmoe::model::rng::Rng;
use odmoe::serve::{
    scale_json, scale_sweep, ArrivalModel, CoreKind, MemoryModel, Policy, Request, Scheduler,
    SchedulerConfig, SyntheticService, TenantSpec, WorkloadSpec,
};
use odmoe::util::prop::check;

const CASES: usize = 48;

fn random_policy(rng: &mut Rng) -> Policy {
    [Policy::Fcfs, Policy::Sjf, Policy::Edf][rng.below(3)]
}

fn random_workload(rng: &mut Rng, n: usize) -> Vec<Request> {
    let rate = 0.5 + rng.uniform() * 8.0;
    let mut spec = WorkloadSpec::poisson(rate, n, 256);
    if rng.uniform() < 0.3 {
        spec.tenants = vec![TenantSpec::interactive(), TenantSpec::batch()];
    }
    if rng.uniform() < 0.4 {
        spec.model = ArrivalModel::ClosedLoop {
            clients: 1 + rng.below(4),
            mean_think_ms: 20.0 + rng.uniform() * 300.0,
        };
    }
    spec.generate(rng.next_u64())
}

fn random_service(rng: &mut Rng) -> SyntheticService {
    let base = SyntheticService::new(
        5.0 + rng.uniform() * 50.0,
        rng.uniform() * 2.0,
        5.0 + rng.uniform() * 100.0,
    );
    if rng.uniform() < 0.5 {
        base.with_batch_marginal(0.05 + rng.uniform() * 0.5)
    } else {
        base
    }
}

/// Both cores on identical inputs; service models are deterministic per
/// construction, so each core gets its own clone.
fn both_cores(
    cfg: &SchedulerConfig,
    svc: &SyntheticService,
    reqs: &[Request],
) -> Result<(String, String), String> {
    let event_cfg = SchedulerConfig { core: CoreKind::Event, ..cfg.clone() };
    let mut ev_svc = svc.clone();
    let ev = Scheduler::run(&event_cfg, &mut ev_svc, reqs).map_err(|e| e.to_string())?;
    let mut rl_svc = svc.clone();
    let rl = Scheduler::run_round_loop(cfg, &mut rl_svc, reqs).map_err(|e| e.to_string())?;
    Ok((format!("{ev:?}"), format!("{rl:?}")))
}

#[test]
fn prop_event_core_is_bit_identical_to_round_loop() {
    check("event core == round loop", CASES, 201, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(4),
            max_batch: [1, 2, 4][rng.below(3)],
            preempt_budget_ms: if rng.uniform() < 0.3 {
                Some(30.0 + rng.uniform() * 200.0)
            } else {
                None
            },
            queue_sample_stride: 1 + rng.below(4),
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(28));
        let svc = random_service(rng);
        let (ev, rl) = both_cores(&cfg, &svc, &reqs)?;
        if ev != rl {
            return Err(format!(
                "cores diverge under {:?} x{} batch {}",
                cfg.policy, cfg.n_replicas, cfg.max_batch
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_event_core_matches_round_loop_under_admission_pressure() {
    check("cores agree with a bounded ledger", CASES, 202, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(3),
            max_batch: 1 + rng.below(3),
            memory: MemoryModel {
                budget_bytes: 2_000,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 100,
            },
            ..Default::default()
        };
        // Mixed sizes: some requests exceed the budget outright and are
        // rejected, the rest contend for admission — both paths must
        // agree on who runs where and when.
        let reqs: Vec<Request> = (0..20)
            .map(|i| {
                let prompt_len = if rng.uniform() < 0.25 { 200 } else { 16 };
                Request::open_loop(i, vec![1; prompt_len], 8, i as f64 * 15.0)
            })
            .collect();
        let svc = random_service(rng);
        let (ev, rl) = both_cores(&cfg, &svc, &reqs)?;
        if ev != rl {
            return Err("cores diverge under admission pressure".into());
        }
        Ok(())
    });
}

#[test]
fn prop_event_core_matches_round_loop_under_replica_failure() {
    check("cores agree through fail-stop", CASES, 203, |rng| {
        let n_replicas = 2 + rng.below(3);
        let mut failures = vec![(rng.below(n_replicas - 1), rng.uniform() * 400.0)];
        if rng.uniform() < 0.3 && n_replicas >= 3 {
            // Two distinct casualties; replica n-1 always survives.
            let second = (failures[0].0 + 1) % (n_replicas - 1);
            failures.push((second, rng.uniform() * 400.0));
        }
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas,
            max_batch: 1 + rng.below(3),
            replica_failures: failures,
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(24));
        let svc = random_service(rng);
        let (ev, rl) = both_cores(&cfg, &svc, &reqs)?;
        if ev != rl {
            return Err(format!("cores diverge with failures {:?}", cfg.replica_failures));
        }
        Ok(())
    });
}

#[test]
fn core_selector_picks_the_round_loop() {
    // `--core round-loop` must actually run the old executor: selecting
    // it through `Scheduler::run` gives the same outcome as calling
    // `run_round_loop` directly (and, per the properties above, the same
    // outcome as the event core — this pins the plumbing, not the math).
    let cfg = SchedulerConfig { core: CoreKind::RoundLoop, n_replicas: 2, ..Default::default() };
    let reqs = WorkloadSpec::poisson(4.0, 12, 256).generate(7);
    let mut a = SyntheticService::new(10.0, 0.2, 20.0);
    let mut b = a.clone();
    let via_selector = Scheduler::run(&cfg, &mut a, &reqs).unwrap();
    let direct = Scheduler::run_round_loop(&cfg, &mut b, &reqs).unwrap();
    assert_eq!(format!("{via_selector:?}"), format!("{direct:?}"));
    assert_eq!(CoreKind::parse("round-loop").unwrap(), CoreKind::RoundLoop);
    assert_eq!(CoreKind::parse("round").unwrap(), CoreKind::RoundLoop);
    assert_eq!(CoreKind::parse("event").unwrap(), CoreKind::Event);
    assert!(CoreKind::parse("warp").is_err());
}

#[test]
fn scale_bench_json_is_identical_at_any_thread_count() {
    // The CI scale-smoke contract: BENCH_scale.json without wall-clock
    // keys is byte-identical between --threads 1 and --threads 4.
    let sizes = [160usize, 320];
    let round_cap = 320;
    let render = |threads: usize| {
        let cells = scale_sweep(&sizes, round_cap, threads, 42).unwrap();
        scale_json(&cells, &sizes, round_cap, 42, false).to_string()
    };
    let serial = render(1);
    let threaded = render(4);
    assert_eq!(serial, threaded, "thread count must not leak into the deterministic section");
    assert!(serial.contains("\"schema\":\"odmoe.scale.v1\""));
    assert!(serial.contains("\"core\":\"round-loop\""), "oracle cells present under the cap");
    assert!(!serial.contains("wall_ms"), "include_wall=false must drop wall-clock keys");
}
