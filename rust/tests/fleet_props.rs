//! Fleet heterogeneity properties (DESIGN.md §10).
//!
//! The pure window/slot invariants run anywhere; the engine equivalence
//! suite — the "single-class fleet is bit-identical to the shared
//! profile" pin the whole subsystem rests on — executes real numerics
//! and needs the AOT artifacts (same convention as
//! `engine_integration.rs`: it panics with a pointer to `make artifacts`
//! when they are absent).

use odmoe::cache::{CacheConfig, TierPolicy};
use odmoe::cluster::{Cluster, HardwareProfile, NodeClass};
use odmoe::coordinator::{
    BatchEngine, Engine, FailureSpec, GroupSchedule, OdMoeConfig, OdMoeEngine, PredictorMode,
    SlotMap,
};
use odmoe::fleet::{capability_slots, FleetSpec};
use odmoe::metrics::memory as memaudit;
use odmoe::model::rng::Rng;
use odmoe::model::WeightStore;
use odmoe::util::prop::check;
use odmoe::Runtime;

const CASES: usize = 64;

// ---------------------------------------------------------------------
// Window properties (no runtime needed) — satellite: t_maxload /
// io_bottleneck_free under uneven worker counts and per-class profiles.
// ---------------------------------------------------------------------

/// Random worker-side profile: the base testbed with PCIe bandwidth,
/// expert size and FFN time jittered into plausible edge ranges.
fn random_profile(rng: &mut Rng) -> HardwareProfile {
    HardwareProfile {
        pcie_gbps: 3.0 + rng.uniform() * 37.0,
        pcie_lat_ms: rng.uniform() * 0.8,
        t_expert_gpu_ms: 0.5 + rng.uniform() * 6.0,
        expert_bytes: (0.2 + rng.uniform() * 0.8) * 500e6,
        ..HardwareProfile::rtx3090()
    }
}

#[test]
fn prop_t_maxload_monotone_in_group_count() {
    check("Eq.(1) window grows with stagger groups", CASES, 31, |rng| {
        let group_size = 1 + rng.below(4);
        let t_main = rng.uniform() * 10.0;
        let t_worker = rng.uniform() * 8.0;
        let mut prev = f64::NEG_INFINITY;
        for n_groups in 1..6 {
            let s = GroupSchedule::new(n_groups * group_size, group_size);
            let w = s.t_maxload(t_main, t_worker);
            if w < prev {
                return Err(format!("window shrank at {n_groups} groups: {w} < {prev}"));
            }
            prev = w;
        }
        Ok(())
    });
}

#[test]
fn prop_io_bottleneck_feasibility_monotone_in_pcie_bandwidth() {
    // The satellite invariant: widening a node's PCIe pipe can never
    // flip a feasible schedule infeasible — for the schedule-level
    // predicate AND the per-class reroute predicate at every chunking.
    check("feasibility monotone in pcie_gbps", CASES, 32, |rng| {
        let p = random_profile(rng);
        let group_size = 1 + rng.below(3);
        let n_groups = 1 + rng.below(5);
        let s = GroupSchedule::new(n_groups * group_size, group_size);
        let chunks = 1 + rng.below(8);
        let slots = 1 + rng.below(3);
        let mut prev_sched = false;
        let mut prev_reroute = false;
        for step in 0..6 {
            let wider = HardwareProfile {
                pcie_gbps: p.pcie_gbps * (1.0 + step as f64 * 0.5),
                ..p.clone()
            };
            let now_sched = s.io_bottleneck_free(&wider);
            let now_reroute = wider.reroute_feasible(slots, n_groups, chunks);
            if prev_sched && !now_sched {
                return Err(format!("io_bottleneck_free flipped at step {step}"));
            }
            if prev_reroute && !now_reroute {
                return Err(format!("reroute_feasible flipped at step {step}"));
            }
            prev_sched = now_sched;
            prev_reroute = now_reroute;
        }
        Ok(())
    });
}

#[test]
fn prop_class_presets_feasibility_monotone_in_bandwidth_and_groups() {
    check("preset classes: more bandwidth/groups never hurts", CASES, 33, |rng| {
        let base = random_profile(rng);
        let chunks = 1 + rng.below(8);
        for class in ["rtx3090", "rtx3080", "jetson", "nano"] {
            let c = NodeClass::preset(class).expect("preset");
            let wp = c.worker_profile(&base);
            for n_groups in 1..5 {
                if wp.reroute_feasible(1, n_groups, chunks)
                    && !wp.reroute_feasible(1, n_groups + 1, chunks)
                {
                    return Err(format!("{class}: extra stagger group broke feasibility"));
                }
                let wider = HardwareProfile { pcie_gbps: wp.pcie_gbps * 2.0, ..wp.clone() };
                if wp.reroute_feasible(1, n_groups, chunks)
                    && !wider.reroute_feasible(1, n_groups, chunks)
                {
                    return Err(format!("{class}: doubling bandwidth broke feasibility"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Slot-map properties under uneven fleets.
// ---------------------------------------------------------------------

#[test]
fn prop_first_fit_covers_slots_and_prefers_capable_workers() {
    check("first-fit capability invariants", CASES, 34, |rng| {
        let group_size = 1 + rng.below(3);
        let n_groups = 1 + rng.below(4);
        // Uneven on purpose: up to group_size - 1 leftover workers, plus
        // extra spares beyond the needed slots.
        let n_workers = n_groups * group_size + rng.below(group_size + 3);
        let capable: Vec<bool> = (0..n_workers).map(|_| rng.uniform() < 0.6).collect();
        let m = SlotMap::first_fit(n_workers, group_size, n_groups, |w| capable[w]);
        let n_slots = n_groups * group_size;
        // Coverage: n_slots distinct workers host exactly one slot each.
        let mut hosts: Vec<usize> = (0..n_groups).flat_map(|g| m.workers_of(g)).collect();
        hosts.sort_unstable();
        let mut dedup = hosts.clone();
        dedup.dedup();
        if hosts.len() != n_slots || dedup.len() != n_slots {
            return Err(format!("slots not covered 1:1: {hosts:?}"));
        }
        // Preference: an incapable worker hosts a slot only if every
        // capable worker already hosts one.
        let n_capable = capable.iter().filter(|&&c| c).count();
        let incapable_hosting = hosts.iter().filter(|&&w| !capable[w]).count();
        if n_capable >= n_slots && incapable_hosting > 0 {
            return Err(format!(
                "{incapable_hosting} incapable host(s) despite {n_capable} capable workers"
            ));
        }
        if n_capable < n_slots && incapable_hosting != n_slots - n_capable {
            return Err("shortfall must be exactly the missing capable hosts".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fail_with_keeps_slots_on_live_workers_and_is_deterministic() {
    check("capability-aware failover invariants", CASES, 35, |rng| {
        let group_size = 1 + rng.below(3);
        let n_workers = group_size * (1 + rng.below(4)) + rng.below(group_size);
        let load_ms: Vec<f64> = (0..n_workers).map(|_| 1.0 + rng.uniform() * 60.0).collect();
        let window = rng.uniform() * 120.0;
        let kills: Vec<usize> = {
            let mut ks = Vec::new();
            let mut alive: Vec<usize> = (0..n_workers).collect();
            for _ in 0..rng.below(n_workers) {
                let v = alive.remove(rng.below(alive.len()));
                ks.push(v);
            }
            ks
        };
        let run = || {
            let mut m = SlotMap::new(n_workers, group_size);
            for &v in &kills {
                m.fail_with(
                    v,
                    |c, slots| slots as f64 * load_ms[c] <= window,
                    |c| load_ms[c],
                );
            }
            m
        };
        let m = run();
        if m != run() {
            return Err("identical kill sequences must produce identical maps".into());
        }
        for l in 0..24 {
            for slot in 0..group_size {
                let w = m.worker_for(l, slot);
                if !m.is_alive(w) {
                    return Err(format!("layer {l} slot {slot} routed to dead worker {w}"));
                }
            }
        }
        // Conservation: every original slot still has exactly one host.
        let total: usize = (0..n_workers).map(|w| m.load_of(w)).sum();
        if total != m.n_groups() * group_size {
            return Err(format!("slot count drifted to {total}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine equivalence (real numerics; needs `make artifacts`).
// ---------------------------------------------------------------------

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn prompt(seed: u64, len: usize, vocab: u32) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as usize) as u32).collect()
}

fn uniform_fleet() -> FleetSpec {
    FleetSpec::uniform(NodeClass::rtx3090(), 8).unwrap()
}

fn assert_same(
    a: &odmoe::coordinator::PromptResult,
    b: &odmoe::coordinator::PromptResult,
    what: &str,
) {
    assert_eq!(a.tokens, b.tokens, "{what}: token stream must match");
    assert_eq!(a.ttft_ms, b.ttft_ms, "{what}: ttft must match bit-for-bit");
    assert_eq!(a.decode_ms, b.decode_ms, "{what}: decode time must match bit-for-bit");
    assert_eq!(a.stall_ms, b.stall_ms, "{what}: stalls must match bit-for-bit");
    assert_eq!(a.correct_per_token, b.correct_per_token, "{what}: recall must match");
}

/// The acceptance pin: a single-class fleet of the base profile's class
/// reproduces the shared-profile engine bit-identically — tokens AND
/// timings — on the sequential, chunked, batched, and
/// failure-injection paths.
#[test]
fn single_class_fleet_is_bit_identical_to_shared_profile() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let p = prompt(11, 16, vocab);

    let configs: Vec<(&str, OdMoeConfig)> = vec![
        ("sequential/sep", OdMoeConfig::default()),
        (
            "sequential/no-prefetch",
            OdMoeConfig { predictor: PredictorMode::None, ..OdMoeConfig::default() },
        ),
        (
            "chunked+staged",
            OdMoeConfig { chunks: 4, prefetch_depth: 1, ..OdMoeConfig::default() },
        ),
    ];
    for (what, cfg) in configs {
        let mut shared = OdMoeEngine::new(&rt, ws.clone(), cfg.clone()).unwrap();
        let fleet_cfg = OdMoeConfig { fleet: Some(uniform_fleet()), ..cfg };
        let mut fleet = OdMoeEngine::new(&rt, ws.clone(), fleet_cfg).unwrap();
        let a = shared.run_prompt(&p, 8, false).unwrap();
        let b = fleet.run_prompt(&p, 8, false).unwrap();
        assert_same(&a, &b, what);
    }

    // Batched decode: three mixed sessions, load/abort tallies included.
    let pa = prompt(1, 16, vocab);
    let pb = prompt(2, 16, vocab);
    let pc = prompt(3, 16, vocab);
    let sessions: Vec<(&[u32], usize)> =
        vec![(pa.as_slice(), 6), (pb.as_slice(), 9), (pc.as_slice(), 4)];
    let mut shared = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let fleet_cfg = OdMoeConfig { fleet: Some(uniform_fleet()), ..OdMoeConfig::default() };
    let mut fleet = OdMoeEngine::new(&rt, ws.clone(), fleet_cfg.clone()).unwrap();
    let a = shared.run_batch(&sessions).unwrap();
    let b = fleet.run_batch(&sessions).unwrap();
    for (x, y) in a.sessions.iter().zip(&b.sessions) {
        assert_same(x, y, "batched");
    }
    assert_eq!(a.expert_loads, b.expert_loads);
    assert_eq!(a.aborted_loads, b.aborted_loads);
    assert_eq!(a.failovers, b.failovers);
    assert_eq!(a.decode_span_ms, b.decode_span_ms);

    // Failure injection: worker + shadow deaths mid-decode reroute
    // identically (the capability-aware fail_with must order targets
    // exactly as the shared-profile reroute did).
    let healthy = a.sessions[1].clone();
    let mid = healthy.ttft_ms + healthy.decode_ms / 2.0;
    for spec in [
        FailureSpec::Worker { worker: 2, at_ms: mid },
        FailureSpec::Worker { worker: 0, at_ms: 0.0 },
        FailureSpec::Shadow { at_ms: mid },
    ] {
        let mut shared = OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
        shared.inject_failure(spec);
        let mut fleet = OdMoeEngine::new(&rt, ws.clone(), fleet_cfg.clone()).unwrap();
        fleet.inject_failure(spec);
        let x = shared.run_prompt(&pb, 9, false).unwrap();
        let y = fleet.run_prompt(&pb, 9, false).unwrap();
        assert_same(&x, &y, &format!("failure {spec:?}"));
        assert_eq!(shared.failovers(), fleet.failovers(), "failover counts match");
    }
}

/// A mixed fleet serves the same tokens (numerics never touch virtual
/// time) but books honest per-class durations: decode on
/// rtx3090s + jetsons is no faster than on rtx3090s alone, and the
/// jetson nodes' ledger peaks stay within the fleet memory audit.
#[test]
fn mixed_fleet_decodes_same_tokens_slower_and_within_audit() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let p = prompt(21, 16, vocab);

    let mut uniform =
        OdMoeEngine::new(&rt, ws.clone(), OdMoeConfig::default()).unwrap();
    let u = uniform.run_prompt(&p, 8, false).unwrap();

    let mixed = FleetSpec::parse("rtx3090:4,jetson:4").unwrap();
    let cfg = OdMoeConfig { fleet: Some(mixed.clone()), ..OdMoeConfig::default() };
    let mut engine = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
    let m = engine.run_prompt(&p, 8, false).unwrap();

    assert_eq!(u.tokens, m.tokens, "virtual time never touches numerics");
    assert!(m.decode_ms.is_finite() && m.decode_ms > 0.0);
    assert!(
        m.decode_ms >= u.decode_ms - 1e-6,
        "jetson links cannot make decode faster: {} vs {}",
        m.decode_ms,
        u.decode_ms
    );

    // Ledger peaks within the fleet audit bound, per node.
    let hp = HardwareProfile::rtx3090();
    let audit = memaudit::odmoe_fleet(&hp, &mixed, rt.cfg.top_k, 1, 0, 0);
    for (i, w) in engine.cluster.workers.iter().enumerate() {
        let (label, bound) = &audit.per_node[2 + i];
        assert!(
            w.gpu_bytes_peak as f64 <= *bound,
            "{label}: peak {} exceeds audit bound {bound}",
            w.gpu_bytes_peak
        );
    }
    // Trace rows carry class names on the mixed fleet.
    assert_eq!(engine.cluster.trace.class_of(2), Some("rtx3090"));
    assert_eq!(engine.cluster.trace.class_of(2 + 7), Some("jetson"));
}

/// Capability-aware construction through the engine: with jetsons listed
/// first at full transfer precision, every slot lands on a 3090 and the
/// jetsons start as spares (they miss the Eq. (1) window monolithically).
#[test]
fn engine_slots_prefer_window_capable_classes() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let fleet = FleetSpec::parse("jetson:2,rtx3090:8").unwrap();
    let cfg = OdMoeConfig {
        n_workers: 10,
        fleet: Some(fleet),
        ..OdMoeConfig::default()
    };
    let engine = OdMoeEngine::new(&rt, ws, cfg).unwrap();
    let cluster = Cluster::with_classes(
        HardwareProfile::rtx3090(),
        FleetSpec::parse("jetson:2,rtx3090:8").unwrap().node_classes(),
    );
    assert_eq!(engine.slots, capability_slots(&cluster, rt.cfg.top_k, 1));
    // 10 workers, 5 groups of 2: all ten host, but the capable 3090s
    // take the first slots and the jetsons only the shortfall.
    assert_eq!(engine.slots.workers_of(0), vec![2, 3]);
    assert_eq!(engine.slots.workers_of(4), vec![0, 1]);
}

// ---------------------------------------------------------------------
// Tiered cache on the fleet path (DESIGN.md §12).
// ---------------------------------------------------------------------

/// The headline cache contract on the mixed-fleet path: budget 0 is the
/// cacheless engine, bit-for-bit — an explicit all-zero [`CacheConfig`]
/// changes neither tokens nor any timing on sequential or batched
/// decode, with and without a mid-decode worker failure.
#[test]
fn budget_zero_cache_is_bit_identical_on_mixed_fleet() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let p = prompt(23, 16, vocab);
    let mixed = FleetSpec::parse("rtx3090:4,jetson:4").unwrap();
    let base = OdMoeConfig { fleet: Some(mixed), ..OdMoeConfig::default() };
    let zeroed = OdMoeConfig { cache: CacheConfig::disabled(), ..base.clone() };

    // Sequential.
    let mut plain = OdMoeEngine::new(&rt, ws.clone(), base.clone()).unwrap();
    let mut zero = OdMoeEngine::new(&rt, ws.clone(), zeroed.clone()).unwrap();
    let a = plain.run_prompt(&p, 8, false).unwrap();
    let b = zero.run_prompt(&p, 8, false).unwrap();
    assert_same(&a, &b, "mixed fleet, cache budget 0, sequential");
    let (hot, warm, cold, misses) = zero.cache_stats();
    assert_eq!(
        (hot, warm, cold, misses),
        (0, 0, 0, 0),
        "a disabled cache must never even be consulted"
    );

    // Batched, with load/abort tallies.
    let pa = prompt(5, 16, vocab);
    let pb = prompt(6, 16, vocab);
    let sessions: Vec<(&[u32], usize)> = vec![(pa.as_slice(), 6), (pb.as_slice(), 9)];
    let mut plain = OdMoeEngine::new(&rt, ws.clone(), base.clone()).unwrap();
    let mut zero = OdMoeEngine::new(&rt, ws.clone(), zeroed.clone()).unwrap();
    let x = plain.run_batch(&sessions).unwrap();
    let y = zero.run_batch(&sessions).unwrap();
    for (s, t) in x.sessions.iter().zip(&y.sessions) {
        assert_same(s, t, "mixed fleet, cache budget 0, batched");
    }
    assert_eq!(x.expert_loads, y.expert_loads);
    assert_eq!(x.aborted_loads, y.aborted_loads);
    assert_eq!(x.decode_span_ms, y.decode_span_ms);

    // Mid-decode worker fail-stop reroutes identically.
    let mid = a.ttft_ms + a.decode_ms / 2.0;
    let mut plain = OdMoeEngine::new(&rt, ws.clone(), base).unwrap();
    plain.inject_failure(FailureSpec::Worker { worker: 2, at_ms: mid });
    let mut zero = OdMoeEngine::new(&rt, ws.clone(), zeroed).unwrap();
    zero.inject_failure(FailureSpec::Worker { worker: 2, at_ms: mid });
    let x = plain.run_prompt(&p, 8, false).unwrap();
    let y = zero.run_prompt(&p, 8, false).unwrap();
    assert_same(&x, &y, "mixed fleet, cache budget 0, failure");
    assert_eq!(plain.failovers(), zero.failovers());
}

/// Convergence toward the fully-cached ceiling on a fleet: a GPU-hot
/// budget large enough to hold every expert a worker can ever serve
/// decodes the same tokens with strictly fewer expert loads and no
/// slower than the cacheless engine (the cache only removes transfer
/// work, it never adds any).
#[test]
fn saturating_hot_budget_cuts_loads_without_touching_tokens() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let vocab = rt.cfg.vocab_size as u32;
    let p = prompt(29, 16, vocab);
    let mixed = FleetSpec::parse("rtx3090:4,jetson:4").unwrap();
    let base = OdMoeConfig { fleet: Some(mixed), ..OdMoeConfig::default() };
    let sessions: Vec<(&[u32], usize)> = vec![(p.as_slice(), 8)];

    let mut plain = OdMoeEngine::new(&rt, ws.clone(), base.clone()).unwrap();
    let u = plain.run_batch(&sessions).unwrap();

    // Enough slots for every (layer, expert) pair in the model — nothing
    // is ever evicted, so every repeat is a hot hit.
    let saturating = rt.cfg.n_layers * rt.cfg.n_experts;
    let cached_cfg = OdMoeConfig {
        cache: CacheConfig {
            hot: saturating,
            warm: 0,
            cold: 0,
            policy: TierPolicy::Lru,
        },
        ..base
    };
    let mut cached = OdMoeEngine::new(&rt, ws.clone(), cached_cfg).unwrap();
    let c = cached.run_batch(&sessions).unwrap();

    assert_eq!(
        u.sessions[0].tokens, c.sessions[0].tokens,
        "cache state shifts timings, never tokens"
    );
    assert!(
        c.expert_loads < u.expert_loads,
        "repeated experts must be served from the hot tier: {} vs {}",
        c.expert_loads,
        u.expert_loads
    );
    assert!(
        c.decode_span_ms <= u.decode_span_ms + 1e-6,
        "dropping transfers can only shorten decode: {} vs {}",
        c.decode_span_ms,
        u.decode_span_ms
    );
    let (hot, _warm, _cold, misses) = cached.cache_stats();
    assert!(hot > 0, "saturating budget must produce hot hits");
    assert!(misses > 0, "first touch of each expert is still a miss");
    let resident: usize = (0..8).map(|w| cached.cache_hot_resident(w)).sum();
    assert!(resident > 0, "experts stay resident after the run");
    assert!(resident <= saturating * 8, "per-worker budget bounds residency");
}
