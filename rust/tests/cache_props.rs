//! Property tests for the tiered expert cache's eviction policies
//! (DESIGN.md §12). All of these are pure data-structure properties —
//! no PJRT runtime needed: capacity ceilings, exact hit/miss
//! accounting, LRU eviction order against a reference model, SIEVE
//! hand/second-chance invariants, and the reuse-distance guarantee that
//! SEP-predicted experts are never evicted.

use odmoe::cache::{CacheConfig, ExpertKey, TierLevel, TierPolicy, TieredCache};
use odmoe::util::prop::check;

const CASES: usize = 64;

fn key(layer: usize, expert: usize) -> ExpertKey {
    (layer, expert)
}

/// No tier ever holds more entries than its slot budget, under any
/// interleaving of lookups and installs, for every policy — and the
/// access counters reconcile exactly: every lookup is classified as
/// exactly one of hot/warm/cold hit or miss.
#[test]
fn prop_tier_capacities_and_stats_reconcile() {
    check("tier capacity + accounting", CASES, 401, |rng| {
        let policy = match rng.below(3) {
            0 => TierPolicy::Lru,
            1 => TierPolicy::Sieve,
            _ => TierPolicy::ReuseDistance,
        };
        let cfg = CacheConfig {
            hot: rng.below(4),
            warm: rng.below(4),
            cold: rng.below(4),
            policy,
        };
        let mut t = TieredCache::new(&cfg);
        let mut lookups = 0u64;
        for _ in 0..120 {
            let k = key(rng.below(4), rng.below(6));
            if rng.uniform() < 0.5 {
                t.lookup(k);
                lookups += 1;
            } else {
                let protected: Vec<ExpertKey> =
                    (0..rng.below(3)).map(|_| key(rng.below(4), rng.below(6))).collect();
                let inst = t.install(k, &protected);
                if inst.hot_resident && !t.contains_hot(k) {
                    return Err(format!("{k:?} reported hot-resident but absent"));
                }
                if !inst.hot_resident && t.contains_hot(k) {
                    return Err(format!("{k:?} refused from hot tier but present"));
                }
            }
            if t.hot_len() > cfg.hot {
                return Err(format!("hot tier {} > budget {}", t.hot_len(), cfg.hot));
            }
            if t.warm_len() > cfg.warm {
                return Err(format!("warm tier {} > budget {}", t.warm_len(), cfg.warm));
            }
            if t.cold_len() > cfg.cold {
                return Err(format!("cold tier {} > budget {}", t.cold_len(), cfg.cold));
            }
            let counted = t.hot_hits + t.warm_hits + t.cold_hits + t.misses;
            if counted != lookups || t.touches() != lookups {
                return Err(format!("{counted} classified accesses for {lookups} lookups"));
            }
        }
        Ok(())
    });
}

/// LRU eviction order matches a reference recency list under randomized
/// touch/install sequences on a hot-only tier: the victim is always the
/// entry whose last use (lookup or install) is oldest.
#[test]
fn prop_lru_eviction_order_matches_reference_model() {
    check("LRU vs reference recency list", CASES, 402, |rng| {
        let cap = 1 + rng.below(4);
        let cfg = CacheConfig { hot: cap, warm: 0, cold: 0, policy: TierPolicy::Lru };
        let mut t = TieredCache::new(&cfg);
        // Reference: keys ordered oldest-use first.
        let mut model: Vec<ExpertKey> = Vec::new();
        for _ in 0..150 {
            let k = key(0, rng.below(8));
            if rng.uniform() < 0.4 {
                let hit = t.lookup(k) == Some(TierLevel::GpuHot);
                let modeled = model.contains(&k);
                if hit != modeled {
                    return Err(format!("{k:?}: lookup hit {hit}, model says {modeled}"));
                }
                if hit {
                    model.retain(|&x| x != k);
                    model.push(k);
                }
            } else {
                let inst = t.install(k, &[]);
                if model.contains(&k) {
                    // Re-install refreshes recency, evicts nothing.
                    if !inst.evicted_hot.is_empty() {
                        return Err(format!("{k:?}: re-install evicted {:?}", inst.evicted_hot));
                    }
                    model.retain(|&x| x != k);
                    model.push(k);
                } else {
                    if model.len() == cap {
                        let victim = model.remove(0);
                        if inst.evicted_hot != vec![victim] {
                            return Err(format!(
                                "expected victim {victim:?}, got {:?}",
                                inst.evicted_hot
                            ));
                        }
                    } else if !inst.evicted_hot.is_empty() {
                        return Err(format!("eviction below capacity: {:?}", inst.evicted_hot));
                    }
                    model.push(k);
                }
                if !inst.hot_resident {
                    return Err(format!("{k:?}: LRU must always admit"));
                }
            }
            if t.hot_len() != model.len() {
                return Err(format!("len {} vs model {}", t.hot_len(), model.len()));
            }
            for &k in &model {
                if !t.contains_hot(k) {
                    return Err(format!("model key {k:?} missing from hot tier"));
                }
            }
        }
        Ok(())
    });
}

/// SIEVE invariants on a hot-only tier, against a reference of the
/// documented algorithm: a hand scans insertion order, un-marking and
/// sparing visited entries, evicting the first unvisited one. The
/// observable contract: victims match the reference exactly, so every
/// entry with its visited bit set survives any single eviction.
#[test]
fn prop_sieve_hand_spares_visited_entries() {
    struct Ref {
        entries: Vec<(ExpertKey, bool)>,
        hand: usize,
    }
    impl Ref {
        fn evict(&mut self) -> ExpertKey {
            if self.hand >= self.entries.len() {
                self.hand = 0;
            }
            loop {
                if self.entries[self.hand].1 {
                    self.entries[self.hand].1 = false;
                    self.hand = (self.hand + 1) % self.entries.len();
                } else {
                    let v = self.entries.remove(self.hand).0;
                    // `hand == victim index`: it now points at the next
                    // entry, exactly like the Tier's removal shift.
                    return v;
                }
            }
        }
    }
    check("SIEVE vs reference hand", CASES, 403, |rng| {
        let cap = 2 + rng.below(4);
        let cfg = CacheConfig { hot: cap, warm: 0, cold: 0, policy: TierPolicy::Sieve };
        let mut t = TieredCache::new(&cfg);
        let mut model = Ref { entries: Vec::new(), hand: 0 };
        for _ in 0..150 {
            let k = key(0, rng.below(10));
            if rng.uniform() < 0.45 {
                let hit = t.lookup(k) == Some(TierLevel::GpuHot);
                let e = model.entries.iter_mut().find(|(x, _)| *x == k);
                if hit != e.is_some() {
                    return Err(format!("{k:?}: hit {hit} disagrees with model"));
                }
                if let Some(e) = e {
                    e.1 = true;
                }
            } else if !model.entries.iter().any(|(x, _)| *x == k) {
                let inst = t.install(k, &[]);
                if model.entries.len() == cap {
                    let victim = model.evict();
                    if inst.evicted_hot != vec![victim] {
                        return Err(format!(
                            "expected victim {victim:?}, got {:?}",
                            inst.evicted_hot
                        ));
                    }
                } else if !inst.evicted_hot.is_empty() {
                    return Err(format!("eviction below capacity: {:?}", inst.evicted_hot));
                }
                model.entries.push((k, false));
            } else {
                // Install of a resident key: pure touch, no eviction.
                let inst = t.install(k, &[]);
                if !inst.evicted_hot.is_empty() {
                    return Err("re-install must not evict".into());
                }
                if let Some(e) = model.entries.iter_mut().find(|(x, _)| *x == k) {
                    e.1 = true;
                }
            }
            if t.hot_len() != model.entries.len() {
                return Err(format!("len {} vs model {}", t.hot_len(), model.entries.len()));
            }
            for &(k, _) in &model.entries {
                if !t.contains_hot(k) {
                    return Err(format!("model key {k:?} missing from hot tier"));
                }
            }
        }
        Ok(())
    });
}

/// The SEP-informed policy's headline guarantee: an expert predicted
/// within the lookahead window (the `protected` set) is NEVER evicted
/// from the hot tier — when every resident is protected, the incoming
/// key is refused (and lands warm) instead.
#[test]
fn prop_reuse_distance_never_evicts_protected_experts() {
    check("reuse-distance protection", CASES, 404, |rng| {
        let cap = 1 + rng.below(4);
        let cfg = CacheConfig { hot: cap, warm: 2, cold: 0, policy: TierPolicy::ReuseDistance };
        let mut t = TieredCache::new(&cfg);
        for _ in 0..120 {
            // A fresh lookahead set each step, like rebuild_protected
            // does per layer.
            let protected: Vec<ExpertKey> =
                (0..rng.below(cap + 2)).map(|_| key(rng.below(3), rng.below(6))).collect();
            let k = key(rng.below(3), rng.below(6));
            if rng.uniform() < 0.3 {
                t.lookup(k);
                continue;
            }
            let hot_before = t.hot_len();
            let was_resident = t.contains_hot(k);
            let inst = t.install(k, &protected);
            for v in &inst.evicted_hot {
                if protected.contains(v) {
                    return Err(format!("protected {v:?} evicted for {k:?}"));
                }
            }
            if !inst.hot_resident {
                // Refusal is only legal when the tier is full of
                // protected residents (and the key itself was absent).
                if was_resident {
                    return Err(format!("{k:?} was resident yet refused"));
                }
                if hot_before < cap {
                    return Err(format!("{k:?} refused with free hot slots"));
                }
                if t.lookup(k).is_none() {
                    return Err(format!("refused {k:?} must land in the warm chain"));
                }
            }
        }
        Ok(())
    });
}

/// Demotion-chain conservation: with all three tiers bounded, a key
/// evicted from hot reappears warm, warm victims fall to cold, and a
/// key is never resident in two tiers at once (lookup classifies it
/// uniquely, hottest first).
#[test]
fn prop_demotion_chain_keeps_keys_unique_across_tiers() {
    check("hot -> warm -> cold demotion", CASES, 405, |rng| {
        let cfg = CacheConfig {
            hot: 1 + rng.below(2),
            warm: 1 + rng.below(2),
            cold: 1 + rng.below(2),
            policy: TierPolicy::Lru,
        };
        let mut t = TieredCache::new(&cfg);
        let mut installed: Vec<ExpertKey> = Vec::new();
        for _ in 0..100 {
            let k = key(0, rng.below(7));
            let inst = t.install(k, &[]);
            if !installed.contains(&k) {
                installed.push(k);
            }
            for v in &inst.evicted_hot {
                // A hot victim demotes to warm, displacing downward —
                // it must still be somewhere below the hot tier.
                if t.contains_hot(*v) {
                    return Err(format!("evicted {v:?} still hot"));
                }
                match t.lookup(*v) {
                    Some(TierLevel::CpuWarm) => {}
                    other => return Err(format!("hot victim {v:?} landed at {other:?}")),
                }
            }
            let total = t.hot_len() + t.warm_len() + t.cold_len();
            if total > cfg.hot + cfg.warm + cfg.cold {
                return Err(format!("{total} residents exceed the summed budgets"));
            }
            if total > installed.len() {
                return Err(format!(
                    "{total} residents but only {} distinct keys ever installed",
                    installed.len()
                ));
            }
        }
        Ok(())
    });
}
