//! Property tests over the serving layer's scheduler invariants, using
//! the in-tree prop driver and the runtime-free synthetic service model
//! (no PJRT artifacts required).

use odmoe::model::rng::Rng;
use odmoe::serve::{
    batch_sweep, batch_sweep_json, rate_sweep, sweep_json, ArrivalModel, MemoryModel, Policy,
    Request, Scheduler, SchedulerConfig, ServiceModel, SessionOutcome, Slo, SyntheticService,
    TenantSpec, WorkloadSpec,
};
use odmoe::util::prop::check;

const CASES: usize = 48;

fn random_policy(rng: &mut Rng) -> Policy {
    [Policy::Fcfs, Policy::Sjf, Policy::Edf][rng.below(3)]
}

fn random_workload(rng: &mut Rng, n: usize) -> Vec<Request> {
    let rate = 0.5 + rng.uniform() * 8.0;
    let mut spec = WorkloadSpec::poisson(rate, n, 256);
    if rng.uniform() < 0.3 {
        spec.tenants = vec![TenantSpec::interactive(), TenantSpec::batch()];
    }
    if rng.uniform() < 0.3 {
        spec.model = ArrivalModel::ClosedLoop {
            clients: 1 + rng.below(4),
            mean_think_ms: 50.0 + rng.uniform() * 500.0,
        };
    }
    spec.generate(rng.next_u64())
}

fn random_service(rng: &mut Rng) -> SyntheticService {
    SyntheticService::new(
        5.0 + rng.uniform() * 50.0,
        rng.uniform() * 2.0,
        5.0 + rng.uniform() * 100.0,
    )
}

#[test]
fn prop_no_replica_runs_two_sessions_at_once() {
    check("replica bookings disjoint", CASES, 101, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(4),
            memory: MemoryModel::unlimited(),
            preempt_budget_ms: if rng.uniform() < 0.3 { Some(200.0) } else { None },
            max_batch: 1,
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(28));
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        for (ri, bookings) in out.bookings.iter().enumerate() {
            for w in bookings.windows(2) {
                let ((_, end_a, id_a), (start_b, _, id_b)) = (w[0], w[1]);
                if start_b < end_a {
                    return Err(format!(
                        "replica {ri}: request {id_b} started at {start_b} before \
                         request {id_a} finished at {end_a}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_completions_conserve_requested_tokens() {
    check("token conservation without preemption", CASES, 102, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(3),
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(20));
        let requested: usize = reqs.iter().map(|r| r.out_tokens).sum();
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        if out.records.len() != reqs.len() {
            return Err(format!("{} records for {} requests", out.records.len(), reqs.len()));
        }
        let mut produced = 0usize;
        for r in &out.records {
            if r.outcome != SessionOutcome::Completed {
                return Err(format!("request {} not completed: {:?}", r.id, r.outcome));
            }
            if r.tokens.len() != r.requested_tokens {
                return Err(format!(
                    "request {} produced {}/{} tokens",
                    r.id,
                    r.tokens.len(),
                    r.requested_tokens
                ));
            }
            produced += r.tokens.len();
        }
        if produced != requested {
            return Err(format!("produced {produced} of {requested} requested tokens"));
        }
        Ok(())
    });
}

#[test]
fn prop_zero_queueing_when_capacity_exceeds_load() {
    check("no queueing under capacity", CASES, 103, |rng| {
        // Fixed service: ttft 10 + 5 ms per output token beyond the first.
        let out_tokens = 1 + rng.below(8);
        let service_ms = 10.0 + 5.0 * (out_tokens as f64 - 1.0);
        // Arrival gaps strictly larger than the service time.
        let gap = service_ms + 1.0 + rng.uniform() * 100.0;
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::open_loop(i, vec![1, 2, 3], out_tokens, i as f64 * gap))
            .collect();
        let cfg = SchedulerConfig { policy: random_policy(rng), ..Default::default() };
        let mut svc = SyntheticService::new(10.0, 0.0, 5.0);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        for r in &out.records {
            if r.queued_ms() != 0.0 {
                return Err(format!("request {} queued {} ms", r.id, r.queued_ms()));
            }
            if r.ttft_ms() != Some(10.0) {
                return Err(format!("request {} ttft {:?}", r.id, r.ttft_ms()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_memory_ledger_balances_to_zero() {
    check("ledger drains fully", CASES, 104, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(3),
            memory: MemoryModel {
                budget_bytes: 2_000,
                kv_bytes_per_token: 10,
                session_fixed_bytes: 100,
            },
            preempt_budget_ms: None,
            max_batch: 1 + rng.below(3),
            ..Default::default()
        };
        // Mixed sizes: some requests exceed the 2 000-byte budget and must
        // be rejected; the rest must drain the ledger back to zero (the
        // scheduler debug-asserts dealloc() frees exactly what was
        // allocated, so a run that finishes proves balance).
        let reqs: Vec<Request> = (0..16)
            .map(|i| {
                let long = rng.uniform() < 0.25;
                let prompt_len = if long { 200 } else { 16 };
                Request::open_loop(i, vec![1; prompt_len], 8, i as f64 * 20.0)
            })
            .collect();
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        for r in &out.records {
            let bytes = cfg
                .memory
                .session_bytes(reqs.iter().find(|q| q.id == r.id).expect("request exists"));
            let should_reject = bytes > cfg.memory.budget_bytes;
            let rejected = r.outcome == SessionOutcome::Rejected;
            if should_reject != rejected {
                return Err(format!(
                    "request {} ({bytes} B, budget {}): rejected={rejected}",
                    r.id, cfg.memory.budget_bytes
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_closed_loop_bounds_concurrency() {
    check("closed loop <= clients in flight", CASES, 105, |rng| {
        let clients = 1 + rng.below(3);
        let spec = WorkloadSpec {
            model: ArrivalModel::ClosedLoop { clients, mean_think_ms: 20.0 },
            ..WorkloadSpec::poisson(1.0, 12, 256)
        };
        let reqs = spec.generate(rng.next_u64());
        let cfg = SchedulerConfig { n_replicas: 4, ..Default::default() };
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        // Count maximum overlap of service intervals across replicas.
        let mut edges: Vec<(f64, i32)> = Vec::new();
        for bookings in &out.bookings {
            for &(s, e, _) in bookings {
                edges.push((s, 1));
                edges.push((e, -1));
            }
        }
        edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let (mut cur, mut peak) = (0i32, 0i32);
        for (_, d) in edges {
            cur += d;
            peak = peak.max(cur);
        }
        if peak > clients as i32 {
            return Err(format!("{peak} sessions in flight with only {clients} clients"));
        }
        Ok(())
    });
}

#[test]
fn prop_preemption_respects_budget() {
    check("preempted sessions fit the budget", CASES, 106, |rng| {
        let budget = 30.0 + rng.uniform() * 100.0;
        let cfg = SchedulerConfig { preempt_budget_ms: Some(budget), ..Default::default() };
        let reqs = random_workload(rng, 4 + rng.below(12));
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        for r in &out.records {
            if r.service_ms() > budget + 1e-9 {
                return Err(format!(
                    "request {} held its replica {} ms, budget {budget}",
                    r.id,
                    r.service_ms()
                ));
            }
            if r.outcome == SessionOutcome::Preempted && r.tokens.len() >= r.requested_tokens {
                return Err(format!("request {} preempted but complete", r.id));
            }
        }
        Ok(())
    });
}

#[test]
fn same_seed_yields_byte_identical_bench_json() {
    let base = WorkloadSpec {
        tenants: vec![TenantSpec::interactive(), TenantSpec::batch()],
        ..WorkloadSpec::poisson(1.0, 16, 256)
    };
    let rates = [0.5, 2.0, 8.0];
    let sched = SchedulerConfig {
        policy: Policy::Edf,
        n_replicas: 2,
        memory: MemoryModel { budget_bytes: 10_000, kv_bytes_per_token: 5, session_fixed_bytes: 50 },
        preempt_budget_ms: Some(500.0),
        max_batch: 1,
        ..Default::default()
    };
    let run = || {
        let mut od = SyntheticService::new(30.0, 0.8, 100.0);
        let mut tr = SyntheticService::new(15.0, 0.4, 75.0);
        let mut systems: Vec<(String, &mut dyn ServiceModel)> =
            vec![("od-moe".into(), &mut od), ("transformers".into(), &mut tr)];
        let results = rate_sweep(&mut systems, &base, &rates, &sched, 42).unwrap();
        sweep_json(&results, &base, &rates, &sched, 42).to_string()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "BENCH_serve.json must be byte-identical for the same seed");
    assert!(a.contains("\"policy\":\"edf\""));
    assert!(a.contains("\"rates_per_s\":[0.5,2,8]"));
}

#[test]
fn prop_max_batch_one_is_the_sequential_scheduler() {
    // With `max_batch: 1` the batched dispatch path must be byte-for-byte
    // the sequential scheduler: the service's batch efficiency can never
    // matter for one-session batches.
    check("max_batch 1 == sequential", CASES, 107, |rng| {
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(3),
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(16));
        let base = random_service(rng);
        let mut plain = base.clone();
        let mut amortized = base.with_batch_marginal(0.1);
        let a = Scheduler::run(&cfg, &mut plain, &reqs).map_err(|e| e.to_string())?;
        let b = Scheduler::run(&cfg, &mut amortized, &reqs).map_err(|e| e.to_string())?;
        for (x, y) in a.records.iter().zip(&b.records) {
            if (x.id, x.start_ms, x.finish_ms, x.first_token_ms, &x.tokens)
                != (y.id, y.start_ms, y.finish_ms, y.first_token_ms, &y.tokens)
            {
                return Err(format!("records diverge for request {} / {}", x.id, y.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batched_concurrency_bounded_and_tokens_conserved() {
    check("<= max_batch in flight per replica", CASES, 108, |rng| {
        let k = 1 + rng.below(4);
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas: 1 + rng.below(3),
            max_batch: k,
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(20));
        let mut svc = random_service(rng).with_batch_marginal(rng.uniform());
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        // Max overlap of service intervals per replica must stay <= k.
        for (ri, bookings) in out.bookings.iter().enumerate() {
            let mut edges: Vec<(f64, i32)> = Vec::new();
            for &(s, e, _) in bookings {
                edges.push((s, 1));
                edges.push((e, -1));
            }
            edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            let (mut cur, mut peak) = (0i32, 0i32);
            for (_, d) in edges {
                cur += d;
                peak = peak.max(cur);
            }
            if peak > k as i32 {
                return Err(format!("replica {ri}: {peak} sessions in flight, max_batch {k}"));
            }
        }
        // Batching must not lose or invent tokens.
        let requested: usize = reqs.iter().map(|r| r.out_tokens).sum();
        let produced: usize = out.records.iter().map(|r| r.tokens.len()).sum();
        if produced != requested {
            return Err(format!("produced {produced} of {requested} requested tokens"));
        }
        if out.records.iter().any(|r| r.outcome != SessionOutcome::Completed) {
            return Err("all sessions must complete without preemption/rejection".into());
        }
        Ok(())
    });
}

#[test]
fn prop_replica_failure_loses_no_work_and_dead_replica_stays_dead() {
    check("replica failure requeues, survivors drain", CASES, 109, |rng| {
        let n_replicas = 2 + rng.below(3);
        let fail_ri = rng.below(n_replicas - 1); // replica n-1 always survives
        let fail_ms = rng.uniform() * 400.0;
        let cfg = SchedulerConfig {
            policy: random_policy(rng),
            n_replicas,
            max_batch: 1 + rng.below(3),
            replica_failures: vec![(fail_ri, fail_ms)],
            ..Default::default()
        };
        let reqs = random_workload(rng, 4 + rng.below(20));
        let mut svc = random_service(rng);
        let out = Scheduler::run(&cfg, &mut svc, &reqs).map_err(|e| e.to_string())?;
        // No work lost: every request completes with its full token count.
        let requested: usize = reqs.iter().map(|r| r.out_tokens).sum();
        let produced: usize = out.records.iter().map(|r| r.tokens.len()).sum();
        if produced != requested {
            return Err(format!("produced {produced} of {requested} requested tokens"));
        }
        if out.records.iter().any(|r| r.outcome != SessionOutcome::Completed) {
            return Err("every session must still complete".into());
        }
        // The dead replica serves nothing past its failure instant.
        for &(start, end, id) in &out.bookings[fail_ri] {
            if end > fail_ms + 1e-9 {
                return Err(format!(
                    "request {id} booked on dead replica {fail_ri}: [{start}, {end}] past {fail_ms}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn batching_raises_throughput_under_overload() {
    // Overloaded single replica: co-scheduling amortizes decode, so the
    // same workload drains strictly faster with a larger batch limit.
    let spec = WorkloadSpec { shared_prompt: true, ..WorkloadSpec::poisson(50.0, 24, 256) };
    let reqs = spec.generate(17);
    let run = |max_batch| {
        let cfg = SchedulerConfig { max_batch, ..Default::default() };
        let mut svc = SyntheticService::new(20.0, 0.0, 50.0).with_batch_marginal(0.05);
        Scheduler::run(&cfg, &mut svc, &reqs).unwrap().makespan_ms
    };
    let sequential = run(1);
    let batched = run(8);
    assert!(
        batched < sequential,
        "batched makespan {batched} must beat sequential {sequential}"
    );
}

#[test]
fn same_seed_yields_byte_identical_batch_json() {
    let base = WorkloadSpec { shared_prompt: true, ..WorkloadSpec::poisson(4.0, 16, 256) };
    let batches = [1usize, 2, 4];
    let rates = [2.0, 8.0];
    let sched = SchedulerConfig::default();
    let run = || {
        let mut od = SyntheticService::new(30.0, 0.8, 100.0).with_batch_marginal(0.1);
        let mut tr = SyntheticService::new(15.0, 0.4, 75.0).with_batch_marginal(0.05);
        let mut systems: Vec<(String, &mut dyn ServiceModel)> =
            vec![("od-moe".into(), &mut od), ("transformers".into(), &mut tr)];
        let results = batch_sweep(&mut systems, &base, &batches, &rates, &sched, 42).unwrap();
        batch_sweep_json(&results, &base, &batches, &rates, &sched, 42).to_string()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "BENCH_batch.json must be byte-identical for the same seed");
    assert!(a.contains("\"bench\":\"batch\""));
    assert!(a.contains("\"batches\":[1,2,4]"));
}

#[test]
fn slo_separates_tenants_under_load() {
    // Two tenants, tight vs relaxed SLO, overloaded single replica: the
    // relaxed tenant keeps full attainment, the tight one loses some.
    let spec = WorkloadSpec {
        model: ArrivalModel::Poisson { rate_per_s: 20.0 },
        tenants: vec![
            TenantSpec::new("tight", Slo::new(50.0, 20.0)),
            TenantSpec::new("loose", Slo::relaxed()),
        ],
        ..WorkloadSpec::poisson(20.0, 24, 256)
    };
    let reqs = spec.generate(9);
    let mut svc = SyntheticService::new(20.0, 0.0, 10.0);
    let out = Scheduler::run(&SchedulerConfig::default(), &mut svc, &reqs).unwrap();
    let report = odmoe::serve::ServeReport::from_outcome(
        "stub",
        20.0,
        &out,
        &["tight".to_string(), "loose".to_string()],
    );
    assert_eq!(report.tenants.len(), 2);
    assert!(report.tenants[1].slo_attainment > report.tenants[0].slo_attainment);
    assert_eq!(report.tenants[1].slo_attainment, 1.0);
}
