//! Property tests over coordinator invariants (routing, batching,
//! scheduling, caches, quantization) using the in-tree prop driver.

use odmoe::cache::{ExpertCache, Policy};
use odmoe::cluster::{HardwareProfile, Resource};
use odmoe::coordinator::{GroupSchedule, SlotMap};
use odmoe::engine::padded_batch;
use odmoe::metrics::{correct_count, kl_divergence, RecallStats};
use odmoe::model::rng::Rng;
use odmoe::model::{ModelConfig, Precision, WeightStore};
use odmoe::quant;
use odmoe::util::prop::check;

const CASES: usize = 64;

#[test]
fn prop_resource_bookings_never_overlap() {
    check("resource bookings disjoint", CASES, 11, |rng| {
        let mut r = Resource::new();
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for _ in 0..20 {
            let earliest = rng.uniform() * 100.0;
            let dur = rng.uniform() * 10.0;
            let (s, e) = r.acquire(earliest, dur);
            if s < earliest {
                return Err(format!("start {s} before earliest {earliest}"));
            }
            for &(a, b) in &intervals {
                if s < b && a < e && e - s > 0.0 {
                    return Err(format!("overlap: ({s},{e}) vs ({a},{b})"));
                }
            }
            intervals.push((s, e));
        }
        Ok(())
    });
}

#[test]
fn prop_resource_preempt_only_shrinks() {
    check("preempt never extends bookings", CASES, 12, |rng| {
        let mut r = Resource::new();
        r.acquire(0.0, rng.uniform() * 20.0);
        let before = r.free_at();
        let at = rng.uniform() * 30.0;
        r.preempt(at);
        if r.free_at() > before {
            return Err("free_at grew".into());
        }
        if r.free_at() > before.max(at) {
            return Err("preempt left resource busy past both bounds".into());
        }
        Ok(())
    });
}

#[test]
fn prop_group_schedule_partitions_workers() {
    check("groups partition workers", CASES, 13, |rng| {
        let group_size = 1 + rng.below(4);
        let n_groups = 1 + rng.below(6);
        let s = GroupSchedule::new(group_size * n_groups, group_size);
        // Every worker appears in exactly one group.
        let mut seen = vec![0usize; s.n_workers];
        for g in 0..s.n_groups() {
            for w in s.workers_of(g) {
                seen[w] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err(format!("not a partition: {seen:?}"));
        }
        // Round-robin covers all groups cyclically.
        for l in 0..32 {
            if s.group_of(l) != l % s.n_groups() {
                return Err("round robin broken".into());
            }
            let w = s.worker_for(l, rng.below(group_size));
            if !s.workers_of(s.group_of(l)).contains(&w) {
                return Err("worker outside its group".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_worker_for_lies_in_its_group() {
    // The satellite invariant: `worker_for(layer, slot)` is a member of
    // `workers_of(group_of(layer))` for ALL layers and slots — both for
    // the static blueprint and for the dynamic slot map, healthy or not.
    check("worker_for ∈ workers_of(group_of)", CASES, 23, |rng| {
        let group_size = 1 + rng.below(4);
        let n_groups = 1 + rng.below(6);
        let s = GroupSchedule::new(group_size * n_groups, group_size);
        let m = SlotMap::from_schedule(&s);
        for l in 0..64 {
            for slot in 0..group_size {
                let w = s.worker_for(l, slot);
                if !s.workers_of(s.group_of(l)).contains(&w) {
                    return Err(format!("static: worker {w} outside group of layer {l}"));
                }
                if m.worker_for(l, slot) != w {
                    return Err("healthy slot map must match the blueprint".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_slotmap_survives_failures_with_full_coverage() {
    check("slots always route to live workers", CASES, 24, |rng| {
        let group_size = 1 + rng.below(3);
        // Sometimes uneven: spares exercise the first-fit relaxation.
        let n_workers = group_size * (1 + rng.below(5)) + rng.below(group_size);
        let mut m = SlotMap::new(n_workers, group_size);
        let total_slots = m.n_groups() * group_size;
        let load_ms = 1.0 + rng.uniform() * 20.0;
        let window_ms = rng.uniform() * 60.0;
        let kills = rng.below(n_workers); // always leaves >= 1 survivor
        for _ in 0..kills {
            let alive: Vec<usize> = (0..n_workers).filter(|&w| m.is_alive(w)).collect();
            let victim = alive[rng.below(alive.len())];
            m.fail(victim, |slots| slots as f64 * load_ms <= window_ms);
            // Every slot maps into its group's current worker list, and
            // only live workers serve.
            for l in 0..32 {
                for slot in 0..group_size {
                    let w = m.worker_for(l, slot);
                    if !m.is_alive(w) {
                        return Err(format!("layer {l} slot {slot} on dead worker {w}"));
                    }
                    if !m.workers_of(m.group_of(l)).contains(&w) {
                        return Err(format!("worker {w} outside group of layer {l}"));
                    }
                }
            }
            // Slot conservation: reassignment never loses or invents work.
            let assigned: usize = (0..n_workers).map(|w| m.load_of(w)).sum();
            if assigned != total_slots {
                return Err(format!("{assigned} slots assigned, expected {total_slots}"));
            }
            let dead_load: usize =
                (0..n_workers).filter(|&w| !m.is_alive(w)).map(|w| m.load_of(w)).sum();
            if dead_load != 0 {
                return Err(format!("{dead_load} slots still on dead workers"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eq1_window_monotone_in_groups() {
    check("t_maxload grows with group count", CASES, 14, |rng| {
        let t_m = rng.uniform() * 10.0 + 0.1;
        let t_w = rng.uniform() * 10.0 + 0.1;
        let g2 = GroupSchedule::new(4, 2).t_maxload(t_m, t_w);
        let g4 = GroupSchedule::new(8, 2).t_maxload(t_m, t_w);
        if g4 <= g2 {
            return Err(format!("window must grow: {g2} vs {g4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cache_never_exceeds_capacity_and_counts_consistent() {
    check("cache capacity + stats", CASES, 15, |rng| {
        let cap = 1 + rng.below(8);
        let policy = if rng.uniform() < 0.5 { Policy::Lru } else { Policy::Lfu };
        let mut c = ExpertCache::new(cap, policy);
        let mut ops = 0u64;
        for _ in 0..100 {
            let key = (rng.below(4), rng.below(8));
            if rng.uniform() < 0.5 {
                c.touch(key);
                ops += 1;
            } else {
                c.insert(key);
            }
            if c.len() > cap {
                return Err(format!("len {} > cap {cap}", c.len()));
            }
        }
        if c.hits + c.misses != ops {
            return Err("hit+miss != touches".into());
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_error_ordering_any_seed() {
    check("fp16 <= int8 <= nf4 error", 16, 16, |rng| {
        let w = rng.normal_vec(64 * 8, 0.5);
        let err = |q: &[f32]| -> f32 {
            q.iter().zip(&w).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
        };
        let e16 = err(&quant::fake_quant_fp16(&w));
        let e8 = err(&quant::fake_quant_int8(&w, 64));
        let e4 = err(&quant::fake_quant_nf4(&w));
        if !(e16 <= e8 && e8 <= e4) {
            return Err(format!("ordering broken: {e16} {e8} {e4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_recall_stats_bounded() {
    check("recall in [0,1]", CASES, 17, |rng| {
        let k = 1 + rng.below(3);
        let layers = 1 + rng.below(12);
        let mut s = RecallStats::new(k, layers);
        for n in 0..rng.below(20) + 1 {
            let correct: Vec<usize> = (0..layers).map(|_| rng.below(k + 1)).collect();
            s.record_token(n, &correct);
        }
        let r = s.recall();
        if !(0.0..=1.0).contains(&r) {
            return Err(format!("recall {r} out of range"));
        }
        for n in 0..s.max_token() {
            if let Some(rn) = s.recall_at(n) {
                if !(0.0..=1.0).contains(&rn) {
                    return Err(format!("recall_at({n}) = {rn}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_correct_count_bounds() {
    check("0 <= correct_count <= k", CASES, 18, |rng| {
        let k = 1 + rng.below(3);
        let pick = |rng: &mut Rng| -> Vec<usize> {
            let mut v = Vec::new();
            while v.len() < k {
                let e = rng.below(8);
                if !v.contains(&e) {
                    v.push(e);
                }
            }
            v
        };
        let a = pick(rng);
        let b = pick(rng);
        let c = correct_count(&a, &b);
        if c > k {
            return Err(format!("count {c} > k {k}"));
        }
        if correct_count(&a, &a) != k {
            return Err("self-intersection must be k".into());
        }
        Ok(())
    });
}

#[test]
fn prop_padded_batch_covers_and_is_supported() {
    check("padded batch >= n and supported", CASES, 19, |rng| {
        let n = 1 + rng.below(128);
        let b = padded_batch(n);
        if b < n {
            return Err(format!("pad {b} < n {n}"));
        }
        if !odmoe::runtime::EXPERT_FFN_SIZES.contains(&b) {
            return Err(format!("unsupported batch {b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_kl_nonnegative() {
    check("KL >= 0", CASES, 20, |rng| {
        let p: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let q: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
        let kl = kl_divergence(&p, &q);
        if kl < -1e-9 {
            return Err(format!("negative KL {kl}"));
        }
        Ok(())
    });
}

#[test]
fn prop_weight_quantization_preserves_shape_and_seed_determinism() {
    check("quantized store shapes", 6, 21, |rng| {
        let seed = rng.next_u64();
        let cfg = ModelConfig::default();
        let ws = WeightStore::generate(&cfg, seed);
        for p in [Precision::Fp16, Precision::Int8, Precision::Nf4] {
            let q = ws.quantized(p);
            if q.layers.len() != ws.layers.len() {
                return Err("layer count changed".into());
            }
            if q.experts[0][0].w1.len() != ws.experts[0][0].w1.len() {
                return Err("expert shape changed".into());
            }
        }
        let again = WeightStore::generate(&cfg, seed);
        if again.embedding != ws.embedding {
            return Err("generation not deterministic".into());
        }
        Ok(())
    });
}

#[test]
fn prop_eq1_feasibility_matches_definition() {
    check("io_bottleneck_free consistent with Eq. 1", CASES, 22, |rng| {
        let mut p = HardwareProfile::rtx3090();
        p.pcie_gbps = 1.0 + rng.uniform() * 50.0;
        let s = GroupSchedule::new(8, 2);
        let free = s.io_bottleneck_free(&p);
        let manual = p.expert_load_ms(1.0) <= s.t_maxload(p.t_main_ms(), p.t_worker_ms());
        if free != manual {
            return Err("feasibility check disagrees with Eq. 1".into());
        }
        Ok(())
    });
}
