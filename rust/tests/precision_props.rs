//! Engine-level contracts of runtime mixed-precision expert loading
//! (DESIGN.md §14): transfer downgrades are a *virtual-time* knob — a
//! policy that only changes transfer precision must serve a token stream
//! bit-identical to the static seed engine on every path (uniform,
//! heterogeneous fleet, chunked streaming, mid-stream failover) — while
//! on a tight-window fleet the downgrades must actually fire, accrue
//! honest quality debt, and strictly beat the static engine's decode
//! clock. Needs the AOT artifacts (same convention as
//! `engine_integration.rs`).

use odmoe::coordinator::{
    BatchEngine, Engine, FailureSpec, OdMoeConfig, OdMoeEngine, PrecisionPolicy,
};
use odmoe::fleet::FleetSpec;
use odmoe::model::WeightStore;
use odmoe::workload::Corpus;
use odmoe::Runtime;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn prompt(rt: &Runtime) -> Vec<u32> {
    Corpus::generate(29, 1, 16, rt.cfg.vocab_size as u32).prompts.pop().unwrap()
}

/// All workers are embedded-class: no worker can land an fp16 train
/// inside its Eq. (1) window, so a slack-aware controller downgrades
/// every load — the fleet where the policy must pay for itself.
fn tight_fleet() -> FleetSpec {
    FleetSpec::parse("jetson:4,nano:2").unwrap()
}

fn cfg_with(policy: PrecisionPolicy, fleet: Option<FleetSpec>, chunks: usize) -> OdMoeConfig {
    let mut cfg = OdMoeConfig {
        precision_policy: policy,
        chunks,
        ..OdMoeConfig::default()
    };
    if let Some(f) = fleet {
        cfg.n_workers = f.n_nodes();
        cfg.fleet = Some(f);
    }
    cfg
}

/// Transfer-precision policies never touch numerics: on the uniform
/// cluster the three policies serve bit-identical token streams (only
/// the virtual clock may move).
#[test]
fn transfer_only_policies_serve_identical_tokens_uniform() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(&rt);
    let mut reference = None;
    for policy in PrecisionPolicy::ALL {
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg_with(policy, None, 1)).unwrap();
        let r = e.run_prompt(&p, 10, false).unwrap();
        match &reference {
            None => reference = Some(r.tokens),
            Some(toks) => assert_eq!(
                toks,
                &r.tokens,
                "{} drifted from the static stream",
                policy.label()
            ),
        }
    }
}

/// Same contract on the hard path: heterogeneous tight-window fleet,
/// chunked streaming, and a mid-run worker death (the failover re-books
/// the undelivered suffix, possibly at a downgraded tier). Tokens stay
/// bit-identical across policies under the *same* fault plan, and the
/// slack-aware engine never decodes slower than static.
#[test]
fn policies_preserve_tokens_under_chunks_and_failover() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(&rt);
    let out = 8;
    let mut static_res = None;
    for policy in PrecisionPolicy::ALL {
        let cfg = cfg_with(policy, Some(tight_fleet()), 4);
        let mut e = OdMoeEngine::new(&rt, ws.clone(), cfg).unwrap();
        e.inject_failure(FailureSpec::Worker { worker: 1, at_ms: 5.0 });
        let r = e.run_batch(&[(p.as_slice(), out)]).unwrap();
        match &static_res {
            None => static_res = Some(r),
            Some(base) => {
                assert_eq!(
                    base.sessions[0].tokens,
                    r.sessions[0].tokens,
                    "{} drifted under chunked failover",
                    policy.label()
                );
                assert!(
                    r.decode_span_ms <= base.decode_span_ms + 1e-6,
                    "{} decoded slower than static: {} vs {}",
                    policy.label(),
                    r.decode_span_ms,
                    base.decode_span_ms
                );
            }
        }
    }
}

/// On the tight-window fleet the controller's downgrades actually fire:
/// zero fp16 streams (no embedded worker fits one), every load at
/// int8/nf4, honest nonzero quality debt on the gauge — and a strictly
/// faster decode clock than the static engine on the same session.
#[test]
fn tight_fleet_downgrades_fire_and_pay() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(&rt);
    let out = 8;

    let mut stat =
        OdMoeEngine::new(&rt, ws.clone(), cfg_with(PrecisionPolicy::Static, Some(tight_fleet()), 1))
            .unwrap();
    let base = stat.run_batch(&[(p.as_slice(), out)]).unwrap();

    let mut e = OdMoeEngine::new(
        &rt,
        ws.clone(),
        cfg_with(PrecisionPolicy::SlackImportance, Some(tight_fleet()), 1),
    )
    .unwrap();
    let r = e.run_batch(&[(p.as_slice(), out)]).unwrap();
    assert_eq!(base.sessions[0].tokens, r.sessions[0].tokens, "downgrades must not drift tokens");

    let reg = e.registry();
    let fp16 = reg.counter("engine.loads_fp16");
    let int8 = reg.counter("engine.loads_int8");
    let nf4 = reg.counter("engine.loads_nf4");
    assert_eq!(fp16, 0, "no embedded-class worker fits an fp16 train in-window");
    assert!(int8 + nf4 > 0, "the tight fleet must downgrade its loads");
    let debt = reg.gauge("engine.quality_debt_frac").expect("debt gauge published");
    assert!(debt > 0.0, "downgraded streams must accrue quality debt, got {debt}");
    assert!(
        r.decode_span_ms < base.decode_span_ms,
        "slack-importance must beat static on the tight fleet: {} vs {}",
        r.decode_span_ms,
        base.decode_span_ms
    );
}

/// The static engine publishes none of the controller's telemetry — the
/// counters exist only when a controller does, so a zero reading in the
/// sweep is "no downgrades", never "no instrumentation".
#[test]
fn static_engine_publishes_no_precision_telemetry() {
    let rt = runtime();
    let ws = WeightStore::generate(&rt.cfg, 42);
    let p = prompt(&rt);
    let mut e = OdMoeEngine::new(&rt, ws, cfg_with(PrecisionPolicy::Static, None, 1)).unwrap();
    e.run_batch(&[(p.as_slice(), 6)]).unwrap();
    let reg = e.registry();
    assert_eq!(reg.counter("engine.loads_fp16"), 0);
    assert_eq!(reg.counter("engine.loads_int8"), 0);
    assert_eq!(reg.counter("engine.loads_nf4"), 0);
    assert!(reg.gauge("engine.quality_debt_frac").is_none(), "static publishes no debt gauge");
}
