//! Property tests for chunked expert streaming (DESIGN.md §9): abort
//! accounting on the span-aware [`Resource`], chunk-count-1 equivalence
//! with the monolithic path, and the tile-pipeline bound. All of these
//! run without the PJRT runtime — the engine-level equivalences live in
//! `batch_props.rs` / `failure_injection.rs` next to their monolithic
//! counterparts.

use odmoe::cluster::{Cluster, HardwareProfile, Resource};
use odmoe::trace::EventKind;
use odmoe::util::prop::check;

const CASES: usize = 64;

/// `busy_total` must equal the surviving booked spans under ANY
/// interleaving of chunk-train bookings and aborts — aborted speculative
/// chunks never inflate it, and it stays finite and non-negative.
#[test]
fn prop_aborted_chunks_never_inflate_busy_total() {
    check("abort accounting exact", CASES, 301, |rng| {
        let mut r = Resource::new();
        // Shadow model: the spans we believe are booked.
        let mut spans: Vec<(f64, f64)> = Vec::new();
        for _ in 0..30 {
            if rng.uniform() < 0.65 || spans.is_empty() {
                // Book a chunk train: 1..=8 chunks back to back.
                let chunks = 1 + rng.below(8);
                let earliest = rng.uniform() * 50.0;
                for _ in 0..chunks {
                    let dur = rng.uniform() * 5.0;
                    let (s, e) = r.acquire(earliest, dur);
                    spans.push((s, e));
                }
            } else {
                // Mispredict storm: abort at a random instant, possibly
                // mid-chunk, possibly before every booking.
                let at = rng.uniform() * r.free_at().max(1.0);
                r.preempt(at);
                // Mirror on the shadow: drop/trim spans past `at`.
                spans.retain(|&(s, _)| s < at);
                if let Some(last) = spans.last_mut() {
                    if last.1 > at {
                        last.1 = at;
                    }
                }
                if r.free_at() > at {
                    return Err(format!("free_at {} after preempt({at})", r.free_at()));
                }
            }
            let expected: f64 = spans.iter().map(|&(s, e)| e - s).sum();
            let busy = r.busy_total();
            if !busy.is_finite() || busy < 0.0 {
                return Err(format!("busy_total corrupted: {busy}"));
            }
            if (busy - expected).abs() > 1e-6 {
                return Err(format!("busy {busy} != surviving spans {expected}"));
            }
        }
        Ok(())
    });
}

/// Chunk count 1 must reproduce today's monolithic-load timings exactly:
/// same (start, done), same link accounting, under random stragglers and
/// random link contention.
#[test]
fn prop_chunk_count_one_is_bit_identical_to_monolithic() {
    check("chunks=1 == monolithic", CASES, 302, |rng| {
        let mut a = Cluster::new(HardwareProfile::rtx3090(), 2);
        let mut b = Cluster::new(HardwareProfile::rtx3090(), 2);
        let slow = 1.0 + rng.uniform() * 4.0;
        a.inject_straggler(0, slow);
        b.inject_straggler(0, slow);
        for _ in 0..8 {
            let w = rng.below(2);
            let earliest = rng.uniform() * 100.0;
            let bytes = 1e6 + rng.uniform() * 1e9;
            let (s1, e1) = a.expert_load(w, earliest, bytes);
            let t = b.expert_load_chunked(w, earliest, bytes, 1, EventKind::ExpertLoad);
            if (s1, e1) != (t.start, t.done()) {
                return Err(format!("({s1},{e1}) vs ({},{})", t.start, t.done()));
            }
            if t.first_ready() != t.done() {
                return Err("one chunk must mean first == last".into());
            }
        }
        for w in 0..2 {
            let (ba, bb) =
                (a.workers[w].pcie.busy_total(), b.workers[w].pcie.busy_total());
            if ba != bb {
                return Err(format!("worker {w} busy {ba} vs {bb}"));
            }
        }
        Ok(())
    });
}

/// Mispredict storms over chunk trains on a live cluster: delivered
/// chunks stay busy, undelivered ones are reclaimed, floors protect work
/// queued ahead, and accounting survives straggler slowdowns.
#[test]
fn prop_mispredict_storms_keep_cluster_accounting_sane() {
    check("chunked mispredict storm", CASES, 303, |rng| {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 3);
        if rng.uniform() < 0.5 {
            c.inject_straggler(rng.below(3), 1.0 + rng.uniform() * 7.0);
        }
        for _ in 0..20 {
            let w = rng.below(3);
            let chunks = 1 + rng.below(8);
            let earliest = rng.uniform() * 40.0;
            let bytes = c.profile.expert_bytes * (0.2 + rng.uniform());
            let t = c.expert_load_chunked(w, earliest, bytes, chunks, EventKind::ExpertLoad);
            if rng.uniform() < 0.5 {
                // Gate result disagreed: cancel the undelivered suffix,
                // floored at the train's own start era.
                let at = t.start + rng.uniform() * (t.done() - t.start);
                c.workers[w].pcie.preempt(at.max(t.free_before));
            }
            for node in &c.workers {
                let busy = node.pcie.busy_total();
                if !busy.is_finite() || busy < 0.0 {
                    return Err(format!("worker {} busy corrupted: {busy}", node.id));
                }
                if node.pcie.free_at() > 1e9 || !node.pcie.free_at().is_finite() {
                    return Err(format!("worker {} free_at diverged", node.id));
                }
            }
        }
        Ok(())
    });
}

/// The tile pipeline never finishes later than the monolithic compute
/// gated on the last chunk: `end <= max(earliest, last_gate) + base`.
#[test]
fn prop_chunked_compute_bounded_by_monolithic() {
    check("tile pipeline bound", CASES, 304, |rng| {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 1);
        let base = 0.5 + rng.uniform() * 4.0;
        let earliest = rng.uniform() * 20.0;
        let k = 1 + rng.below(8);
        // Ascending random gates (chunk completion times).
        let mut gates: Vec<f64> = Vec::with_capacity(k);
        let mut t = rng.uniform() * 30.0;
        for _ in 0..k {
            t += rng.uniform() * 10.0;
            gates.push(t);
        }
        let (_, end) = c.expert_compute_chunked(0, earliest, base, &gates);
        let last_gate = *gates.last().expect("k >= 1");
        let mono_end = earliest.max(last_gate) + base;
        if end > mono_end + 1e-9 {
            return Err(format!("pipelined end {end} beats nothing: mono {mono_end}"));
        }
        // GPU busy time is exactly one FFN regardless of tiling.
        let busy = c.workers[0].gpu.busy_total();
        if (busy - base).abs() > 1e-9 {
            return Err(format!("gpu busy {busy} != base {base}"));
        }
        Ok(())
    });
}

/// Resuming a dead worker's stream re-books only the undelivered chunks:
/// the resumed train moves exactly the remaining durations.
#[test]
fn prop_failover_resume_books_only_undelivered_chunks() {
    check("failover resumes the suffix", CASES, 305, |rng| {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        let chunks = 2 + rng.below(7);
        let bytes = c.profile.expert_bytes;
        let durs = c.profile.chunk_durations(bytes, chunks);
        let t = c.expert_load_chunked(0, 0.0, bytes, chunks, EventKind::ExpertLoad);
        // Kill worker 0 somewhere inside the stream.
        let at = t.start + rng.uniform() * (t.done() - t.start - 1e-9);
        let delivered = t.delivered_by(at);
        c.fail_worker(0, at);
        if delivered >= chunks {
            return Err("a stream that died cannot have delivered every chunk".into());
        }
        // The replacement books only the suffix.
        let resume = c.expert_load_chunks(1, at, &durs[delivered..], EventKind::ExpertLoad);
        let expected: f64 = durs[delivered..].iter().sum();
        let booked = c.workers[1].pcie.busy_total();
        if (booked - expected).abs() > 1e-9 {
            return Err(format!("resumed {booked} ms, expected suffix {expected}"));
        }
        if resume.chunk_ends.len() != chunks - delivered {
            return Err(format!(
                "{} resumed chunks, expected {}",
                resume.chunk_ends.len(),
                chunks - delivered
            ));
        }
        // The dead link keeps only what it actually moved.
        let dead_busy = c.workers[0].pcie.busy_total();
        if !dead_busy.is_finite() || dead_busy < 0.0 || dead_busy > at + 1e-9 {
            return Err(format!("dead link busy {dead_busy} vs freeze at {at}"));
        }
        Ok(())
    });
}

/// SSD staging for the tiered cache's cold tier (DESIGN.md §12): the
/// staging time is exactly access latency + payload at `ssd_gbps`,
/// monotone in bytes, and every booking lands on the worker's dedicated
/// storage [`Resource`] — reads queue like PCIe transfers but never
/// touch the PCIe or GPU accounting.
#[test]
fn prop_ssd_staging_books_exact_durations_on_its_own_resource() {
    check("ssd cold-tier staging", CASES, 306, |rng| {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        let mut last_end = [0.0f64; 2];
        let mut booked = [0.0f64; 2];
        let mut prev_ms = 0.0f64;
        let mut bytes = 1e6f64;
        for _ in 0..12 {
            let w = rng.below(2);
            let earliest = rng.uniform() * 30.0;
            // Growing payloads double as the monotonicity probe.
            bytes += rng.uniform() * 2e8;
            let expect = c.profile.ssd_lat_ms + bytes / (c.profile.ssd_gbps * 1e9) * 1e3;
            let ms = c.profile.ssd_stage_ms(bytes);
            if (ms - expect).abs() > 1e-9 {
                return Err(format!("ssd_stage_ms {ms} != model {expect}"));
            }
            if ms + 1e-9 < prev_ms {
                return Err(format!("staging time shrank with a larger payload: {ms}"));
            }
            prev_ms = ms;
            let (s, e) = c.ssd_stage(w, earliest, bytes);
            if s < earliest - 1e-9 {
                return Err(format!("stage started at {s} before earliest {earliest}"));
            }
            if s + 1e-9 < last_end[w] {
                return Err(format!("worker {w}: storage reads must queue: {s} < {}", last_end[w]));
            }
            if ((e - s) - ms).abs() > 1e-9 {
                return Err(format!("booked span {} != staging time {ms}", e - s));
            }
            last_end[w] = e;
            booked[w] += e - s;
        }
        for w in 0..2 {
            let ssd = c.workers[w].ssd.busy_total();
            if (ssd - booked[w]).abs() > 1e-6 {
                return Err(format!("worker {w}: ssd busy {ssd} != booked {}", booked[w]));
            }
            if c.workers[w].pcie.busy_total() != 0.0 || c.workers[w].gpu.busy_total() != 0.0 {
                return Err(format!("worker {w}: staging leaked onto PCIe/GPU"));
            }
        }
        Ok(())
    });
}
