//! Cross-language known-answer tests: execute every AOT artifact through
//! the PJRT runtime on the inputs recorded by `aot.py` and compare with the
//! outputs JAX produced at build time. This validates the entire
//! python -> HLO-text -> rust -> PJRT round trip numerically.

use anyhow::{anyhow, Result};
use odmoe::util::json::Json;

struct Check {
    inputs: Vec<Vec<f64>>,
    input_shapes: Vec<Vec<usize>>,
    input_dtypes: Vec<String>,
    outputs: Vec<Vec<f64>>,
    output_dtypes: Vec<String>,
}

fn artifact_dir() -> String {
    std::env::var("ODMOE_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn parse_check(v: &Json) -> Result<Check> {
    let vecs = |key: &str| -> Result<Vec<Vec<f64>>> {
        v.get(key)?.as_arr()?.iter().map(|a| a.as_f64_vec()).collect()
    };
    let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
        v.get(key)?.as_arr()?.iter().map(|a| a.as_usize_vec()).collect()
    };
    let strs = |key: &str| -> Result<Vec<String>> {
        v.get(key)?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect()
    };
    Ok(Check {
        inputs: vecs("inputs")?,
        input_shapes: shapes("input_shapes")?,
        input_dtypes: strs("input_dtypes")?,
        outputs: vecs("outputs")?,
        output_dtypes: strs("output_dtypes")?,
    })
}

fn load_checks() -> Result<Vec<(String, Check)>> {
    let text = std::fs::read_to_string(format!("{}/checks.json", artifact_dir()))?;
    let v = Json::parse(&text)?;
    v.as_obj()?
        .iter()
        .map(|(k, c)| Ok((k.clone(), parse_check(c)?)))
        .collect()
}

fn run_artifact(name: &str, check: &Check) -> Result<()> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
    let path = format!("{}/{}.hlo.txt", artifact_dir(), name);
    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| anyhow!("{e:?}"))?;
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow!("compile {name}: {e:?}"))?;

    let mut bufs = Vec::new();
    for ((vals, shape), dtype) in check
        .inputs
        .iter()
        .zip(&check.input_shapes)
        .zip(&check.input_dtypes)
    {
        let buf = match dtype.as_str() {
            "float32" => {
                let v: Vec<f32> = vals.iter().map(|&x| x as f32).collect();
                client.buffer_from_host_buffer(&v, shape, None)
            }
            "int32" => {
                let v: Vec<i32> = vals.iter().map(|&x| x as i32).collect();
                client.buffer_from_host_buffer(&v, shape, None)
            }
            other => return Err(anyhow!("unhandled input dtype {other}")),
        }
        .map_err(|e| anyhow!("upload: {e:?}"))?;
        bufs.push(buf);
    }
    let arg_refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let out = exe.execute_b(&arg_refs).map_err(|e| anyhow!("exec {name}: {e:?}"))?;
    let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
    let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
    assert_eq!(parts.len(), check.outputs.len(), "{name}: output arity");

    for (i, ((part, want), dtype)) in parts
        .iter()
        .zip(&check.outputs)
        .zip(&check.output_dtypes)
        .enumerate()
    {
        match dtype.as_str() {
            "float32" => {
                let got = part.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
                assert_eq!(got.len(), want.len(), "{name} out{i} length");
                for (j, (g, w)) in got.iter().zip(want).enumerate() {
                    let diff = (*g as f64 - w).abs();
                    let tol = 1e-4 + 1e-4 * w.abs();
                    assert!(
                        diff <= tol,
                        "{name} out{i}[{j}]: got {g}, want {w} (diff {diff:.3e})"
                    );
                }
            }
            "int32" => {
                let got = part.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?;
                let want_i: Vec<i32> = want.iter().map(|&x| x as i32).collect();
                assert_eq!(got, want_i, "{name} out{i}");
            }
            other => return Err(anyhow!("unhandled output dtype {other}")),
        }
    }
    Ok(())
}

fn run_one(name: &str) {
    let checks = load_checks().expect("artifacts missing — run `make artifacts`");
    let c = checks
        .iter()
        .find(|(n, _)| n == name)
        .unwrap_or_else(|| panic!("artifact {name} not in checks.json"));
    run_artifact(name, &c.1).unwrap();
}

#[test]
fn main_block_decode_matches_jax() {
    run_one("main_block_decode");
}

#[test]
fn lm_head_matches_jax() {
    run_one("lm_head");
}

#[test]
fn expert_ffn_t1_matches_jax() {
    run_one("expert_ffn_t1");
}

#[test]
fn expert_ffn_t16_matches_jax() {
    run_one("expert_ffn_t16");
}

#[test]
fn expert_ffn_t128_matches_jax() {
    run_one("expert_ffn_t128");
}

#[test]
fn prefill_t16_matches_jax() {
    run_one("main_block_prefill_t16");
}

#[test]
fn prefill_t128_matches_jax() {
    run_one("main_block_prefill_t128");
}

#[test]
fn all_checks_execute() {
    let checks = load_checks().expect("artifacts missing — run `make artifacts`");
    assert!(checks.len() >= 11, "expected >= 11 artifacts, got {}", checks.len());
    for (name, c) in &checks {
        run_artifact(name, c).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}
