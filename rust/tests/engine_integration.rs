//! Integration tests over the full Rust-side model engine: generation is
//! deterministic, prefill and decode agree, and the quantized shadow model
//! tracks the full-precision router (the SEP premise).

use odmoe::engine::{BatchState, ModelState};
use odmoe::model::{ModelConfig, Precision, WeightStore};
use odmoe::Runtime;

fn runtime() -> Runtime {
    Runtime::load_default().expect("artifacts missing — run `make artifacts`")
}

fn state(rt: &Runtime, seed: u64) -> ModelState<'_> {
    let ws = WeightStore::generate(&ModelConfig::default(), seed);
    ModelState::new(rt, ws).unwrap()
}

#[test]
fn decode_is_deterministic() {
    let rt = runtime();
    let mut a = state(&rt, 42);
    let mut b = state(&rt, 42);
    let mut tok_a = 17u32;
    let mut tok_b = 17u32;
    for _ in 0..4 {
        let ra = a.decode_step(tok_a).unwrap();
        let rb = b.decode_step(tok_b).unwrap();
        assert_eq!(ra.token_out, rb.token_out);
        assert_eq!(ra.routes, rb.routes);
        tok_a = ra.token_out;
        tok_b = rb.token_out;
    }
}

#[test]
fn routes_are_valid_topk() {
    let rt = runtime();
    let cfg = ModelConfig::default();
    let mut s = state(&rt, 7);
    let rec = s.decode_step(3).unwrap();
    assert_eq!(rec.routes.len(), cfg.n_layers);
    for r in &rec.routes {
        assert_eq!(r.experts.len(), cfg.top_k);
        assert!(r.experts.iter().all(|&e| e < cfg.n_experts));
        assert_ne!(r.experts[0], r.experts[1], "top-2 must be distinct");
        let sum: f32 = r.weights.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "router weights must sum to 1");
        assert!(r.weights[0] >= r.weights[1], "descending router weights");
    }
    assert_eq!(rec.logits.len(), cfg.vocab_size);
}

#[test]
fn prefill_matches_sequential_decode() {
    let rt = runtime();
    let prompt: Vec<u32> = (0..16).map(|i| (i * 13 + 5) % 256).collect();

    let mut via_prefill = state(&rt, 9);
    let rec_p = via_prefill.prefill(&prompt).unwrap();

    let mut via_decode = state(&rt, 9);
    let mut last = None;
    for &t in &prompt {
        last = Some(via_decode.decode_step(t).unwrap());
    }
    let rec_d = last.unwrap();

    assert_eq!(rec_p.token_out, rec_d.token_out, "greedy next token must agree");
    // Per-layer routes of the last prompt token must agree.
    for (l, (rp, rd)) in rec_p.routes.iter().zip(&rec_d.routes).enumerate() {
        assert_eq!(rp.experts, rd.experts, "layer {l} route");
    }
    // And continued decode from both states must produce the same token.
    let n1 = via_prefill.decode_step(rec_p.token_out).unwrap();
    let n2 = via_decode.decode_step(rec_d.token_out).unwrap();
    assert_eq!(n1.token_out, n2.token_out);
}

#[test]
fn shadow_router_agreement_is_high() {
    let rt = runtime();
    let cfg = ModelConfig::default();
    let full_ws = WeightStore::generate(&cfg, 11);
    let mut full = ModelState::new(&rt, full_ws.clone()).unwrap();
    let mut shadow = ModelState::new(&rt, full_ws.quantized(Precision::Fp16)).unwrap();

    let mut agree = 0usize;
    let mut total = 0usize;
    let mut tok = 5u32;
    for _ in 0..8 {
        let rf = full.decode_step(tok).unwrap();
        let rs = shadow.decode_step(tok).unwrap();
        for (a, b) in rf.routes.iter().zip(&rs.routes) {
            let mut ea = a.experts.clone();
            let mut eb = b.experts.clone();
            ea.sort_unstable();
            eb.sort_unstable();
            total += 2;
            agree += ea.iter().filter(|e| eb.contains(e)).count();
        }
        // Keep the two models KV-aligned (this test isolates token drift).
        shadow.align_kv_from(&full);
        tok = rf.token_out;
    }
    let rate = agree as f64 / total as f64;
    assert!(rate > 0.95, "fp16 shadow agreement {rate} too low");
}

#[test]
fn kv_alignment_restores_divergence() {
    let rt = runtime();
    let cfg = ModelConfig::default();
    let ws = WeightStore::generate(&cfg, 13);
    let mut full = ModelState::new(&rt, ws.clone()).unwrap();
    let mut shadow = ModelState::new(&rt, ws.quantized(Precision::Nf4)).unwrap();

    let mut tok = 1u32;
    for _ in 0..6 {
        let r = full.decode_step(tok).unwrap();
        let _ = shadow.decode_step(tok).unwrap();
        tok = r.token_out;
    }
    // After alignment the caches must be bitwise identical.
    shadow.align_kv_from(&full);
    for (a, b) in shadow.caches.iter().zip(&full.caches) {
        assert_eq!(a.k(), b.k());
        assert_eq!(a.v(), b.v());
        assert_eq!(a.len, b.len);
    }
    assert_eq!(shadow.pos, full.pos);
}

#[test]
fn reset_gives_fresh_generation() {
    let rt = runtime();
    let mut s = state(&rt, 21);
    let first = s.decode_step(9).unwrap();
    for _ in 0..3 {
        let _ = s.decode_step(0).unwrap();
    }
    s.reset();
    let again = s.decode_step(9).unwrap();
    assert_eq!(first.token_out, again.token_out);
    assert_eq!(first.routes, again.routes);
}

#[test]
fn prefill_activations_cover_most_experts() {
    // Paper §3.3 footnote: 16-token prompts activate ~7.6/8 experts per
    // layer; 128-token prompts activate ~8/8.
    let rt = runtime();
    let mut s = state(&rt, 23);
    let prompt: Vec<u32> = (0..128).map(|i| (i * 7 + 31) % 256).collect();
    let acts = s.prefill_activations(&prompt).unwrap();
    let cfg = ModelConfig::default();
    assert_eq!(acts.len(), cfg.n_layers);
    let avg: f64 = acts
        .iter()
        .map(|layer| layer.iter().filter(|&&b| b).count() as f64)
        .sum::<f64>()
        / acts.len() as f64;
    assert!(avg > 6.5, "long prompts should activate nearly all experts, got {avg}");
}

#[test]
fn batch_state_sessions_match_dedicated_states() {
    // Two sessions interleaved through ONE shared ModelState via KV swap
    // must generate exactly what two dedicated states would: batching is
    // a scheduling construct, never a numerics one.
    let rt = runtime();
    let pa: Vec<u32> = (0..16).map(|i| (i * 13 + 5) % 256).collect();
    let pb: Vec<u32> = (0..16).map(|i| (i * 29 + 3) % 256).collect();

    let mut shared = state(&rt, 42);
    let mut batch = BatchState::new();
    batch.join(&mut shared, 0, &pa, 5).unwrap();
    batch.join(&mut shared, 1, &pb, 5).unwrap();
    for _ in 0..4 {
        for i in [0usize, 1] {
            let token = batch.slot(i).next_token;
            batch.activate(i, &mut shared);
            let rec = shared.decode_step(token).unwrap();
            batch.deactivate(i, &mut shared);
            batch.record_token(i, rec.token_out);
        }
    }

    for (i, prompt) in [(0usize, &pa), (1usize, &pb)] {
        let mut solo = state(&rt, 42);
        let first = solo.prefill(prompt).unwrap();
        let mut tokens = vec![first.token_out];
        for _ in 0..4 {
            let rec = solo.decode_step(*tokens.last().unwrap()).unwrap();
            tokens.push(rec.token_out);
        }
        assert_eq!(batch.slot(i).tokens, tokens, "session {i} diverged");
        assert!(batch.slot(i).done());
    }
}
