//! Online SLO control loop (DESIGN.md §15): the deterministic decision
//! engine behind `--control reactive`.
//!
//! The PR 5 planner picks one static deployment offline; this module is
//! the closed loop that keeps an SLO alive when the workload drifts away
//! from what was planned — flash crowds, diurnal swings, rolling node
//! failures. Every `epoch_ms` of virtual time the serving event core
//! ([`crate::serve`]) hands the controller a rolling-window observation
//! (windowed p99 TTFT, queue depth, live replicas, busy fraction,
//! completions) and applies whatever [`Decision`] comes back:
//!
//! * **scale up / down** against the fleet budget (`min_replicas` ..=
//!   `max_replicas`),
//! * **tighten / relax admission** (cap in-flight sessions at the
//!   dispatch width under sustained pressure),
//! * **precision relief** — when the fleet budget is exhausted, shrink
//!   transfer time at a quality-debt cost (HOBBIT's runtime knob, the
//!   PR 9 mechanism),
//! * **popularity-driven expert replication** — fold cross-session
//!   expert-demand counts (the batched path's load-dedup tallies,
//!   [`crate::coordinator::replication::demand_from_routes`]) into a
//!   greedy demand-split [`Placement`] when demand skew crosses the
//!   threshold (SlimCaching's k-replication framing).
//!
//! Everything here is pure arithmetic over the observation — no clocks,
//! no randomness — so a run with the controller on is exactly as
//! deterministic as one without, and `od-moe bench` can tally the
//! decision grid as pinned integers (`control/*` in
//! `rust/benches/perf_baseline.json`, independently recomputed by
//! `rust/benches/baseline_mirror.py`). With `--control off` (the
//! default) the scheduler builds no controller at all — the PR 8/9
//! structural pin: off is the absence of the mechanism, byte-identical
//! in tokens AND timings.

use anyhow::{bail, ensure, Result};

use crate::cluster::Ms;
use crate::coordinator::replication::{place_replicated, place_single, Demand, Placement};

/// Controller knobs. Defaults match the `od-moe bench` decision grid and
/// the autoscale sweep; the CLI overrides epoch/target/budget
/// (`--control-epoch`, `--control-target-p99`, `--control-max-replicas`).
#[derive(Debug, Clone, PartialEq)]
pub struct ControlConfig {
    /// Virtual time between controller invocations.
    pub epoch_ms: Ms,
    /// The p99 TTFT the loop defends (arrival → first token).
    pub target_p99_ttft_ms: Ms,
    /// Fleet class budget: the replica count may move inside this band.
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Dispatch width of one replica (the scheduler's `max_batch`) —
    /// sizes the queue watermarks and the tightened admission cap.
    pub dispatch_width: usize,
    /// Rolling TTFT window the p99 is read from.
    pub window: usize,
    /// Virtual-time factor a precision-relief epoch applies to measured
    /// service (transfer downgrades shrink the expert-load share of
    /// service time; < 1.0). Quality debt is charged per token served
    /// under relief — the PR 9 honesty convention.
    pub relief_scale: f64,
    /// Expert demand skew (max/mean of per-expert counts) above which
    /// replication triggers.
    pub imbalance_threshold: f64,
    /// Worker group the replication placement spreads experts over.
    pub group_workers: usize,
    /// Memory bound of the greedy demand-split placement.
    pub max_replicas_per_expert: usize,
    /// Bytes one additional expert replica costs (reported, never
    /// hidden: `replication_bytes` in the autoscale artifact).
    pub expert_bytes: u64,
    /// Share of service time that is expert-load bound — what
    /// replication can actually speed up (the rest is compute/LAN).
    pub expert_load_share: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        Self {
            epoch_ms: 200.0,
            target_p99_ttft_ms: 300.0,
            min_replicas: 1,
            max_replicas: 8,
            dispatch_width: 4,
            window: 256,
            relief_scale: 0.85,
            imbalance_threshold: 1.5,
            group_workers: 4,
            max_replicas_per_expert: 2,
            expert_bytes: 500_000_000,
            expert_load_share: 0.5,
        }
    }
}

impl ControlConfig {
    /// Parse the `--control` mode: `off` (no controller at all — the
    /// structural pin) or `reactive` (defaults, tuned by the other
    /// flags).
    pub fn parse(mode: &str) -> Result<Option<Self>> {
        match mode {
            "off" => Ok(None),
            "reactive" => Ok(Some(Self::default())),
            other => bail!("unknown control mode {other:?} (off|reactive)"),
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.epoch_ms.is_finite() && self.epoch_ms > 0.0, "control epoch must be > 0 ms");
        ensure!(
            self.target_p99_ttft_ms.is_finite() && self.target_p99_ttft_ms > 0.0,
            "control target p99 must be > 0 ms"
        );
        ensure!(self.min_replicas >= 1, "need at least one replica");
        ensure!(
            self.max_replicas >= self.min_replicas,
            "replica budget {}..{} is empty",
            self.min_replicas,
            self.max_replicas
        );
        ensure!(self.dispatch_width >= 1, "need a positive dispatch width");
        ensure!(self.window >= 1, "need a positive window");
        ensure!(
            self.relief_scale > 0.0 && self.relief_scale <= 1.0,
            "relief scale must be in (0, 1]"
        );
        ensure!(self.imbalance_threshold >= 1.0, "imbalance threshold must be >= 1.0");
        ensure!(self.group_workers >= 1, "need at least one group worker");
        ensure!(self.max_replicas_per_expert >= 1, "need a positive replica bound");
        ensure!(
            self.expert_load_share >= 0.0 && self.expert_load_share <= 1.0,
            "expert-load share must be in [0, 1]"
        );
        Ok(())
    }
}

/// What the event core observed over the epoch that just ended.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochObservation {
    /// Windowed p99 of arrival → first-token latency (0 while the
    /// window is empty — treated as "no evidence", never as pressure).
    pub p99_ttft_ms: Ms,
    /// Waiting + admitted-but-not-running sessions at the epoch instant.
    pub queue_depth: usize,
    /// Replicas that are alive and accepting work.
    pub live_replicas: usize,
    /// Fraction of live replicas mid-batch at the epoch instant.
    pub busy_frac: f64,
    /// Sessions completed during the epoch.
    pub completed: u64,
}

/// One epoch's actuation, applied by the event core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Decision {
    /// +1 = add a replica, -1 = retire one, 0 = hold. Already clamped
    /// to the fleet budget.
    pub replica_delta: i32,
    /// Cap in-flight sessions at `live * dispatch_width` this epoch.
    pub tighten_admission: bool,
    /// Drop the cap (and any active relief) — the system is calm.
    pub relax: bool,
    /// Serve under the downgraded-transfer time scale this epoch
    /// (only decided when the replica budget is exhausted).
    pub precision_relief: bool,
}

impl Decision {
    /// Primary label for timelines and tables.
    pub fn label(&self) -> &'static str {
        if self.replica_delta > 0 {
            "scale-up"
        } else if self.replica_delta < 0 {
            "scale-down"
        } else if self.precision_relief {
            "relief"
        } else if self.relax {
            "relax"
        } else {
            "hold"
        }
    }
}

/// Hysteresis state between epochs. Scale-down and admission-tightening
/// both require *consecutive* evidence (two calm / two pressured epochs)
/// so one noisy window cannot flap the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ControlState {
    pub pressure_epochs: u32,
    pub calm_epochs: u32,
}

/// Classification of one observation — the stateless core of
/// [`ControlState::observe`], tallied by `od-moe bench` as the
/// `control/grid_*` pinned integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pressure {
    /// SLO in danger: windowed p99 beyond 1.25× target, or the queue
    /// beyond twice what the fleet can dispatch.
    Over,
    /// Comfortably idle: p99 under half target, queue under half a
    /// dispatch round, most replicas idle.
    Calm,
    /// Neither — hold everything.
    Neutral,
}

/// Stateless classify: the thresholds, in one place. All comparisons
/// are strict and the bench grid keeps every operand off its boundary,
/// so the pinned tallies are exact integers, not band-dependent.
pub fn classify(cfg: &ControlConfig, obs: &EpochObservation) -> Pressure {
    let cap = obs.live_replicas * cfg.dispatch_width;
    let over = obs.p99_ttft_ms > 1.25 * cfg.target_p99_ttft_ms || obs.queue_depth > 2 * cap;
    if over {
        return Pressure::Over;
    }
    let calm = obs.p99_ttft_ms < 0.5 * cfg.target_p99_ttft_ms
        && 2 * obs.queue_depth < cap
        && obs.busy_frac < 0.5;
    if calm {
        Pressure::Calm
    } else {
        Pressure::Neutral
    }
}

impl ControlState {
    /// One epoch step: classify the observation, update the hysteresis
    /// counters, and emit the actuation. Pure in (self, cfg, obs) —
    /// `od-moe bench` replays a scripted episode through this exact
    /// function and pins the resulting action counts.
    pub fn observe(&mut self, cfg: &ControlConfig, obs: &EpochObservation) -> Decision {
        let mut d = Decision::default();
        match classify(cfg, obs) {
            Pressure::Over => {
                self.pressure_epochs += 1;
                self.calm_epochs = 0;
                if obs.live_replicas < cfg.max_replicas {
                    d.replica_delta = 1;
                } else {
                    // Budget exhausted: trade quality for time instead.
                    d.precision_relief = true;
                }
                if self.pressure_epochs >= 2 {
                    d.tighten_admission = true;
                }
            }
            Pressure::Calm => {
                self.calm_epochs += 1;
                self.pressure_epochs = 0;
                d.relax = true;
                if self.calm_epochs >= 2 && obs.live_replicas > cfg.min_replicas {
                    d.replica_delta = -1;
                    self.calm_epochs = 0;
                }
            }
            Pressure::Neutral => {
                self.pressure_epochs = 0;
                self.calm_epochs = 0;
            }
        }
        d
    }
}

/// Replication verdict for one epoch's accumulated demand.
#[derive(Debug, Clone)]
pub struct ReplicationPlan {
    pub placement: Placement,
    /// Single-placement max load the placement is judged against.
    pub single_max_load: f64,
    /// Expert-replica slots beyond one-per-expert (the memory cost).
    pub extra_replicas: usize,
    /// Virtual-time factor on the expert-load share of service
    /// (`<= 1.0`): load shrinks by the max-load ratio on the
    /// `expert_load_share` fraction of service time.
    pub time_scale: f64,
}

/// Evaluate popularity-driven replication over accumulated per-expert
/// demand: returns a plan iff the single-placement skew crosses
/// `cfg.imbalance_threshold` AND the greedy demand-split placement
/// actually lowers the max per-worker load. Deterministic in the demand
/// vector alone.
pub fn plan_replication(cfg: &ControlConfig, demand: &Demand) -> Option<ReplicationPlan> {
    if demand.len() < 2 || demand.iter().all(|&d| d == 0) {
        return None;
    }
    let single = place_single(demand, cfg.group_workers);
    if single.imbalance() <= cfg.imbalance_threshold {
        return None;
    }
    let placement = place_replicated(demand, cfg.group_workers, cfg.max_replicas_per_expert);
    let (pre, post) = (single.max_load(), placement.max_load());
    if post >= pre {
        return None;
    }
    let share = cfg.expert_load_share;
    let time_scale = (1.0 - share) + share * (post / pre);
    Some(ReplicationPlan {
        single_max_load: pre,
        extra_replicas: placement.replica_count().saturating_sub(demand.len()),
        time_scale,
        placement,
    })
}

/// One row of the controller's per-epoch timeline — what
/// `BENCH_autoscale.json` records for the reactive cells.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochSnapshot {
    pub t_ms: Ms,
    pub p99_ttft_ms: Ms,
    pub queue_depth: usize,
    pub live_replicas: usize,
    pub completed: u64,
    pub action: &'static str,
}

/// Everything a controlled run did, costs included — honesty is the
/// point: replica-hours and replication bytes ride next to the latency
/// wins in the same artifact.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ControlReport {
    pub epochs: Vec<EpochSnapshot>,
    pub scale_ups: u32,
    pub scale_downs: u32,
    /// Epochs where relief transitioned on (budget-exhausted pressure).
    pub reliefs: u32,
    pub tightens: u32,
    pub replications: u32,
    /// Admitted-but-not-running sessions migrated off retiring replicas
    /// (ledger-correct requeues; running sessions always drain).
    pub migrated: u32,
    /// ∫ live replicas dt — the replica-hours cost of elasticity.
    pub replica_ms: f64,
    /// Bytes of additional expert replicas placed (memory cost).
    pub replication_bytes: u64,
    /// Tokens served under precision relief (the quality-debt proxy:
    /// each paid the downgraded-transfer error, per DESIGN.md §14).
    pub quality_debt_tokens: u64,
    pub peak_replicas: usize,
    pub final_replicas: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(p99: Ms, queue: usize, live: usize, busy: f64) -> EpochObservation {
        EpochObservation {
            p99_ttft_ms: p99,
            queue_depth: queue,
            live_replicas: live,
            busy_frac: busy,
            completed: 0,
        }
    }

    #[test]
    fn parse_rejects_unknown_modes_and_off_is_no_controller() {
        assert!(ControlConfig::parse("off").unwrap().is_none());
        assert!(ControlConfig::parse("reactive").unwrap().is_some());
        let err = ControlConfig::parse("pid").unwrap_err().to_string();
        assert!(err.contains("off|reactive"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_budgets() {
        let c = ControlConfig { min_replicas: 4, max_replicas: 2, ..ControlConfig::default() };
        assert!(c.validate().is_err());
        let mut c = ControlConfig { epoch_ms: 0.0, ..ControlConfig::default() };
        assert!(c.validate().is_err());
        c.epoch_ms = 100.0;
        c.relief_scale = 0.0;
        assert!(c.validate().is_err());
        assert!(ControlConfig::default().validate().is_ok());
    }

    #[test]
    fn pressure_scales_up_until_the_budget_then_degrades_precision() {
        let cfg = ControlConfig { max_replicas: 3, ..ControlConfig::default() };
        let mut st = ControlState::default();
        // p99 well past 1.25x target, fleet below budget: add a replica.
        let d = st.observe(&cfg, &obs(500.0, 0, 2, 0.9));
        assert_eq!(d.replica_delta, 1);
        assert!(!d.precision_relief);
        // At the budget the same pressure turns into precision relief.
        let d = st.observe(&cfg, &obs(500.0, 0, 3, 0.9));
        assert_eq!(d.replica_delta, 0);
        assert!(d.precision_relief);
        assert!(d.tighten_admission, "second consecutive pressured epoch tightens");
    }

    #[test]
    fn queue_blowup_alone_is_pressure() {
        // Empty TTFT window (p99 = 0) but a queue past 2x dispatch
        // capacity: still scale up — early flash crowds look exactly
        // like this before any first token lands.
        let cfg = ControlConfig::default();
        let mut st = ControlState::default();
        let cap = 2 * cfg.dispatch_width; // live = 2
        let d = st.observe(&cfg, &obs(0.0, 2 * cap + 1, 2, 1.0));
        assert_eq!(d.replica_delta, 1);
    }

    #[test]
    fn scale_down_needs_two_consecutive_calm_epochs() {
        let cfg = ControlConfig::default();
        let mut st = ControlState::default();
        let calm = obs(50.0, 0, 4, 0.2);
        let d1 = st.observe(&cfg, &calm);
        assert_eq!(d1.replica_delta, 0, "one calm epoch only relaxes");
        assert!(d1.relax);
        let d2 = st.observe(&cfg, &calm);
        assert_eq!(d2.replica_delta, -1);
        // The counter resets: the next calm epoch holds again.
        let d3 = st.observe(&cfg, &calm);
        assert_eq!(d3.replica_delta, 0);
    }

    #[test]
    fn scale_down_respects_the_floor_and_neutral_resets_hysteresis() {
        let cfg = ControlConfig::default();
        let mut st = ControlState::default();
        let floor = obs(50.0, 0, cfg.min_replicas, 0.2);
        st.observe(&cfg, &floor);
        let d = st.observe(&cfg, &floor);
        assert_eq!(d.replica_delta, 0, "never below min_replicas");
        // Calm, neutral, calm: no scale-down (evidence must be consecutive).
        let mut st = ControlState::default();
        st.observe(&cfg, &obs(50.0, 0, 4, 0.2));
        st.observe(&cfg, &obs(200.0, 0, 4, 0.7));
        let d = st.observe(&cfg, &obs(50.0, 0, 4, 0.2));
        assert_eq!(d.replica_delta, 0);
    }

    #[test]
    fn decision_labels_rank_scaling_over_relief() {
        assert_eq!(Decision { replica_delta: 1, ..Decision::default() }.label(), "scale-up");
        assert_eq!(Decision { replica_delta: -1, ..Decision::default() }.label(), "scale-down");
        assert_eq!(
            Decision { precision_relief: true, ..Decision::default() }.label(),
            "relief"
        );
        assert_eq!(Decision { relax: true, ..Decision::default() }.label(), "relax");
        assert_eq!(Decision::default().label(), "hold");
    }

    #[test]
    fn replication_triggers_only_on_skew_and_reports_costs() {
        let cfg = ControlConfig::default(); // 4 workers, <=2 replicas/expert
        // Uniform demand: no skew, no plan.
        assert!(plan_replication(&cfg, &vec![8, 8, 8, 8]).is_none());
        assert!(plan_replication(&cfg, &vec![0, 0, 0, 0]).is_none(), "no demand, no plan");
        assert!(plan_replication(&cfg, &vec![5]).is_none(), "one expert cannot rebalance");
        // One hot expert: single placement pins its whole demand on one
        // worker; the plan splits it and prices the extra replicas.
        let plan = plan_replication(&cfg, &vec![64, 2, 2, 2]).expect("skew crosses threshold");
        assert!(plan.placement.max_load() < plan.single_max_load);
        assert!(plan.extra_replicas >= 1);
        assert!(plan.time_scale < 1.0 && plan.time_scale > 0.0);
        // Scale only touches the expert-load share of service time.
        let ratio = plan.placement.max_load() / plan.single_max_load;
        let want = (1.0 - cfg.expert_load_share) + cfg.expert_load_share * ratio;
        assert!((plan.time_scale - want).abs() < 1e-12);
    }

    #[test]
    fn the_bench_grid_classification_is_the_pinned_tally() {
        // The exact grid `od-moe bench` tallies (and the Python mirror
        // recomputes): 6 p99 ratios x 5 queue depths x 3 busy fractions
        // at live=2, width=4, target=100. Keep in lockstep with
        // cli::bench and rust/benches/baseline_mirror.py.
        let cfg = ControlConfig {
            target_p99_ttft_ms: 100.0,
            dispatch_width: 4,
            ..ControlConfig::default()
        };
        let (mut over, mut calm, mut hold) = (0u64, 0u64, 0u64);
        for ratio in [0.4, 0.8, 1.1, 1.3, 1.6, 2.2] {
            for queue in [0usize, 2, 6, 12, 24] {
                for busy in [0.2, 0.55, 0.9] {
                    match classify(&cfg, &obs(100.0 * ratio, queue, 2, busy)) {
                        Pressure::Over => over += 1,
                        Pressure::Calm => calm += 1,
                        Pressure::Neutral => hold += 1,
                    }
                }
            }
        }
        assert_eq!((over, calm, hold), (54, 2, 34));
    }
}
