//! Host-side padded KV cache for one layer.
//!
//! The cache is a fixed-capacity `[max_seq, n_kv_heads, head_dim]` buffer;
//! the decode graph masks positions beyond the valid length. Rust owns the
//! buffer (it is what SEP's KV alignment copies between nodes) and uploads
//! it per decode call.

use crate::model::ModelConfig;

/// Fixed-capacity K/V buffers for one layer.
#[derive(Debug, Clone)]
pub struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    row: usize,
    /// Valid rows (tokens committed).
    pub len: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        let row = cfg.n_kv_heads * cfg.head_dim;
        Self {
            k: vec![0.0; cfg.max_seq_len * row],
            v: vec![0.0; cfg.max_seq_len * row],
            row,
            len: 0,
            max_seq: cfg.max_seq_len,
        }
    }

    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    pub fn reset(&mut self) {
        self.k.fill(0.0);
        self.v.fill(0.0);
        self.len = 0;
    }

    /// Commit the new token's K/V rows at position `pos`.
    pub fn commit(&mut self, pos: usize, k_new: &[f32], v_new: &[f32]) {
        assert!(pos < self.max_seq, "kv cache overflow at pos {pos}");
        assert_eq!(k_new.len(), self.row);
        assert_eq!(v_new.len(), self.row);
        self.k[pos * self.row..(pos + 1) * self.row].copy_from_slice(k_new);
        self.v[pos * self.row..(pos + 1) * self.row].copy_from_slice(v_new);
        self.len = self.len.max(pos + 1);
    }

    /// Commit `count` rows starting at `start` (prefill path).
    pub fn commit_block(&mut self, start: usize, count: usize, k_all: &[f32], v_all: &[f32]) {
        assert!(start + count <= self.max_seq);
        assert_eq!(k_all.len(), count * self.row);
        let dst = start * self.row..(start + count) * self.row;
        self.k[dst.clone()].copy_from_slice(k_all);
        self.v[dst].copy_from_slice(v_all);
        self.len = self.len.max(start + count);
    }

    /// Full-state copy (SEP KV alignment: shadow <- main).
    pub fn copy_from(&mut self, other: &KvCache) {
        debug_assert_eq!(self.row, other.row);
        self.k.copy_from_slice(&other.k);
        self.v.copy_from_slice(&other.v);
        self.len = other.len;
    }

    /// Bytes a full-cache alignment transfer would ship for `tokens` rows
    /// of one layer (2 tensors * row floats * 4 bytes).
    pub fn align_bytes_per_token(&self) -> usize {
        2 * self.row * 4
    }
}

/// KV bytes one session of `tokens` occupies across *all* layers at model
/// scale (2 tensors x `kv_dim` floats x 4 B per token per layer) — the
/// per-session unit of the serving layer's admission ledger. The
/// paper-scale equivalent is
/// [`crate::cluster::HardwareProfile::kv_align_bytes`] per token, which
/// [`crate::serve::MemoryModel::from_profile`] uses.
pub fn session_kv_bytes(cfg: &ModelConfig, tokens: usize) -> u64 {
    (2 * cfg.kv_dim() * 4 * cfg.n_layers * tokens) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn cache() -> KvCache {
        KvCache::new(&ModelConfig::default())
    }

    #[test]
    fn commit_and_read_back() {
        let mut c = cache();
        let row: Vec<f32> = (0..32).map(|i| i as f32).collect();
        c.commit(0, &row, &row);
        assert_eq!(c.len, 1);
        assert_eq!(&c.k()[..32], row.as_slice());
        assert_eq!(c.k()[32], 0.0);
    }

    #[test]
    fn commit_block_matches_sequential_commits() {
        let mut a = cache();
        let mut b = cache();
        let rows: Vec<f32> = (0..4 * 32).map(|i| i as f32 * 0.5).collect();
        for t in 0..4 {
            a.commit(t, &rows[t * 32..(t + 1) * 32], &rows[t * 32..(t + 1) * 32]);
        }
        b.commit_block(0, 4, &rows, &rows);
        assert_eq!(a.k(), b.k());
        assert_eq!(a.len, b.len);
    }

    #[test]
    fn copy_from_replicates_state() {
        let mut a = cache();
        let row = vec![1.5f32; 32];
        a.commit(0, &row, &row);
        a.commit(1, &row, &row);
        let mut b = cache();
        b.copy_from(&a);
        assert_eq!(b.len, 2);
        assert_eq!(a.k(), b.k());
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn overflow_panics() {
        let mut c = cache();
        let row = vec![0f32; 32];
        c.commit(512, &row, &row);
    }

    #[test]
    fn align_bytes_matches_paper_formula_scaled() {
        // Paper: 8 KB per token per layer at Mixtral scale (2 * 8 heads *
        // 128 dim * 4 B = 8 KiB). Tiny-Mixtral: 2 * 2 * 16 * 4 = 256 B.
        assert_eq!(cache().align_bytes_per_token(), 256);
    }

    #[test]
    fn session_bytes_consistent_with_per_layer_cache() {
        let cfg = ModelConfig::default();
        // Per-layer per-token bytes x layers x tokens.
        let per_layer = cache().align_bytes_per_token() as u64;
        assert_eq!(session_kv_bytes(&cfg, 1), per_layer * cfg.n_layers as u64);
        assert_eq!(session_kv_bytes(&cfg, 144), per_layer * cfg.n_layers as u64 * 144);
        assert_eq!(session_kv_bytes(&cfg, 0), 0);
    }
}
