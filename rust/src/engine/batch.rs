//! Per-session numerics state for multi-session batched decode.
//!
//! [`BatchState`] lets one [`ModelState`] (one weight upload, one device
//! model) serve N concurrent sessions with *per-session exact* numerics:
//! each session owns its KV caches and position, and is swapped into the
//! shared model for exactly one decode step at a time. A session's token
//! stream is therefore bit-identical to what a dedicated `ModelState`
//! decoding it alone would produce — batching changes *when* tokens are
//! produced (virtual time, booked by the engines), never *which* tokens.
//!
//! This module is pure numerics. The batched virtual-time accounting —
//! route merging, expert-load deduplication, batch-efficiency factors —
//! lives with the engines in [`crate::coordinator::batch`] and its
//! implementations.

use anyhow::Result;

use super::{KvCache, ModelState, StepRecord};

/// One session's private decode state within a batch.
#[derive(Debug)]
pub struct BatchSlot {
    /// Caller-chosen session index (position in the batch request list).
    pub id: usize,
    /// This session's KV caches (one per layer), swapped into the shared
    /// model for the duration of one decode step.
    caches: Vec<KvCache>,
    /// Tokens consumed so far (the session's `ModelState::pos`).
    pos: usize,
    /// Input token for the session's next decode step.
    pub next_token: u32,
    /// All generated tokens (the first one produced by prefill).
    pub tokens: Vec<u32>,
    /// Total tokens requested (including the prefill token).
    pub target: usize,
}

impl BatchSlot {
    /// Has this session generated all requested tokens?
    pub fn done(&self) -> bool {
        self.tokens.len() >= self.target
    }
}

/// Per-session KV/position bookkeeping for batched decode over one shared
/// [`ModelState`].
///
/// Usage: [`BatchState::join`] prefills each session and captures its
/// state; each decode iteration then brackets every active session's
/// [`ModelState::decode_step`] with [`BatchState::activate`] /
/// [`BatchState::deactivate`] and records the output via
/// [`BatchState::record_token`].
#[derive(Debug, Default)]
pub struct BatchState {
    slots: Vec<BatchSlot>,
}

impl BatchState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn slot(&self, i: usize) -> &BatchSlot {
        &self.slots[i]
    }

    /// Prefill `prompt` on `model` (resetting it first) and capture the
    /// resulting KV state as a new session slot generating `target` tokens
    /// in total. Returns the prefill step record (first token + routes).
    pub fn join(
        &mut self,
        model: &mut ModelState,
        id: usize,
        prompt: &[u32],
        target: usize,
    ) -> Result<StepRecord> {
        anyhow::ensure!(target >= 1, "session needs at least one output token");
        model.reset();
        let rec = model.prefill(prompt)?;
        self.slots.push(BatchSlot {
            id,
            caches: model.caches.clone(),
            pos: model.pos,
            next_token: rec.token_out,
            tokens: vec![rec.token_out],
            target,
        });
        Ok(rec)
    }

    /// Swap session `i`'s KV caches and position into the shared model.
    /// The model's previous contents are parked in the slot until
    /// [`BatchState::deactivate`] restores them; every activate must be
    /// paired with a deactivate before the next session runs.
    pub fn activate(&mut self, i: usize, model: &mut ModelState) {
        let slot = &mut self.slots[i];
        std::mem::swap(&mut slot.caches, &mut model.caches);
        std::mem::swap(&mut slot.pos, &mut model.pos);
    }

    /// Capture the model's (advanced) KV state back into slot `i`.
    pub fn deactivate(&mut self, i: usize, model: &mut ModelState) {
        let slot = &mut self.slots[i];
        std::mem::swap(&mut slot.caches, &mut model.caches);
        std::mem::swap(&mut slot.pos, &mut model.pos);
    }

    /// Record the token produced for session `i` this iteration; it
    /// becomes the session's next decode input.
    pub fn record_token(&mut self, i: usize, token: u32) {
        let slot = &mut self.slots[i];
        slot.next_token = token;
        slot.tokens.push(token);
    }

    /// Indices of sessions that still owe tokens, in slot order.
    pub fn active(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| !self.slots[i].done()).collect()
    }
}
