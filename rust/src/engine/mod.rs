//! Full-model forward over the PJRT runtime: KV caches, decode steps,
//! prefill, greedy generation.
//!
//! [`ModelState`] is the numerics workhorse shared by every node role and
//! engine: the full-precision main model, the quantized SEP shadow model,
//! and all baseline engines drive one of these each. Virtual-time cost
//! accounting lives elsewhere (`cluster`); this module is purely about
//! getting the right numbers out of the AOT artifacts.

pub mod batch;
pub mod kv;

use anyhow::Result;

use crate::model::{ModelConfig, WeightStore};
use crate::runtime::{DeviceModel, Runtime, EXPERT_FFN_SIZES, PREFILL_SIZES};

pub use batch::{BatchSlot, BatchState};
pub use kv::KvCache;

/// Per-layer routing decision for one token.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    /// Selected expert ids, descending router weight (`top_k` of them).
    pub experts: Vec<usize>,
    /// Softmax weights over the selection (same order).
    pub weights: Vec<f32>,
}

/// Everything observed while decoding one token.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub token_in: u32,
    pub token_out: u32,
    /// Routing per layer (`n_layers` entries).
    pub routes: Vec<Route>,
    /// LM-head logits (`vocab_size`).
    pub logits: Vec<f32>,
}

/// Hook controlling how the expert MLPs of one layer are executed.
///
/// Arguments: `(layer, route, x_resid[1,d], h_norm[1,d])`; returns the
/// *combined* expert contribution `[1, d]` to add to the residual stream.
/// Engines override this to skip experts (AdapMoE), run quantized tiers
/// (HOBBIT), or pull weights from a different store; `x_resid` also feeds
/// their lookahead predictors.
pub type ExpertExec<'a> = dyn FnMut(usize, &Route, &[f32], &[f32]) -> Result<Vec<f32>> + 'a;

/// Host-side state of one model replica (weights + KV caches + position).
pub struct ModelState<'rt> {
    pub rt: &'rt Runtime,
    pub ws: WeightStore,
    dm: DeviceModel,
    pub caches: Vec<KvCache>,
    /// Tokens consumed so far (== valid KV length).
    pub pos: usize,
}

impl<'rt> ModelState<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore) -> Result<Self> {
        let dm = DeviceModel::upload(rt, &ws)?;
        let caches = (0..ws.cfg.n_layers).map(|_| KvCache::new(&ws.cfg)).collect();
        Ok(Self { rt, ws, dm, caches, pos: 0 })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.ws.cfg
    }

    /// Clear caches and position for a fresh request.
    pub fn reset(&mut self) {
        for c in &mut self.caches {
            c.reset();
        }
        self.pos = 0;
    }

    /// Default expert execution: run all selected experts from own weights
    /// and combine with router weights.
    pub fn run_experts(&self, layer: usize, route: &Route, h: &[f32]) -> Result<Vec<f32>> {
        self.run_experts_except(layer, route, h, None)
    }

    /// [`Self::run_experts`] minus the route position `skip` (`None`
    /// runs all): the skipped expert's weighted contribution is simply
    /// omitted from the combine — an *honest* quality cost, since the
    /// residual stream really loses that term. The route itself is
    /// untouched; step records keep the router's full selection as
    /// ground truth.
    pub fn run_experts_except(
        &self,
        layer: usize,
        route: &Route,
        h: &[f32],
        skip: Option<usize>,
    ) -> Result<Vec<f32>> {
        let d = self.cfg().d_model;
        let mut acc = vec![0f32; d];
        for (i, &e) in route.experts.iter().enumerate() {
            if Some(i) == skip {
                continue;
            }
            let y = self.rt.expert_ffn(&self.dm, layer, e, h, 1)?;
            let w = route.weights[i];
            for j in 0..d {
                acc[j] += w * y[j];
            }
        }
        Ok(acc)
    }

    /// Decode one token with the default expert execution.
    pub fn decode_step(&mut self, token: u32) -> Result<StepRecord> {
        self.decode_inner(token, None, None)
    }

    /// Decode one token, delegating expert execution to `exec`.
    pub fn decode_step_with(&mut self, token: u32, exec: &mut ExpertExec) -> Result<StepRecord> {
        self.decode_inner(token, Some(exec), None)
    }

    /// Decode one token with the default expert execution, letting
    /// `decide` drop at most one routed expert per layer: called with
    /// each layer's route, it returns the route *position* to skip (or
    /// `None` to run all). Used by the runtime precision controller's
    /// deadline skip rule (DESIGN.md §14); a decider that always returns
    /// `None` is bit-identical to [`Self::decode_step`].
    pub fn decode_step_skipping(
        &mut self,
        token: u32,
        decide: &mut dyn FnMut(usize, &Route) -> Option<usize>,
    ) -> Result<StepRecord> {
        self.decode_inner(token, None, Some(decide))
    }

    fn decode_inner(
        &mut self,
        token: u32,
        mut exec: Option<&mut ExpertExec>,
        mut skip: Option<&mut dyn FnMut(usize, &Route) -> Option<usize>>,
    ) -> Result<StepRecord> {
        let cfg = self.cfg().clone();
        anyhow::ensure!(self.pos < cfg.max_seq_len, "KV cache full");
        let mut x = self.ws.embed(token).to_vec();
        let mut routes = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let out = self.rt.main_block_decode(
                &self.dm,
                l,
                &x,
                self.caches[l].k(),
                self.caches[l].v(),
                self.pos,
            )?;
            self.caches[l].commit(self.pos, &out.k_new, &out.v_new);
            let route = Route {
                experts: out.route_idx.iter().map(|&i| i as usize).collect(),
                weights: out.route_w.clone(),
            };
            let contrib = match exec.as_mut() {
                Some(f) => f(l, &route, &out.x_resid, &out.h_norm)?,
                None => {
                    let s = skip.as_mut().and_then(|d| d(l, &route));
                    self.run_experts_except(l, &route, &out.h_norm, s)?
                }
            };
            x = out.x_resid;
            for j in 0..cfg.d_model {
                x[j] += contrib[j];
            }
            routes.push(route);
        }
        let (logits, token_out) = self.rt.lm_head(&self.dm, &x)?;
        self.pos += 1;
        Ok(StepRecord { token_in: token, token_out, routes, logits })
    }

    /// Batched prefill over the whole prompt. Returns per-token records
    /// (logits only for the last token) — mirrors the paper's §3.3 batched
    /// prefill where all experts are exercised in grouped matmuls.
    pub fn prefill(&mut self, prompt: &[u32]) -> Result<StepRecord> {
        let cfg = self.cfg().clone();
        let t = prompt.len();
        anyhow::ensure!(
            PREFILL_SIZES.contains(&t),
            "no prefill executable for prompt length {t} (have {PREFILL_SIZES:?})"
        );
        anyhow::ensure!(self.pos == 0, "prefill requires a fresh state");
        let d = cfg.d_model;
        let mut x: Vec<f32> = Vec::with_capacity(t * d);
        for &tok in prompt {
            x.extend_from_slice(self.ws.embed(tok));
        }
        let mut last_routes = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let out = self.rt.main_block_prefill(&self.dm, l, &x, t)?;
            self.caches[l].commit_block(0, t, &out.k_all, &out.v_all);
            // Group tokens by expert and run batched expert FFNs (padded to
            // the nearest specialized size).
            let mut groups: Vec<Vec<usize>> = vec![Vec::new(); cfg.n_experts];
            for tok in 0..t {
                for k in 0..cfg.top_k {
                    groups[out.route_idx[tok * cfg.top_k + k] as usize].push(tok);
                }
            }
            let mut xnew = out.x_resid.clone();
            for (e, toks) in groups.iter().enumerate() {
                if toks.is_empty() {
                    continue;
                }
                let bt = padded_batch(toks.len());
                let mut h = vec![0f32; bt * d];
                for (row, &tok) in toks.iter().enumerate() {
                    h[row * d..(row + 1) * d]
                        .copy_from_slice(&out.h_norm[tok * d..(tok + 1) * d]);
                }
                let y = self.rt.expert_ffn(&self.dm, l, e, &h, bt)?;
                for (row, &tok) in toks.iter().enumerate() {
                    // Router weight of expert e for this token.
                    let mut w = 0f32;
                    for k in 0..cfg.top_k {
                        if out.route_idx[tok * cfg.top_k + k] as usize == e {
                            w = out.route_w[tok * cfg.top_k + k];
                        }
                    }
                    for j in 0..d {
                        xnew[tok * d + j] += w * y[row * d + j];
                    }
                }
            }
            x = xnew;
            // Keep the last token's route for reporting.
            let tok = t - 1;
            last_routes.push(Route {
                experts: (0..cfg.top_k)
                    .map(|k| out.route_idx[tok * cfg.top_k + k] as usize)
                    .collect(),
                weights: (0..cfg.top_k)
                    .map(|k| out.route_w[tok * cfg.top_k + k])
                    .collect(),
            });
        }
        let last = &x[(t - 1) * d..t * d];
        let (logits, token_out) = self.rt.lm_head(&self.dm, last)?;
        self.pos = t;
        Ok(StepRecord { token_in: *prompt.last().unwrap(), token_out, routes: last_routes, logits })
    }

    /// Per-layer expert-activation sets across ALL prompt tokens during
    /// prefill (for the §3.3 activation-count claim / bench).
    pub fn prefill_activations(&mut self, prompt: &[u32]) -> Result<Vec<Vec<bool>>> {
        let cfg = self.cfg().clone();
        let t = prompt.len();
        anyhow::ensure!(PREFILL_SIZES.contains(&t) && self.pos == 0);
        let d = cfg.d_model;
        let mut x: Vec<f32> = Vec::with_capacity(t * d);
        for &tok in prompt {
            x.extend_from_slice(self.ws.embed(tok));
        }
        let mut activations = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let out = self.rt.main_block_prefill(&self.dm, l, &x, t)?;
            let mut act = vec![false; cfg.n_experts];
            for v in &out.route_idx {
                act[*v as usize] = true;
            }
            activations.push(act);
            // Continue the residual stream exactly as prefill() does.
            self.caches[l].commit_block(0, t, &out.k_all, &out.v_all);
            let mut xnew = out.x_resid.clone();
            for tok in 0..t {
                for k in 0..cfg.top_k {
                    let e = out.route_idx[tok * cfg.top_k + k] as usize;
                    let w = out.route_w[tok * cfg.top_k + k];
                    let h = &out.h_norm[tok * d..(tok + 1) * d];
                    let mut hp = vec![0f32; d];
                    hp.copy_from_slice(h);
                    let y = self.rt.expert_ffn(&self.dm, l, e, &hp, 1)?;
                    for j in 0..d {
                        xnew[tok * d + j] += w * y[j];
                    }
                }
            }
            x = xnew;
        }
        self.reset();
        Ok(activations)
    }

    /// Overwrite this model's KV caches with `other`'s (SEP KV alignment).
    pub fn align_kv_from(&mut self, other: &ModelState) {
        for (mine, theirs) in self.caches.iter_mut().zip(&other.caches) {
            mine.copy_from(theirs);
        }
        self.pos = other.pos;
    }
}

/// Smallest specialized expert-FFN batch size >= n (capped at the largest).
pub fn padded_batch(n: usize) -> usize {
    for &s in &EXPERT_FFN_SIZES {
        if s >= n {
            return s;
        }
    }
    *EXPERT_FFN_SIZES.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_batch_picks_next_size() {
        assert_eq!(padded_batch(1), 1);
        assert_eq!(padded_batch(3), 4);
        assert_eq!(padded_batch(9), 16);
        assert_eq!(padded_batch(128), 128);
        assert_eq!(padded_batch(129), 128); // capped; callers chunk
    }
}
