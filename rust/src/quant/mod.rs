//! Quantization substrate — mirrors `python/compile/kernels/ref.py` exactly
//! (same NF4 codebook, same per-row INT8 absmax scheme, same tie-breaking),
//! so shadow weights built here are bit-identical in behaviour to the
//! quantized kernels validated in the Python test suite.
//!
//! The shadow model consumes *fake-quantized* (quantize→dequantize) f32
//! weights: numerically identical to running the dequant-fused kernels on
//! compressed weights, while letting one f32 HLO artifact serve every
//! precision level (DESIGN.md §3).

/// The 16 NF4 levels (QLoRA): quantiles of N(0,1) normalized to [-1, 1].
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.696_192_8,
    -0.525_073_05,
    -0.394_917_5,
    -0.284_441_38,
    -0.184_773_43,
    -0.091_050_036,
    0.0,
    0.079_580_3,
    0.160_930_2,
    0.246_112_3,
    0.337_915_24,
    0.440_709_83,
    0.562_617,
    0.722_956_84,
    1.0,
];

/// NF4 block size (flattened row-major blocks), matching the Python oracle.
pub const NF4_BLOCK: usize = 64;

/// Precision levels the paper evaluates for the shadow model (plus FP32 for
/// the full-precision path and the baselines' quantized expert tiers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
    Nf4,
}

impl Precision {
    /// Bytes per parameter when stored/transferred at this precision,
    /// for weight rows of `row_len` elements.
    ///
    /// Scale metadata follows the actual quantization schemes in this
    /// module: INT8 keeps ONE f32 absmax per *row* (see
    /// [`fake_quant_int8`]), so its overhead is `4 / row_len` and
    /// depends on the matrix shape; NF4 keeps one f32 scale per
    /// [`NF4_BLOCK`]-element block regardless of row length (see
    /// [`fake_quant_nf4`]). (The old formula amortized the INT8 scale
    /// per `NF4_BLOCK` elements — the NF4 constant — contradicting the
    /// documented per-row scheme; a 4096-wide row really costs
    /// ~1.001 B/param, not 1.0625.)
    pub fn bytes_per_param(self, row_len: usize) -> f64 {
        assert!(row_len > 0, "a weight row has at least one element");
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::Int8 => 1.0 + 4.0 / row_len as f64, // one f32 absmax per row
            Precision::Nf4 => 0.5 + 4.0 / NF4_BLOCK as f64, // one f32 scale per block
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
            Precision::Nf4 => "nf4",
        }
    }

    /// Parse a `fp32|fp16|int8|nf4` CLI token.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "fp32" => Precision::Fp32,
            "fp16" => Precision::Fp16,
            "int8" => Precision::Int8,
            "nf4" => Precision::Nf4,
            other => anyhow::bail!("unknown precision {other:?} (fp32|fp16|int8|nf4)"),
        })
    }

    /// In-flight expert-transfer size at this precision, as a fraction of
    /// the FP16 transfer (`HardwareProfile::expert_bytes` is calibrated
    /// as the FP16-plus-framing payload, so FP16 is the unit). Evaluated
    /// at the paper's 4096-wide expert rows ([`PAPER_EXPERT_ROW`]).
    /// Numerics in this repo stay FP32 — in-flight precision is a
    /// bandwidth property (EXPERIMENTS.md §Calibration), which is
    /// exactly what makes it a *deployment knob* the fleet planner can
    /// search over (HOBBIT, arXiv 2411.01433).
    pub fn transfer_factor(self) -> f64 {
        self.bytes_per_param(PAPER_EXPERT_ROW) / Precision::Fp16.bytes_per_param(PAPER_EXPERT_ROW)
    }

    /// Modeled per-element relative reconstruction error of streaming an
    /// expert at this precision, as a fraction of the weight scale —
    /// the quality cost a runtime transfer downgrade accrues
    /// (`coordinator::precision`, DESIGN.md §14). FP16 is 0.0 by
    /// definition: `expert_bytes` is calibrated as the FP16 payload, so
    /// fp16 *is* the deployed full-fidelity stream. The INT8/NF4 values
    /// are round(½·quantization-step) for unit-scale weights — half the
    /// 2/254 INT8 step and half the widest (~0.3039·absmax) NF4
    /// inter-level gap weighted by occupancy — and preserve the measured
    /// ordering in [`fake_quant`]'s error-bound tests: fp16 < int8 < nf4.
    pub fn rel_error(self) -> f64 {
        match self {
            Precision::Fp32 | Precision::Fp16 => 0.0,
            Precision::Int8 => 0.008,
            Precision::Nf4 => 0.03,
        }
    }
}

/// Mixtral-8x7B expert weight-row width (the `w1/w3` trailing dim), the
/// row length [`Precision::transfer_factor`] amortizes scales over.
pub const PAPER_EXPERT_ROW: usize = 4096;

/// f32 -> f16 -> f32 round trip (IEEE 754 binary16, round-to-nearest-even).
pub fn fake_quant_fp16(w: &[f32]) -> Vec<f32> {
    w.iter().map(|&x| f16_to_f32(f32_to_f16(x))).collect()
}

/// Per-row symmetric absmax INT8 quantize→dequantize. `cols` is the row
/// length; `w.len()` must be a multiple of it.
pub fn fake_quant_int8(w: &[f32], cols: usize) -> Vec<f32> {
    assert_eq!(w.len() % cols, 0, "int8: len not a multiple of cols");
    let mut out = Vec::with_capacity(w.len());
    for row in w.chunks(cols) {
        let absmax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        for &v in row {
            let q = (v / scale).round().clamp(-127.0, 127.0);
            out.push(q * scale);
        }
    }
    out
}

/// Blockwise NF4 quantize→dequantize over the row-major flattening.
pub fn fake_quant_nf4(w: &[f32]) -> Vec<f32> {
    assert_eq!(w.len() % NF4_BLOCK, 0, "nf4: len not a multiple of block");
    let mut out = Vec::with_capacity(w.len());
    for block in w.chunks(NF4_BLOCK) {
        let absmax = block.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax } else { 1.0 };
        for &v in block {
            let code = nearest_nf4(v / scale);
            out.push(NF4_LEVELS[code] * scale);
        }
    }
    out
}

/// Index of the nearest NF4 level (ties toward the lower index, matching
/// `jnp.argmin` in the Python oracle).
pub fn nearest_nf4(x: f32) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for (i, &l) in NF4_LEVELS.iter().enumerate() {
        let d = (x - l).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// Quantize→dequantize a weight matrix at the given precision.
/// `cols` is the trailing dimension (INT8 scales are per leading row;
/// 1-D tensors pass `cols = len`, matching `ref.fake_quant`).
pub fn fake_quant(w: &[f32], cols: usize, p: Precision) -> Vec<f32> {
    match p {
        Precision::Fp32 => w.to_vec(),
        Precision::Fp16 => fake_quant_fp16(w),
        Precision::Int8 => fake_quant_int8(w, cols),
        Precision::Nf4 => fake_quant_nf4(w),
    }
}

// --- IEEE binary16 conversion (no `half` crate: keeps the dep tree lean) ---

/// f32 -> f16 bits with round-to-nearest-even.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xFF) as i32;
    let mut frac = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf / NaN.
        return sign | 0x7C00 | if frac != 0 { 0x200 } else { 0 };
    }
    exp -= 127 - 15;
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if exp <= 0 {
        // Subnormal (or underflow to zero).
        if exp < -10 {
            return sign;
        }
        frac |= 0x80_0000; // implicit leading 1
        let shift = (14 - exp) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let rounded = frac + half_ulp - 1 + ((frac >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // Normal: round mantissa 23 -> 10 bits, nearest-even.
    let half_ulp = 0x0FFF + ((frac >> 13) & 1);
    frac += half_ulp;
    if frac & 0x80_0000 != 0 {
        frac = 0;
        exp += 1;
        if exp >= 0x1F {
            return sign | 0x7C00;
        }
    }
    sign | ((exp as u16) << 10) | (frac >> 13) as u16
}

/// f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = (h & 0x8000) as u32;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 16
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 16) | (((127 - 15 + e + 1) as u32) << 23) | ((f & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        (sign << 16) | 0x7F80_0000 | (frac << 13)
    } else {
        (sign << 16) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_roundtrip_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "value {v}");
        }
    }

    #[test]
    fn fp16_error_bound() {
        let mut rng = crate::model::rng::Rng::new(1);
        for _ in 0..1000 {
            let v = rng.normal() as f32;
            let back = f16_to_f32(f32_to_f16(v));
            // Relative error bounded by 2^-11 for normal range.
            assert!((back - v).abs() <= v.abs() * 4.9e-4 + 1e-7, "{v} -> {back}");
        }
    }

    #[test]
    fn fp16_overflow_to_inf_and_nan() {
        assert!(f16_to_f32(f32_to_f16(1e6)).is_infinite());
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1e-12)), 0.0); // underflow
    }

    #[test]
    fn int8_error_bound() {
        let mut rng = crate::model::rng::Rng::new(2);
        let w = rng.normal_vec(64 * 8, 1.0);
        let back = fake_quant_int8(&w, 64);
        for row in 0..8 {
            let r = &w[row * 64..(row + 1) * 64];
            let absmax = r.iter().fold(0f32, |m, &v| m.max(v.abs()));
            let step = absmax / 127.0;
            for i in 0..64 {
                assert!((back[row * 64 + i] - r[i]).abs() <= step * 0.5 + 1e-7);
            }
        }
    }

    #[test]
    fn int8_preserves_zero_rows() {
        let w = vec![0f32; 128];
        assert_eq!(fake_quant_int8(&w, 64), w);
    }

    #[test]
    fn nf4_error_bound() {
        let mut rng = crate::model::rng::Rng::new(3);
        let w = rng.normal_vec(NF4_BLOCK * 16, 1.0);
        let back = fake_quant_nf4(&w);
        for b in 0..16 {
            let blk = &w[b * NF4_BLOCK..(b + 1) * NF4_BLOCK];
            let absmax = blk.iter().fold(0f32, |m, &v| m.max(v.abs()));
            for i in 0..NF4_BLOCK {
                // Largest inter-level gap is ~0.3039 absmax; error <= half of it.
                assert!((back[b * NF4_BLOCK + i] - blk[i]).abs() <= 0.16 * absmax + 1e-7);
            }
        }
    }

    #[test]
    fn nf4_idempotent() {
        let mut rng = crate::model::rng::Rng::new(4);
        let w = rng.normal_vec(NF4_BLOCK * 4, 0.3);
        let once = fake_quant_nf4(&w);
        let twice = fake_quant_nf4(&once);
        assert_eq!(once, twice);
    }

    #[test]
    fn error_ordering_fp16_int8_nf4() {
        // Same invariant as python test_fake_quant_modes.
        let mut rng = crate::model::rng::Rng::new(5);
        let w = rng.normal_vec(64 * 64, 1.0);
        let err = |back: &[f32]| -> f32 {
            back.iter().zip(&w).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
        };
        let e16 = err(&fake_quant_fp16(&w));
        let e8 = err(&fake_quant_int8(&w, 64));
        let e4 = err(&fake_quant_nf4(&w));
        assert!(e16 < e8 && e8 < e4, "fp16={e16} int8={e8} nf4={e4}");
    }

    #[test]
    fn nearest_nf4_endpoints_and_zero() {
        assert_eq!(nearest_nf4(-2.0), 0);
        assert_eq!(nearest_nf4(2.0), 15);
        assert_eq!(nearest_nf4(0.0), 7);
    }

    #[test]
    fn bytes_per_param_ordering() {
        // Any row length wide enough for INT8's per-row scale to beat
        // FP16 (row_len > 4) preserves the precision ordering.
        for row_len in [8usize, 64, 4096] {
            assert!(
                Precision::Fp32.bytes_per_param(row_len)
                    > Precision::Fp16.bytes_per_param(row_len)
            );
            assert!(
                Precision::Fp16.bytes_per_param(row_len)
                    > Precision::Int8.bytes_per_param(row_len)
            );
            assert!(
                Precision::Int8.bytes_per_param(row_len)
                    > Precision::Nf4.bytes_per_param(row_len)
            );
        }
    }

    #[test]
    fn transfer_factor_is_unit_at_fp16_and_ordered() {
        assert_eq!(Precision::Fp16.transfer_factor(), 1.0);
        assert!((Precision::Fp32.transfer_factor() - 2.0).abs() < 1e-2);
        let int8 = Precision::Int8.transfer_factor();
        let nf4 = Precision::Nf4.transfer_factor();
        assert!((int8 - 0.5).abs() < 1e-2, "int8 halves the stream: {int8}");
        assert!((nf4 - 0.28).abs() < 1e-2, "nf4 is ~0.28 of fp16: {nf4}");
        assert!(nf4 < int8 && int8 < 1.0);
    }

    #[test]
    fn precision_parse_round_trips() {
        for p in [Precision::Fp32, Precision::Fp16, Precision::Int8, Precision::Nf4] {
            assert_eq!(Precision::parse(p.label()).unwrap(), p);
        }
        assert!(Precision::parse("fp8").is_err());
    }

    #[test]
    fn precision_parse_error_lists_every_valid_name() {
        let err = Precision::parse("bf16").unwrap_err().to_string();
        for name in ["fp32", "fp16", "int8", "nf4"] {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
        assert!(err.contains("bf16"), "error must echo the rejected token: {err}");
    }

    #[test]
    fn bytes_per_param_property_monotone_for_random_row_lens() {
        // Property sweep: for ANY row length past the degenerate
        // crossover (row_len > 4, where INT8's one-f32-per-row overhead
        // exceeds the fp16 payload itself), bytes/param is strictly
        // monotone in precision width. Row lengths are drawn from the
        // repo's deterministic RNG, spanning 5..~8k.
        let mut rng = crate::model::rng::Rng::new(9);
        let mut lens: Vec<usize> = (0..50).map(|_| 5 + (rng.normal().abs() * 2000.0) as usize).collect();
        lens.extend([5usize, 6, 64, 1024, PAPER_EXPERT_ROW, 8192]);
        for row_len in lens {
            let widths = [
                Precision::Fp32.bytes_per_param(row_len),
                Precision::Fp16.bytes_per_param(row_len),
                Precision::Int8.bytes_per_param(row_len),
                Precision::Nf4.bytes_per_param(row_len),
            ];
            for pair in widths.windows(2) {
                assert!(pair[0] > pair[1], "row_len {row_len}: {widths:?} not monotone");
            }
            // INT8's per-row scale is accounted exactly, for every row_len.
            assert_eq!(
                Precision::Int8.bytes_per_param(row_len),
                1.0 + 4.0 / row_len as f64,
                "row_len {row_len}"
            );
        }
    }

    #[test]
    fn transfer_factor_fp16_is_exactly_one() {
        // Not "close to": the Static pinning argument needs fp16's
        // factor to be *bitwise* 1.0, so `bytes * factor == bytes` and
        // the runtime controller's tier-0 chunk train reproduces the
        // engine's static train exactly.
        assert_eq!(Precision::Fp16.transfer_factor(), 1.0);
        assert_eq!(500e6 * Precision::Fp16.transfer_factor(), 500e6);
    }

    #[test]
    fn rel_error_is_zero_at_deployed_precision_and_ordered() {
        assert_eq!(Precision::Fp32.rel_error(), 0.0);
        assert_eq!(Precision::Fp16.rel_error(), 0.0);
        assert!(Precision::Int8.rel_error() > 0.0);
        assert!(
            Precision::Nf4.rel_error() > Precision::Int8.rel_error(),
            "modeled error must preserve the measured fp16 < int8 < nf4 ordering"
        );
    }

    #[test]
    fn int8_scale_overhead_is_per_row_not_per_nf4_block() {
        // The per-row absmax scheme: exactly one f32 per row, so the
        // amortized overhead shrinks with row length — unlike NF4, whose
        // block size is fixed.
        assert_eq!(Precision::Int8.bytes_per_param(64), 1.0 + 4.0 / 64.0);
        assert_eq!(Precision::Int8.bytes_per_param(4096), 1.0 + 4.0 / 4096.0);
        assert!(
            Precision::Int8.bytes_per_param(4096) < Precision::Int8.bytes_per_param(64),
            "wider rows amortize the per-row scale further"
        );
        // NF4's overhead is row-length independent.
        assert_eq!(
            Precision::Nf4.bytes_per_param(64),
            Precision::Nf4.bytes_per_param(4096)
        );
        // One 64-wide row happens to match the old constant — the bug
        // only showed on rows wider than one NF4 block.
        assert!((Precision::Int8.bytes_per_param(64) - 1.0625).abs() < 1e-12);
    }
}
