//! Workloads + evaluation harnesses: prompt corpora (the stand-ins for
//! the paper's LongWriter/Alpaca sets), the speed harness behind Table 2(i)
//! and Figs. 8–10, the recall harness behind Figs. 3/6 and Table 1, and
//! the fidelity harness behind Table 2(iii).

pub mod corpus;
pub mod fidelity;
pub mod recall;
pub mod speed;

pub use corpus::Corpus;
