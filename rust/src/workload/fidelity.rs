//! Output-fidelity harness (Table 2(iii) substitution, DESIGN.md §2).
//!
//! What the paper's quality benchmarks demonstrate is that OD-MoE serves
//! the *exact* full-precision model while quantizing/skipping baselines
//! degrade it. With a synthetic-scale model we measure that property
//! directly: token-stream agreement and logit KL against the FP32
//! greedy-decode reference, over a shared corpus.

use anyhow::Result;

use crate::coordinator::Engine;
use crate::engine::ModelState;
use crate::metrics::Fidelity;
use crate::model::WeightStore;
use crate::runtime::Runtime;
use crate::workload::Corpus;

/// Reference generations: FP32 greedy decode.
pub struct Reference {
    /// Per prompt: generated tokens (first from prefill).
    pub tokens: Vec<Vec<u32>>,
    /// Per prompt: per-step logits.
    pub logits: Vec<Vec<Vec<f32>>>,
}

/// Produce the FP32 reference stream for a corpus.
pub fn reference(
    rt: &Runtime,
    ws: &WeightStore,
    corpus: &Corpus,
    out_tokens: usize,
) -> Result<Reference> {
    let mut state = ModelState::new(rt, ws.clone())?;
    let mut tokens = Vec::new();
    let mut logits = Vec::new();
    for prompt in &corpus.prompts {
        state.reset();
        let rec = state.prefill(prompt)?;
        let mut toks = vec![rec.token_out];
        let mut lgs = vec![rec.logits];
        let mut t = rec.token_out;
        for _ in 1..out_tokens {
            let s = state.decode_step(t)?;
            toks.push(s.token_out);
            lgs.push(s.logits);
            t = s.token_out;
        }
        tokens.push(toks);
        logits.push(lgs);
    }
    Ok(Reference { tokens, logits })
}

/// Compare an engine's generations against the reference.
pub fn evaluate(
    engine: &mut dyn Engine,
    reference: &Reference,
    corpus: &Corpus,
    out_tokens: usize,
) -> Result<Fidelity> {
    let mut fid = Fidelity::default();
    for (pi, prompt) in corpus.prompts.iter().enumerate() {
        engine.reset()?;
        let res = engine.run_prompt(prompt, out_tokens, true)?;
        let ref_toks = &reference.tokens[pi];
        let ref_logits = &reference.logits[pi];
        let mut diverged = None;
        for i in 0..res.tokens.len().min(ref_toks.len()) {
            fid.record_step(
                &ref_logits[i],
                &res.step_logits[i],
                ref_toks[i],
                res.tokens[i],
            );
            if diverged.is_none() && res.tokens[i] != ref_toks[i] {
                diverged = Some(i);
            }
        }
        fid.first_divergence.push(diverged);
    }
    Ok(fid)
}
