//! Speed harness: serve a corpus through any [`Engine`] and aggregate
//! TTFT / decoding throughput / output throughput, at paper scale.
//!
//! Paper-scale conversion: the virtual-time model books per-layer work for
//! our 12-layer Tiny-Mixtral; Mixtral-8x7B has 32 layers and per-token
//! time is linear in depth, so reported milliseconds scale by 32/12.
//! (Both raw and scaled values are retained.)

use anyhow::Result;

use crate::coordinator::{Engine, PromptResult};
use crate::metrics::SpeedStats;
use crate::workload::Corpus;

/// Paper model depth / our model depth.
pub const PAPER_LAYER_SCALE: f64 = 32.0 / 12.0;

/// One (input_len, output_len) evaluation cell of Table 2(i).
#[derive(Debug, Clone)]
pub struct SpeedCell {
    pub input_len: usize,
    pub output_len: usize,
    /// Raw virtual-time stats (12-layer model).
    pub raw: SpeedStats,
    /// Paper-scale stats (32-layer equivalent).
    pub scaled: SpeedStats,
    pub total_stall_ms: f64,
}

impl SpeedCell {
    pub fn label(&self) -> String {
        format!("({}, {})", self.input_len, self.output_len)
    }
}

/// Run `engine` over a corpus, producing one Table 2(i) cell.
pub fn run_speed_cell(
    engine: &mut dyn Engine,
    corpus: &Corpus,
    out_tokens: usize,
) -> Result<SpeedCell> {
    let mut raw = SpeedStats::default();
    let mut scaled = SpeedStats::default();
    let mut stall = 0.0;
    let input_len = corpus.prompts.first().map(|p| p.len()).unwrap_or(0);
    for prompt in &corpus.prompts {
        engine.reset()?;
        let res: PromptResult = engine.run_prompt(prompt, out_tokens, false)?;
        let n = res.tokens.len().saturating_sub(1);
        raw.record(res.ttft_ms, res.decode_ms, n);
        scaled.record(
            res.ttft_ms * PAPER_LAYER_SCALE,
            res.decode_ms * PAPER_LAYER_SCALE,
            n,
        );
        stall += res.stall_ms;
    }
    Ok(SpeedCell { input_len, output_len: out_tokens, raw, scaled, total_stall_ms: stall })
}

/// The paper's four (input, output) cells for one engine.
pub fn run_speed_table(
    engine: &mut dyn Engine,
    seed: u64,
    prompts_per_len: usize,
    vocab: u32,
    out_lens: &[usize],
) -> Result<Vec<SpeedCell>> {
    let (short, long) = Corpus::speed_set(seed, prompts_per_len, vocab);
    let mut cells = Vec::new();
    for &out in out_lens {
        cells.push(run_speed_cell(engine, &short, out)?);
    }
    for &out in out_lens {
        cells.push(run_speed_cell(engine, &long, out)?);
    }
    Ok(cells)
}
