//! Recall harness: runs the full-precision model beside one or more
//! predictors and accumulates Eq. (2)/(3) statistics. Powers Fig. 3
//! (quantization x alignment curves), Fig. 6 (alignment-period grid) and
//! Table 1 (baseline predictor comparison).

use anyhow::Result;

use crate::engine::{ModelState, Route};
use crate::metrics::{correct_count, RecallStats};
use crate::model::{Precision, WeightStore};
use crate::predictor::{AlignmentConfig, Predictor, SepPredictor};
use crate::runtime::{DeviceModel, Runtime};
use crate::workload::Corpus;

/// Measure SEP recall for one (precision, alignment) configuration over a
/// corpus, decoding `out_tokens` per prompt.
pub fn sep_recall(
    rt: &Runtime,
    ws: &WeightStore,
    precision: Precision,
    align: AlignmentConfig,
    corpus: &Corpus,
    out_tokens: usize,
) -> Result<RecallStats> {
    let cfg = ws.cfg.clone();
    let mut stats = RecallStats::new(cfg.top_k, cfg.n_layers);
    let mut main = ModelState::new(rt, ws.clone())?;
    let mut sep = SepPredictor::new(rt, ws, precision, align)?;
    for prompt in &corpus.prompts {
        main.reset();
        sep.reset();
        let rec = main.prefill(prompt)?;
        sep.prefill(prompt)?;
        let mut token = rec.token_out;
        for n in 0..out_tokens {
            sep.begin_token(&main, token)?;
            let step = main.decode_step(token)?;
            let correct: Vec<usize> = (0..cfg.n_layers)
                .map(|l| correct_count(&sep.predict(l).experts, &step.routes[l].experts))
                .collect();
            stats.record_token(n, &correct);
            token = step.token_out;
        }
    }
    Ok(stats)
}

/// Measure a baseline predictor's recall over a corpus.
///
/// Predictions are requested just before each layer executes and the
/// layer's true activations are fed back immediately after — the same
/// online protocol the original systems use. Only layers for which the
/// predictor produced a prediction are counted (HOBBIT's convention:
/// recall over predicted layers). Returns `(recall, predictions_counted)`.
pub fn baseline_recall(
    rt: &Runtime,
    ws: &WeightStore,
    predictor: &mut dyn Predictor,
    corpus: &Corpus,
    out_tokens: usize,
) -> Result<(f64, u64)> {
    let cfg = ws.cfg.clone();
    let dm = DeviceModel::upload(rt, ws)?;
    let mut main = ModelState::new(rt, ws.clone())?;
    let mut correct_sum: u64 = 0;
    let mut total: u64 = 0;
    for prompt in &corpus.prompts {
        main.reset();
        let rec = main.prefill(prompt)?;
        let mut token = rec.token_out;
        for _ in 0..out_tokens {
            predictor.begin_token(token);
            let pred_ref = &mut *predictor;
            let (cs, tt) = (&mut correct_sum, &mut total);
            let (d, k) = (cfg.d_model, cfg.top_k);
            let mut exec = |layer: usize,
                            route: &Route,
                            x_resid: &[f32],
                            h: &[f32]|
             -> Result<Vec<f32>> {
                if let Some(p) = pred_ref.predict(layer) {
                    *cs += correct_count(&p, &route.experts) as u64;
                    *tt += k as u64;
                }
                pred_ref.observe(layer, x_resid, h, route);
                // Numerics: full-precision experts, unchanged.
                let mut acc = vec![0f32; d];
                for (i, &e) in route.experts.iter().enumerate() {
                    let y = rt.expert_ffn(&dm, layer, e, h, 1)?;
                    let w = route.weights[i];
                    for j in 0..d {
                        acc[j] += w * y[j];
                    }
                }
                Ok(acc)
            };
            let step = main.decode_step_with(token, &mut exec)?;
            token = step.token_out;
        }
    }
    let recall = if total == 0 { 0.0 } else { correct_sum as f64 / total as f64 };
    Ok((recall, total))
}
