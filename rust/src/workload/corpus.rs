//! Deterministic synthetic prompt corpora.
//!
//! The paper's datasets only supply token streams — recall and speed
//! statistics depend on router behaviour, not prompt semantics
//! (DESIGN.md §2). Prompts are generated with a Markov-ish token walk so
//! consecutive tokens are correlated (pure-uniform streams under-exercise
//! the KV cache and produce unnaturally uniform expert churn).

use crate::model::rng::Rng;

/// A set of prompts of fixed length.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub prompts: Vec<Vec<u32>>,
}

/// The Markov-ish token walk both generators share.
fn walk(rng: &mut Rng, len: usize, vocab: u32) -> Vec<u32> {
    let mut toks = Vec::with_capacity(len);
    let mut cur = rng.below(vocab as usize) as u32;
    for _ in 0..len {
        toks.push(cur);
        // Correlated walk: small step with p=0.7, jump otherwise.
        cur = if rng.uniform() < 0.7 {
            let step = rng.below(7) as i64 - 3;
            (cur as i64 + step).rem_euclid(vocab as i64) as u32
        } else {
            rng.below(vocab as usize) as u32
        };
    }
    toks
}

impl Corpus {
    /// `n` prompts of `len` tokens over `vocab`.
    pub fn generate(seed: u64, n: usize, len: usize, vocab: u32) -> Self {
        Self::generate_mixed(seed, &vec![len; n], vocab)
    }

    /// One prompt per entry of `lens` (the serving workload generator
    /// draws per-request lengths). RNG streams are forked per prompt, so
    /// prompt `i` is identical to [`Corpus::generate`]'s prompt `i`
    /// whenever the lengths agree.
    pub fn generate_mixed(seed: u64, lens: &[usize], vocab: u32) -> Self {
        let base = Rng::new(seed ^ 0xC0FFEE);
        let prompts = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| {
                let mut rng = base.fork(i as u64 + 1);
                walk(&mut rng, len, vocab)
            })
            .collect();
        Self { prompts }
    }

    /// The paper's speed-test corpus shape: half short, half long prompts
    /// (§4.1 inherits HOBBIT's 30x16-token + 30x128-token Alpaca subset).
    pub fn speed_set(seed: u64, per_length: usize, vocab: u32) -> (Self, Self) {
        (
            Self::generate(seed, per_length, 16, vocab),
            Self::generate(seed ^ 0x51, per_length, 128, vocab),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(7, 3, 16, 256);
        let b = Corpus::generate(7, 3, 16, 256);
        assert_eq!(a.prompts, b.prompts);
    }

    #[test]
    fn shapes() {
        let c = Corpus::generate(1, 5, 128, 256);
        assert_eq!(c.prompts.len(), 5);
        assert!(c.prompts.iter().all(|p| p.len() == 128));
        assert!(c.prompts.iter().flatten().all(|&t| t < 256));
    }

    #[test]
    fn prompts_differ_from_each_other() {
        let c = Corpus::generate(1, 2, 32, 256);
        assert_ne!(c.prompts[0], c.prompts[1]);
    }

    #[test]
    fn tokens_are_correlated_but_not_constant() {
        let c = Corpus::generate(3, 1, 128, 256);
        let p = &c.prompts[0];
        let distinct: std::collections::HashSet<_> = p.iter().collect();
        assert!(distinct.len() > 10, "should not be constant");
        // Majority of steps are small moves.
        let small = p.windows(2).filter(|w| {
            let d = (w[0] as i64 - w[1] as i64).rem_euclid(256);
            d <= 3 || d >= 253
        }).count();
        assert!(small * 2 > p.len(), "walk should be mostly local: {small}");
    }

    #[test]
    fn mixed_matches_fixed_when_lengths_agree() {
        let fixed = Corpus::generate(9, 3, 16, 256);
        let mixed = Corpus::generate_mixed(9, &[16, 16, 16], 256);
        assert_eq!(fixed.prompts, mixed.prompts);
    }

    #[test]
    fn mixed_lengths_are_respected() {
        let c = Corpus::generate_mixed(9, &[16, 128, 16], 256);
        let lens: Vec<usize> = c.prompts.iter().map(|p| p.len()).collect();
        assert_eq!(lens, vec![16, 128, 16]);
        // Prefixes agree with the fixed-length generator (same fork per
        // index, same walk).
        let fixed = Corpus::generate(9, 3, 16, 256);
        assert_eq!(&c.prompts[1][..16], fixed.prompts[1].as_slice());
    }

    #[test]
    fn speed_set_has_both_lengths() {
        let (short, long) = Corpus::speed_set(1, 3, 256);
        assert_eq!(short.prompts[0].len(), 16);
        assert_eq!(long.prompts[0].len(), 128);
    }
}
