//! Tiny-Mixtral configuration. Mirrors `python/compile/config.py` — the
//! runtime refuses to load artifacts built for a different config (the AOT
//! step writes `artifacts/config.json` for exactly this check).

use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Architecture hyper-parameters of the model all engines serve.
///
/// Defaults are the scale-reduced stand-in for Mixtral-8x7B (same component
/// structure: RMSNorm, rotary GQA attention, softmax top-k router, SwiGLU
/// experts — see DESIGN.md §2 for the substitution argument).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Per-expert SwiGLU hidden size.
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
    /// KV-cache capacity baked into the decode graphs.
    pub max_seq_len: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            vocab_size: 256,
            d_model: 64,
            n_layers: 12,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            d_ff: 128,
            n_experts: 8,
            top_k: 2,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
            max_seq_len: 512,
        }
    }
}

impl ModelConfig {
    /// Query projection width (`n_heads * head_dim`).
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Key/value projection width (`n_kv_heads * head_dim`).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Parameters in one expert (w1 + w3 + w2).
    pub fn expert_param_count(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    /// Bytes of one f32 expert — the unit of on-demand loading.
    pub fn expert_bytes_f32(&self) -> usize {
        self.expert_param_count() * 4
    }

    /// Load the config the artifacts were built for and verify it matches.
    pub fn load_and_verify(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        let def = ModelConfig::default();
        ensure!(
            cfg == def,
            "artifacts were built for a different config:\n  artifacts: {cfg:?}\n  crate:     {def:?}"
        );
        Ok(cfg)
    }

    /// Parse from the JSON written by `python/compile/aot.py`.
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            vocab_size: v.get("vocab_size")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            rope_theta: v.get("rope_theta")?.as_f64()?,
            rms_eps: v.get("rms_eps")?.as_f64()?,
            max_seq_len: v.get("max_seq_len")?.as_usize()?,
        })
    }

    /// Basic internal consistency (used by prop-tests and CLI overrides).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_heads % self.n_kv_heads == 0, "n_heads % n_kv_heads != 0");
        ensure!(self.top_k >= 1 && self.top_k <= self.n_experts, "bad top_k");
        ensure!(self.head_dim % 2 == 0, "rope needs even head_dim");
        ensure!(self.max_seq_len > 0 && self.d_model > 0, "degenerate dims");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ModelConfig::default().validate().unwrap();
    }

    #[test]
    fn derived_dims() {
        let c = ModelConfig::default();
        assert_eq!(c.q_dim(), 64);
        assert_eq!(c.kv_dim(), 32);
        assert_eq!(c.expert_param_count(), 3 * 64 * 128);
        assert_eq!(c.expert_bytes_f32(), 98304);
    }

    #[test]
    fn rejects_bad_topk() {
        let mut c = ModelConfig::default();
        c.top_k = 9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn parses_aot_config_json() {
        let src = r#"{"d_ff":128,"d_model":64,"head_dim":16,"max_seq_len":512,
            "n_experts":8,"n_heads":4,"n_kv_heads":2,"n_layers":12,
            "rms_eps":1e-05,"rope_theta":10000.0,"top_k":2,"vocab_size":256}"#;
        let cfg = ModelConfig::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg, ModelConfig::default());
    }
}
