//! Host-side weight store — the simulated "CPU memory" holding every
//! parameter of the model on each node (paper §1: experts live in DRAM and
//! are loaded to GPU on demand; here "loading" is metered by the cluster
//! simulator while the bytes feed PJRT executions directly).
//!
//! All matrices are row-major `[in, out]` (x @ W convention), matching the
//! L2 graphs in `python/compile/model.py`.

use crate::model::config::ModelConfig;
use crate::model::rng::Rng;
use crate::quant::fake_quant;

pub use crate::quant::Precision;

/// One expert's SwiGLU parameters.
#[derive(Debug, Clone)]
pub struct ExpertWeights {
    /// Gate projection `[d_model, d_ff]`.
    pub w1: Vec<f32>,
    /// Up projection `[d_model, d_ff]`.
    pub w3: Vec<f32>,
    /// Down projection `[d_ff, d_model]`.
    pub w2: Vec<f32>,
}

impl ExpertWeights {
    pub fn param_count(&self) -> usize {
        self.w1.len() + self.w3.len() + self.w2.len()
    }
}

/// Per-layer non-expert parameters (what the paper's main node hosts).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Attention-input RMSNorm gain `[d_model]`.
    pub attn_norm: Vec<f32>,
    /// Q/K/V/O projections: `[d, q_dim]`, `[d, kv_dim]`, `[d, kv_dim]`, `[q_dim, d]`.
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    /// Post-attention RMSNorm gain `[d_model]`.
    pub ffn_norm: Vec<f32>,
    /// Router `[d_model, n_experts]`.
    pub w_gate: Vec<f32>,
}

/// Full model parameters: non-expert stack + `n_layers x n_experts` experts.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub cfg: ModelConfig,
    /// Token embedding `[vocab, d_model]`.
    pub embedding: Vec<f32>,
    /// Final RMSNorm gain `[d_model]`.
    pub final_norm: Vec<f32>,
    /// LM head `[d_model, vocab]`.
    pub w_out: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// `experts[layer][expert]`.
    pub experts: Vec<Vec<ExpertWeights>>,
    /// Precision this store was (fake-)quantized to.
    pub precision: Precision,
}

impl WeightStore {
    /// Generate deterministic full-precision weights from a seed.
    ///
    /// Init scale is `1/sqrt(fan_in)`-ish, with mild per-expert asymmetry in
    /// the router path so expert popularity is non-uniform (as in real MoE
    /// models — this is what makes LFU/statistical baselines meaningful).
    pub fn generate(cfg: &ModelConfig, seed: u64) -> Self {
        let base = Rng::new(seed);
        let d = cfg.d_model;
        let scale = |fan_in: usize| 1.0 / (fan_in as f32).sqrt();

        let mut r = base.fork(0x0E);
        let embedding = r.normal_vec(cfg.vocab_size * d, 1.0);
        let final_norm = vec![1.0; d];
        let w_out = r.normal_vec(d * cfg.vocab_size, scale(d));

        let mut layers = Vec::with_capacity(cfg.n_layers);
        let mut experts = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut r = base.fork(0x100 + l as u64);
            let mut gate = r.normal_vec(d * cfg.n_experts, scale(d));
            // Skew router columns so activation frequencies are non-uniform.
            for e in 0..cfg.n_experts {
                let bias = 0.15 * ((e as f32 / cfg.n_experts as f32) - 0.5);
                for row in 0..d {
                    gate[row * cfg.n_experts + e] *= 1.0 + bias;
                }
            }
            layers.push(LayerWeights {
                attn_norm: vec![1.0; d],
                wq: r.normal_vec(d * cfg.q_dim(), scale(d)),
                wk: r.normal_vec(d * cfg.kv_dim(), scale(d)),
                wv: r.normal_vec(d * cfg.kv_dim(), scale(d)),
                wo: r.normal_vec(cfg.q_dim() * d, scale(cfg.q_dim())),
                ffn_norm: vec![1.0; d],
                w_gate: gate,
            });
            let mut lx = Vec::with_capacity(cfg.n_experts);
            for e in 0..cfg.n_experts {
                let mut r = base.fork(0x10_000 + (l * cfg.n_experts + e) as u64);
                lx.push(ExpertWeights {
                    w1: r.normal_vec(d * cfg.d_ff, scale(d)),
                    w3: r.normal_vec(d * cfg.d_ff, scale(d)),
                    w2: r.normal_vec(cfg.d_ff * d, scale(cfg.d_ff)),
                });
            }
            experts.push(lx);
        }
        Self {
            cfg: cfg.clone(),
            embedding,
            final_norm,
            w_out,
            layers,
            experts,
            precision: Precision::Fp32,
        }
    }

    /// Build the shadow variant: every tensor quantize→dequantized at `p`
    /// (the paper quantizes the whole shadow model, §2.3).
    pub fn quantized(&self, p: Precision) -> Self {
        if p == Precision::Fp32 {
            return self.clone();
        }
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let q = |w: &[f32], cols: usize| fake_quant(w, cols, p);
        Self {
            cfg: cfg.clone(),
            embedding: q(&self.embedding, d),
            final_norm: q(&self.final_norm, d),
            w_out: q(&self.w_out, cfg.vocab_size),
            layers: self
                .layers
                .iter()
                .map(|lw| LayerWeights {
                    attn_norm: q(&lw.attn_norm, d),
                    wq: q(&lw.wq, cfg.q_dim()),
                    wk: q(&lw.wk, cfg.kv_dim()),
                    wv: q(&lw.wv, cfg.kv_dim()),
                    wo: q(&lw.wo, d),
                    ffn_norm: q(&lw.ffn_norm, d),
                    w_gate: q(&lw.w_gate, cfg.n_experts),
                })
                .collect(),
            experts: self
                .experts
                .iter()
                .map(|lx| {
                    lx.iter()
                        .map(|e| ExpertWeights {
                            w1: q(&e.w1, cfg.d_ff),
                            w3: q(&e.w3, cfg.d_ff),
                            w2: q(&e.w2, d),
                        })
                        .collect()
                })
                .collect(),
            precision: p,
        }
    }

    /// Quantize only the experts (HOBBIT/Mixtral-Offloading style baselines
    /// keep attention full-precision and compress the offloaded experts).
    pub fn with_quantized_experts(&self, p: Precision) -> Self {
        let mut out = self.clone();
        let cfg = &self.cfg;
        for lx in &mut out.experts {
            for e in lx.iter_mut() {
                e.w1 = fake_quant(&e.w1, cfg.d_ff, p);
                e.w3 = fake_quant(&e.w3, cfg.d_ff, p);
                e.w2 = fake_quant(&e.w2, cfg.d_model, p);
            }
        }
        out
    }

    /// Embedding row for a token (host-side lookup; exact row copy).
    pub fn embed(&self, token: u32) -> &[f32] {
        let d = self.cfg.d_model;
        let i = token as usize;
        assert!(i < self.cfg.vocab_size, "token {i} out of vocab");
        &self.embedding[i * d..(i + 1) * d]
    }

    /// Total parameter count (for the memory audit, Table 2(ii)).
    pub fn param_count(&self) -> usize {
        let per_layer: usize = self
            .layers
            .first()
            .map(|l| {
                l.attn_norm.len()
                    + l.wq.len()
                    + l.wk.len()
                    + l.wv.len()
                    + l.wo.len()
                    + l.ffn_norm.len()
                    + l.w_gate.len()
            })
            .unwrap_or(0);
        let experts: usize = self.experts.iter().flatten().map(|e| e.param_count()).sum();
        self.embedding.len() + self.final_norm.len() + self.w_out.len()
            + per_layer * self.layers.len()
            + experts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::default()
    }

    #[test]
    fn generation_is_deterministic() {
        let a = WeightStore::generate(&cfg(), 42);
        let b = WeightStore::generate(&cfg(), 42);
        assert_eq!(a.embedding, b.embedding);
        assert_eq!(a.experts[3][5].w2, b.experts[3][5].w2);
    }

    #[test]
    fn seeds_differ() {
        let a = WeightStore::generate(&cfg(), 1);
        let b = WeightStore::generate(&cfg(), 2);
        assert_ne!(a.layers[0].wq, b.layers[0].wq);
    }

    #[test]
    fn shapes() {
        let c = cfg();
        let w = WeightStore::generate(&c, 0);
        assert_eq!(w.layers.len(), c.n_layers);
        assert_eq!(w.experts.len(), c.n_layers);
        assert_eq!(w.experts[0].len(), c.n_experts);
        assert_eq!(w.layers[0].wq.len(), c.d_model * c.q_dim());
        assert_eq!(w.experts[0][0].w1.len(), c.d_model * c.d_ff);
        assert_eq!(w.embed(5).len(), c.d_model);
    }

    #[test]
    fn quantized_store_differs_but_tracks() {
        let w = WeightStore::generate(&cfg(), 7);
        let s = w.quantized(Precision::Int8);
        assert_ne!(w.layers[0].wq, s.layers[0].wq);
        let max_err = w.layers[0].wq.iter().zip(&s.layers[0].wq)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // Error bounded well below weight scale (1/8).
        assert!(max_err < 0.01, "int8 err {max_err}");
    }

    #[test]
    fn fp32_quantized_is_identity() {
        let w = WeightStore::generate(&cfg(), 7);
        let s = w.quantized(Precision::Fp32);
        assert_eq!(w.layers[0].wq, s.layers[0].wq);
    }

    #[test]
    fn expert_only_quant_keeps_attention_exact() {
        let w = WeightStore::generate(&cfg(), 7);
        let s = w.with_quantized_experts(Precision::Nf4);
        assert_eq!(w.layers[0].wq, s.layers[0].wq);
        assert_ne!(w.experts[0][0].w1, s.experts[0][0].w1);
    }

    #[test]
    fn param_count_matches_formula() {
        let c = cfg();
        let w = WeightStore::generate(&c, 0);
        let expected = c.vocab_size * c.d_model          // embedding
            + c.d_model                                   // final norm
            + c.d_model * c.vocab_size                    // lm head
            + c.n_layers * (2 * c.d_model                 // norms
                + c.d_model * c.q_dim() + 2 * c.d_model * c.kv_dim()
                + c.q_dim() * c.d_model
                + c.d_model * c.n_experts)                // router
            + c.n_layers * c.n_experts * c.expert_param_count();
        assert_eq!(w.param_count(), expected);
    }
}
