//! Model substrate: configuration, deterministic weight generation, and the
//! host-side weight store (the simulated "CPU memory" of every edge node).

pub mod config;
pub mod rng;
pub mod weights;

pub use config::ModelConfig;
pub use weights::{ExpertWeights, LayerWeights, Precision, WeightStore};
