//! Deterministic PRNG for weight/corpus generation — no external crates.
//!
//! SplitMix64 for seeding + xoshiro256** for the stream; Box-Muller for
//! normals. Determinism matters: every experiment in EXPERIMENTS.md is
//! reproducible from a seed, and the Python tests never need to share
//! weight files with Rust (each side validates against its own oracles,
//! the cross-language check goes through `artifacts/checks.json`).

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare: None }
    }

    /// Derive an independent stream (for per-layer / per-expert weights).
    pub fn fork(&self, stream: u64) -> Self {
        Self::new(self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// `len` normals scaled by `std`, as f32.
    pub fn normal_vec(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| self.normal() as f32 * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_well_spread() {
        let mut r = Rng::new(3);
        let vals: Vec<f64> = (0..10_000).map(|_| r.uniform()).collect();
        assert!(vals.iter().all(|&v| (0.0..1.0).contains(&v)));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let vals: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
