//! Unified metrics registry: named counters, gauges, and histograms with
//! one structured JSONL export schema shared by `decode`, `serve`
//! (including `--scale-sweep`'s per-cell `scale.*` series, DESIGN.md
//! §13), and `plan` (DESIGN.md §11).
//!
//! The registry replaces ad-hoc counter plumbing (the engine's private
//! `failovers` field, loose abort/load counters threaded through return
//! structs): producers increment named metrics at the event site, and any
//! consumer — a CLI summary line, a `METRICS_*.jsonl` artifact, a test —
//! reads them back by name. Names are dotted paths
//! (`engine.failovers`, `scheduler.rejected`, `plan.candidates`);
//! everything is `BTreeMap`-backed so exports are deterministically
//! ordered.

use std::collections::BTreeMap;

use crate::serve::WindowedHistogram;
use crate::util::json::{num, obj, Json};

/// A process-local metrics registry. Cheap to create; `Default` is empty.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
    /// Rolling-window series ([`WindowedHistogram`]): the "recent past"
    /// signal the SLO control loop reads, exported with exact
    /// percentiles over the current window (DESIGN.md §15).
    windows: BTreeMap<String, WindowedHistogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to the named counter (creating it at zero).
    pub fn counter_add(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to its latest value.
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one observation into the named histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().push(v);
    }

    /// Raw samples of a histogram (empty if never observed).
    pub fn histogram(&self, name: &str) -> &[f64] {
        self.histograms.get(name).map_or(&[], |v| v.as_slice())
    }

    /// Record one observation into the named rolling-window series,
    /// created with `window` retained samples on first touch (later
    /// calls keep the original width — the window is part of the
    /// series' identity).
    pub fn observe_windowed(&mut self, name: &str, window: usize, v: f64) {
        self.windows
            .entry(name.to_string())
            .or_insert_with(|| WindowedHistogram::new(window))
            .push(v);
    }

    /// The named rolling-window series, if ever observed.
    pub fn windowed(&self, name: &str) -> Option<&WindowedHistogram> {
        self.windows.get(name)
    }

    /// Fold `other` into `self`: counters add, gauges take `other`'s
    /// value, histogram samples append.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().extend_from_slice(v);
        }
        for (k, w) in &other.windows {
            let mine = self
                .windows
                .entry(k.clone())
                .or_insert_with(|| WindowedHistogram::new(w.window()));
            for v in w.ordered() {
                mine.push(v);
            }
        }
    }

    pub fn clear(&mut self) {
        self.counters.clear();
        self.gauges.clear();
        self.histograms.clear();
        self.windows.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.gauges.is_empty()
            && self.histograms.is_empty()
            && self.windows.is_empty()
    }

    /// Export every metric as JSON Lines, one object per line, counters
    /// then gauges then histograms, each sorted by name. `source` tags
    /// which subcommand produced the line — the one schema shared by
    /// `decode`, `serve`, and `plan`:
    ///
    /// ```text
    /// {"kind":"counter","name":"engine.failovers","source":"decode","value":2}
    /// {"kind":"gauge","name":"engine.loads_per_token","source":"decode","value":3.9}
    /// {"kind":"histogram","name":"...","source":"...","count":..,"mean":..,
    ///  "min":..,"max":..,"p50":..,"p95":..,"p99":..}
    /// ```
    pub fn export_jsonl(&self, source: &str) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let line = obj(vec![
                ("kind", Json::Str("counter".into())),
                ("name", Json::Str(name.clone())),
                ("source", Json::Str(source.into())),
                ("value", Json::Num(*v as f64)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        for (name, v) in &self.gauges {
            let line = obj(vec![
                ("kind", Json::Str("gauge".into())),
                ("name", Json::Str(name.clone())),
                ("source", Json::Str(source.into())),
                ("value", num(*v)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        for (name, samples) in &self.histograms {
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
            let p = |q: f64| crate::metrics::percentile_sorted(&sorted, q);
            let line = obj(vec![
                ("kind", Json::Str("histogram".into())),
                ("name", Json::Str(name.clone())),
                ("source", Json::Str(source.into())),
                ("count", Json::Num(samples.len() as f64)),
                ("mean", num(crate::metrics::mean(samples))),
                ("min", num(sorted.first().copied().unwrap_or(0.0))),
                ("max", num(sorted.last().copied().unwrap_or(0.0))),
                ("p50", num(p(0.5))),
                ("p95", num(p(0.95))),
                ("p99", num(p(0.99))),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        for (name, w) in &self.windows {
            let s = w.summary();
            let line = obj(vec![
                ("kind", Json::Str("windowed_histogram".into())),
                ("name", Json::Str(name.clone())),
                ("source", Json::Str(source.into())),
                ("window", Json::Num(w.window() as f64)),
                ("pushed", Json::Num(w.pushed() as f64)),
                ("count", Json::Num(s.count as f64)),
                ("mean", num(s.mean)),
                ("p50", num(s.p50)),
                ("p95", num(s.p95)),
                ("p99", num(s.p99)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_roundtrip() {
        let mut r = Registry::new();
        r.counter_add("engine.failovers", 2);
        r.counter_add("engine.failovers", 3);
        r.gauge_set("engine.loads_per_token", 3.5);
        r.gauge_set("engine.loads_per_token", 3.9);
        r.observe("serve.ttft_ms", 10.0);
        r.observe("serve.ttft_ms", 30.0);
        assert_eq!(r.counter("engine.failovers"), 5);
        assert_eq!(r.counter("never.touched"), 0);
        assert_eq!(r.gauge("engine.loads_per_token"), Some(3.9));
        assert_eq!(r.histogram("serve.ttft_ms"), &[10.0, 30.0]);
        assert!(!r.is_empty());
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn merge_adds_counters_and_appends_samples() {
        let mut a = Registry::new();
        a.counter_add("c", 1);
        a.observe("h", 1.0);
        a.gauge_set("g", 1.0);
        let mut b = Registry::new();
        b.counter_add("c", 2);
        b.observe("h", 2.0);
        b.gauge_set("g", 2.0);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        assert_eq!(a.histogram("h"), &[1.0, 2.0]);
        assert_eq!(a.gauge("g"), Some(2.0), "gauge takes the newer value");
    }

    #[test]
    fn jsonl_export_is_one_valid_object_per_line() {
        let mut r = Registry::new();
        r.counter_add("b.count", 7);
        r.counter_add("a.count", 1);
        r.gauge_set("z.gauge", 0.25);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.observe("lat_ms", v);
        }
        let text = r.export_jsonl("decode");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        // Counters sorted by name, then gauges, then histograms.
        assert!(lines[0].contains("\"a.count\""), "{text}");
        assert!(lines[1].contains("\"b.count\""), "{text}");
        assert!(lines[2].contains("\"z.gauge\""), "{text}");
        assert!(lines[3].contains("\"histogram\""), "{text}");
        for line in &lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("source").unwrap().as_str().unwrap(), "decode");
            assert!(j.get("kind").is_ok() && j.get("name").is_ok());
        }
        let h = Json::parse(lines[3]).unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 4);
        assert_eq!(h.get("min").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(h.get("max").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(h.get("p50").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn windowed_series_retains_only_the_last_window() {
        let mut r = Registry::new();
        for v in 0..10 {
            r.observe_windowed("serve.ttft_recent", 4, v as f64);
        }
        let w = r.windowed("serve.ttft_recent").unwrap();
        assert_eq!(w.pushed(), 10, "lifetime count survives eviction");
        assert_eq!(w.ordered(), vec![6.0, 7.0, 8.0, 9.0], "only the last 4 retained");
        assert!(r.windowed("never.touched").is_none());
        assert!(!r.is_empty());
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn merge_folds_windowed_samples_in_order() {
        let mut a = Registry::new();
        a.observe_windowed("w", 3, 1.0);
        a.observe_windowed("w", 3, 2.0);
        let mut b = Registry::new();
        b.observe_windowed("w", 3, 3.0);
        b.observe_windowed("w", 3, 4.0);
        b.observe_windowed("only_b", 2, 9.0);
        a.merge(&b);
        // a's window (width 3) receives b's samples newest-last, so the
        // oldest of the four combined falls out.
        assert_eq!(a.windowed("w").unwrap().ordered(), vec![2.0, 3.0, 4.0]);
        assert_eq!(a.windowed("only_b").unwrap().ordered(), vec![9.0]);
    }

    #[test]
    fn windowed_export_reports_window_and_lifetime_pushes() {
        let mut r = Registry::new();
        for v in [5.0, 1.0, 3.0, 7.0, 9.0] {
            r.observe_windowed("serve.ttft_recent", 3, v);
        }
        let text = r.export_jsonl("serve");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "windowed_histogram");
        assert_eq!(j.get("window").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("pushed").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 3);
        // Percentiles are over the retained window {3, 7, 9} only.
        assert_eq!(j.get("p50").unwrap().as_f64().unwrap(), 7.0);
    }
}
