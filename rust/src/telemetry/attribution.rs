//! Per-token critical-path attribution over virtual-time traces.
//!
//! After a decode, the engine's [`Trace`] holds every booked interval
//! (main compute, shadow steps, expert loads / chunk streams, FFN tiles,
//! LAN holds, stalls). This module turns that log into answers to "which
//! resource bound this token?":
//!
//! * [`decompose`] — an exact time decomposition of one window: every
//!   elementary interval between event boundaries is attributed to the
//!   highest-priority phase active anywhere in the cluster during it
//!   (stall > expert load > prefetch > expert compute > LAN > shadow >
//!   main > idle), so the per-phase times partition the window: they sum
//!   to the window length to f64 resolution (DESIGN.md §11 invariant A).
//! * [`critical_path`] — a backward walk from the window's end through
//!   the binding chain of events; the returned segments partition the
//!   window, so their total equals the makespan (invariant B).
//! * [`attribute`] — both of the above per token (plus a per-layer split
//!   at the `embed-back` LAN arrivals, the layer boundaries of the
//!   OD-MoE pipeline), packaged as [`DecodeAttribution`] with table and
//!   JSON renderers for `od-moe decode --attribution`.

use crate::cluster::Ms;
use crate::trace::{Event, EventKind, NodeRef, Trace};
use crate::util::json::{num, obj, Json};

/// Number of attribution phases (the length of [`Phase::ALL`]).
pub const NPHASES: usize = 8;

/// What a slice of wall-clock decode time was spent on. Variant order is
/// *binding priority*: when intervals overlap across nodes, the earlier
/// variant wins the attribution (an expert load that overlaps main
/// compute is the scarce resource — hiding loads behind compute is the
/// paper's whole mechanism, so overlapped time counts against the load).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Explicit I/O stall booked by the engine (expert wait past arrival).
    Stall,
    /// Demand expert weight transfer on a worker PCIe link.
    ExpertLoad,
    /// Speculative chunk stream (prefetch depth >= 1).
    Prefetch,
    /// Worker FFN tile.
    ExpertCompute,
    /// Shared LAN wire held.
    Lan,
    /// Shadow-node predictor step.
    ShadowCompute,
    /// Main-node non-expert compute.
    MainCompute,
    /// Nothing booked anywhere: a dependency wait.
    Idle,
}

impl Phase {
    /// All phases, highest binding priority first.
    pub const ALL: [Phase; NPHASES] = [
        Phase::Stall,
        Phase::ExpertLoad,
        Phase::Prefetch,
        Phase::ExpertCompute,
        Phase::Lan,
        Phase::ShadowCompute,
        Phase::MainCompute,
        Phase::Idle,
    ];

    /// The phase a trace event belongs to (`None` for zero-width failure
    /// markers, which occupy no time).
    pub fn of(kind: EventKind) -> Option<Phase> {
        Some(match kind {
            EventKind::Stall => Phase::Stall,
            EventKind::ExpertLoad => Phase::ExpertLoad,
            EventKind::Prefetch => Phase::Prefetch,
            EventKind::ExpertCompute => Phase::ExpertCompute,
            EventKind::LanSend => Phase::Lan,
            EventKind::ShadowCompute => Phase::ShadowCompute,
            EventKind::MainCompute => Phase::MainCompute,
            EventKind::Failure => return None,
        })
    }

    /// Index into a `[_; NPHASES]` bucket array (priority order).
    pub fn idx(self) -> usize {
        Phase::ALL.iter().position(|&p| p == self).expect("phase in ALL")
    }

    /// Stable snake_case name (the JSON schema key).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Stall => "stall",
            Phase::ExpertLoad => "expert_load",
            Phase::Prefetch => "prefetch",
            Phase::ExpertCompute => "expert_compute",
            Phase::Lan => "lan",
            Phase::ShadowCompute => "shadow",
            Phase::MainCompute => "main",
            Phase::Idle => "idle",
        }
    }
}

/// Events that occupy time and overlap `(t0, t1)`, as clipped spans.
fn clipped<'a>(trace: &'a Trace, t0: Ms, t1: Ms) -> Vec<(&'a Event, Ms, Ms, Phase)> {
    trace
        .events()
        .iter()
        .filter_map(|ev| {
            let phase = Phase::of(ev.kind)?;
            if ev.end <= ev.start || ev.end <= t0 || ev.start >= t1 {
                return None;
            }
            Some((ev, ev.start.max(t0), ev.end.min(t1), phase))
        })
        .collect()
}

/// Exact phase decomposition of `[t0, t1]`: per-phase busy time under the
/// priority rule, partitioning the window (the buckets sum to `t1 - t0`
/// up to f64 rounding; property-tested in `rust/tests/telemetry_props.rs`).
pub fn decompose(trace: &Trace, t0: Ms, t1: Ms) -> [Ms; NPHASES] {
    let mut out = [0.0; NPHASES];
    if t1 <= t0 {
        return out;
    }
    let evs = clipped(trace, t0, t1);
    let mut cuts: Vec<Ms> = Vec::with_capacity(2 * evs.len() + 2);
    cuts.push(t0);
    cuts.push(t1);
    for &(_, s, e, _) in &evs {
        cuts.push(s);
        cuts.push(e);
    }
    cuts.sort_by(|a, b| a.partial_cmp(b).expect("finite trace times"));
    cuts.dedup();
    for w in cuts.windows(2) {
        let (a, b) = (w[0], w[1]);
        if b <= a {
            continue;
        }
        // Consecutive cuts: any event overlapping (a, b) covers it whole,
        // so the binding phase is constant on the interval.
        let mut best = Phase::Idle;
        for &(_, s, e, phase) in &evs {
            if s <= a && e >= b && phase < best {
                best = phase;
            }
        }
        out[best.idx()] += b - a;
    }
    out
}

/// One link of the binding chain: either a booked event (clipped to the
/// walk) or a dependency gap with nothing booked anywhere.
#[derive(Debug, Clone)]
pub struct CpSegment {
    pub phase: Phase,
    /// The node the binding event booked on (`None` for gaps).
    pub node: Option<NodeRef>,
    pub label: &'static str,
    pub start: Ms,
    pub end: Ms,
}

impl CpSegment {
    pub fn dur(&self) -> Ms {
        self.end - self.start
    }
}

/// Walk the binding chain backward from `t1`: at each cursor, follow the
/// highest-priority event covering it (earliest start wins ties — the
/// resource was continuously held); where nothing covers the cursor,
/// emit an [`Phase::Idle`] gap back to the latest earlier event end. The
/// segments partition `[t0, t1]`, so their lengths sum to the makespan.
pub fn critical_path(trace: &Trace, t0: Ms, t1: Ms) -> Vec<CpSegment> {
    let evs = clipped(trace, t0, t1);
    let mut segs: Vec<CpSegment> = Vec::new();
    let mut cursor = t1;
    while cursor > t0 {
        let mut best: Option<(Phase, Ms, NodeRef, &'static str)> = None;
        for &(ev, s, e, phase) in &evs {
            if s < cursor && e >= cursor {
                let cand = (phase, s, ev.node, ev.label);
                best = Some(match best {
                    None => cand,
                    Some(b) if (cand.0, cand.1, cand.2) < (b.0, b.1, b.2) => cand,
                    Some(b) => b,
                });
            }
        }
        match best {
            Some((phase, s, node, label)) => {
                segs.push(CpSegment { phase, node: Some(node), label, start: s, end: cursor });
                cursor = s;
            }
            None => {
                let prev = evs
                    .iter()
                    .map(|&(_, _, e, _)| e)
                    .filter(|&e| e < cursor)
                    .fold(t0, Ms::max);
                segs.push(CpSegment {
                    phase: Phase::Idle,
                    node: None,
                    label: "wait",
                    start: prev,
                    end: cursor,
                });
                cursor = prev;
            }
        }
    }
    segs.reverse();
    segs
}

/// Phase decomposition of one slice of a token (between two consecutive
/// `embed-back` arrivals = one expert layer; the tail past the last
/// arrival is the LM head, `layer: None`).
#[derive(Debug, Clone)]
pub struct LayerSlice {
    /// Expert layer index, or `None` for the LM-head tail.
    pub layer: Option<usize>,
    pub start: Ms,
    pub end: Ms,
    pub phase_ms: [Ms; NPHASES],
}

/// One decode iteration's attribution.
#[derive(Debug, Clone)]
pub struct TokenAttribution {
    /// Decode iteration index (0 = first decoded token after prefill).
    pub index: usize,
    pub start: Ms,
    pub end: Ms,
    pub phase_ms: [Ms; NPHASES],
    /// Per-layer split when the trace carries `embed-back` boundaries
    /// (empty for engines without the OD-MoE layer pipeline).
    pub layers: Vec<LayerSlice>,
}

impl TokenAttribution {
    /// Measured iteration latency (the window length).
    pub fn latency(&self) -> Ms {
        self.end - self.start
    }

    /// Sum of the phase buckets (== latency, the invariant under test).
    pub fn phases_total(&self) -> Ms {
        self.phase_ms.iter().sum()
    }

    /// The dominant phase (largest bucket; binding priority breaks ties).
    pub fn bound(&self) -> Phase {
        let mut best = Phase::Idle;
        let mut best_ms = f64::NEG_INFINITY;
        for p in Phase::ALL {
            let ms = self.phase_ms[p.idx()];
            if ms > best_ms {
                best = p;
                best_ms = ms;
            }
        }
        best
    }
}

/// Attribution of a full decode: per-token decompositions plus the
/// binding chain over the whole decode window.
#[derive(Debug, Clone)]
pub struct DecodeAttribution {
    pub tokens: Vec<TokenAttribution>,
    pub critical: Vec<CpSegment>,
    /// Decode window start (first token span's start).
    pub t0: Ms,
    /// Decode window end (= makespan instant).
    pub t1: Ms,
}

/// Attribute a decode from its trace and the engine's recorded per-token
/// spans ([`crate::coordinator::OdMoeEngine::token_spans`]).
pub fn attribute(trace: &Trace, spans: &[(Ms, Ms)]) -> DecodeAttribution {
    let t0 = spans.first().map_or(0.0, |s| s.0);
    let t1 = spans.last().map_or(0.0, |s| s.1);
    let tokens = spans
        .iter()
        .enumerate()
        .map(|(index, &(s, e))| {
            let phase_ms = decompose(trace, s, e);
            let layers = layer_slices(trace, s, e);
            TokenAttribution { index, start: s, end: e, phase_ms, layers }
        })
        .collect();
    DecodeAttribution { tokens, critical: critical_path(trace, t0, t1), t0, t1 }
}

/// Split `[t0, t1]` at the `embed-back` LAN arrivals inside it.
fn layer_slices(trace: &Trace, t0: Ms, t1: Ms) -> Vec<LayerSlice> {
    let mut bounds: Vec<Ms> = trace
        .events()
        .iter()
        .filter(|ev| ev.kind == EventKind::LanSend && ev.label == "embed-back")
        .filter_map(|ev| ev.arrival)
        .filter(|&a| a > t0 && a <= t1)
        .collect();
    bounds.sort_by(|a, b| a.partial_cmp(b).expect("finite arrivals"));
    bounds.dedup();
    if bounds.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(bounds.len() + 1);
    let mut prev = t0;
    for (l, &b) in bounds.iter().enumerate() {
        let phase_ms = decompose(trace, prev, b);
        out.push(LayerSlice { layer: Some(l), start: prev, end: b, phase_ms });
        prev = b;
    }
    if t1 > prev {
        let phase_ms = decompose(trace, prev, t1);
        out.push(LayerSlice { layer: None, start: prev, end: t1, phase_ms });
    }
    out
}

impl DecodeAttribution {
    /// Total decode time attributed (sum over token windows).
    pub fn total_ms(&self) -> Ms {
        self.tokens.iter().map(|t| t.latency()).sum()
    }

    /// Per-phase totals across all tokens.
    pub fn phase_totals(&self) -> [Ms; NPHASES] {
        let mut out = [0.0; NPHASES];
        for t in &self.tokens {
            for i in 0..NPHASES {
                out[i] += t.phase_ms[i];
            }
        }
        out
    }

    /// Sum of critical-path segment lengths (== `t1 - t0`, invariant B).
    pub fn critical_total(&self) -> Ms {
        self.critical.iter().map(|s| s.dur()).sum()
    }

    /// Per-phase share of the critical path.
    pub fn critical_by_phase(&self) -> [Ms; NPHASES] {
        let mut out = [0.0; NPHASES];
        for s in &self.critical {
            out[s.phase.idx()] += s.dur();
        }
        out
    }

    /// The `--attribution` text table: one row per token, a totals row,
    /// and the critical-path summary line.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:>5} {:>9}", "tok", "ms"));
        for p in Phase::ALL {
            out.push_str(&format!(" {:>9}", p.name()));
        }
        out.push_str("  bound\n");
        out.push_str(&"-".repeat(15 + 10 * NPHASES + 7));
        out.push('\n');
        for t in &self.tokens {
            out.push_str(&format!("{:>5} {:>9.3}", t.index, t.latency()));
            for p in Phase::ALL {
                out.push_str(&format!(" {:>9.3}", t.phase_ms[p.idx()]));
            }
            out.push_str(&format!("  {}\n", t.bound().name()));
        }
        let totals = self.phase_totals();
        out.push_str(&format!("{:>5} {:>9.3}", "all", self.total_ms()));
        for p in Phase::ALL {
            out.push_str(&format!(" {:>9.3}", totals[p.idx()]));
        }
        out.push('\n');
        let makespan = self.t1 - self.t0;
        let cp = self.critical_by_phase();
        let mut shares: Vec<String> = Vec::new();
        if makespan > 0.0 {
            for p in Phase::ALL {
                let frac = cp[p.idx()] / makespan;
                if frac > 0.005 {
                    shares.push(format!("{} {:.1}%", p.name(), 100.0 * frac));
                }
            }
        }
        out.push_str(&format!(
            "critical path {:.3} ms over {} segments: {}\n",
            self.critical_total(),
            self.critical.len(),
            shares.join(", ")
        ));
        out
    }

    /// The `--attribution` JSON document (schema in DESIGN.md §11).
    pub fn to_json(&self) -> Json {
        let phases_obj = |ms: &[Ms; NPHASES]| {
            obj(Phase::ALL.iter().map(|p| (p.name(), num(ms[p.idx()]))).collect())
        };
        let tokens: Vec<Json> = self
            .tokens
            .iter()
            .map(|t| {
                let layers: Vec<Json> = t
                    .layers
                    .iter()
                    .map(|l| {
                        obj(vec![
                            (
                                "layer",
                                match l.layer {
                                    Some(i) => Json::Num(i as f64),
                                    None => Json::Str("lm_head".into()),
                                },
                            ),
                            ("start_ms", num(l.start)),
                            ("end_ms", num(l.end)),
                            ("phases_ms", phases_obj(&l.phase_ms)),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("token", Json::Num(t.index as f64)),
                    ("start_ms", num(t.start)),
                    ("end_ms", num(t.end)),
                    ("latency_ms", num(t.latency())),
                    ("phases_ms", phases_obj(&t.phase_ms)),
                    ("bound", Json::Str(t.bound().name().into())),
                    ("layers", Json::Arr(layers)),
                ])
            })
            .collect();
        let critical: Vec<Json> = self
            .critical
            .iter()
            .map(|s| {
                obj(vec![
                    ("phase", Json::Str(s.phase.name().into())),
                    (
                        "node",
                        match s.node {
                            Some(NodeRef::Node(n)) => Json::Num(n as f64),
                            Some(NodeRef::Lan) => Json::Str("lan".into()),
                            None => Json::Null,
                        },
                    ),
                    ("label", Json::Str(s.label.into())),
                    ("start_ms", num(s.start)),
                    ("end_ms", num(s.end)),
                ])
            })
            .collect();
        obj(vec![
            ("schema", Json::Str("odmoe.attribution.v1".into())),
            ("makespan_ms", num(self.t1 - self.t0)),
            ("total_ms", num(self.total_ms())),
            ("phase_totals_ms", phases_obj(&self.phase_totals())),
            ("critical_by_phase_ms", phases_obj(&self.critical_by_phase())),
            ("tokens", Json::Arr(tokens)),
            ("critical_path", Json::Arr(critical)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_trace() -> Trace {
        let mut t = Trace::new();
        t.enabled = true;
        // main [0,4), load [2,10) on worker 0 (overlap -> load wins [2,4)),
        // gap [10,11), expert compute [11,14).
        t.push(EventKind::MainCompute, 0, 0.0, 4.0, "M");
        t.push(EventKind::ExpertLoad, 2, 2.0, 10.0, "EL");
        t.push(EventKind::ExpertCompute, 2, 11.0, 14.0, "EC");
        t
    }

    #[test]
    fn decompose_partitions_the_window() {
        let t = demo_trace();
        let d = decompose(&t, 0.0, 14.0);
        assert!((d[Phase::MainCompute.idx()] - 2.0).abs() < 1e-12, "{d:?}");
        assert!((d[Phase::ExpertLoad.idx()] - 8.0).abs() < 1e-12, "{d:?}");
        assert!((d[Phase::ExpertCompute.idx()] - 3.0).abs() < 1e-12, "{d:?}");
        assert!((d[Phase::Idle.idx()] - 1.0).abs() < 1e-12, "{d:?}");
        let sum: f64 = d.iter().sum();
        assert!((sum - 14.0).abs() < 1e-9, "conservation: {sum}");
    }

    #[test]
    fn decompose_clips_to_the_window() {
        let t = demo_trace();
        let d = decompose(&t, 3.0, 9.0);
        assert!((d.iter().sum::<f64>() - 6.0).abs() < 1e-9);
        assert_eq!(d[Phase::MainCompute.idx()], 0.0, "main fully shadowed by the load");
        assert!((d[Phase::ExpertLoad.idx()] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn critical_path_partitions_the_makespan() {
        let t = demo_trace();
        let cp = critical_path(&t, 0.0, 14.0);
        let total: f64 = cp.iter().map(|s| s.dur()).sum();
        assert!((total - 14.0).abs() < 1e-9, "{cp:?}");
        // Chain: main-ish prefix, load, gap, compute — contiguous.
        for w in cp.windows(2) {
            assert_eq!(w[0].end, w[1].start, "segments must be contiguous");
        }
        assert_eq!(cp.first().unwrap().start, 0.0);
        assert_eq!(cp.last().unwrap().end, 14.0);
        assert_eq!(cp.last().unwrap().phase, Phase::ExpertCompute);
        assert!(cp.iter().any(|s| s.phase == Phase::Idle && s.label == "wait"));
    }

    #[test]
    fn failure_markers_occupy_no_time() {
        let mut t = demo_trace();
        t.push(EventKind::Failure, 2, 5.0, 5.0, "fail");
        let d = decompose(&t, 0.0, 14.0);
        assert!((d.iter().sum::<f64>() - 14.0).abs() < 1e-9);
        let cp = critical_path(&t, 0.0, 14.0);
        assert!(cp.iter().all(|s| s.label != "fail"));
    }

    #[test]
    fn attribute_splits_layers_at_embed_back_arrivals() {
        let mut t = demo_trace();
        // Two layer boundaries inside the token, then an LM-head tail.
        t.push_lan(3.9, 4.0, 6.0, "embed-back");
        t.push_lan(9.0, 9.5, 10.0, "embed-back");
        let a = attribute(&t, &[(0.0, 14.0)]);
        assert_eq!(a.tokens.len(), 1);
        let tok = &a.tokens[0];
        assert!((tok.phases_total() - tok.latency()).abs() < 1e-9);
        assert_eq!(tok.layers.len(), 3);
        assert_eq!(tok.layers[0].layer, Some(0));
        assert_eq!(tok.layers[1].layer, Some(1));
        assert_eq!(tok.layers[2].layer, None, "tail is the LM head");
        assert_eq!(tok.layers[0].end, 6.0);
        assert_eq!(tok.layers[2].end, 14.0);
        let sliced: f64 = tok.layers.iter().map(|l| l.end - l.start).sum();
        assert!((sliced - tok.latency()).abs() < 1e-9);
        assert_eq!(tok.bound(), Phase::ExpertLoad);
    }

    #[test]
    fn table_and_json_render() {
        let t = demo_trace();
        let a = attribute(&t, &[(0.0, 10.0), (10.0, 14.0)]);
        let table = a.render_table();
        assert!(table.contains("expert_load"), "{table}");
        assert!(table.contains("critical path"), "{table}");
        let j = a.to_json();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "odmoe.attribution.v1");
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }

    #[test]
    fn empty_trace_is_all_idle() {
        let t = Trace::new();
        let d = decompose(&t, 0.0, 5.0);
        assert_eq!(d[Phase::Idle.idx()], 5.0);
        let cp = critical_path(&t, 0.0, 5.0);
        assert_eq!(cp.len(), 1);
        assert_eq!(cp[0].phase, Phase::Idle);
    }
}
