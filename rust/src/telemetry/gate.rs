//! Regression gate for `od-moe bench`: diff a fresh `BENCH_perf.json`
//! against the committed baseline with a relative noise band.
//!
//! `BENCH_perf.json` has two sections (DESIGN.md §11):
//!
//! * `"virtual"` — deterministic virtual-time metrics (simulated decode
//!   makespans, scheduler sweep percentiles). These only move when the
//!   *modeled* performance changes, so the gate compares them key by key:
//!   a relative increase beyond the noise band is a regression and
//!   `od-moe bench --ci` exits nonzero.
//! * `"wall"` — wall-clock microbench distributions. Machine-dependent,
//!   never gated; kept for humans reading the step summary.
//!
//! A baseline containing `"bootstrap": true` (the state this repo ships
//! in until a real baseline is committed) makes the gate a no-op that
//! prints regeneration instructions — the documented escape hatch for
//! intentional perf changes is the same command:
//! `od-moe bench --write-baseline`.

use anyhow::{bail, Result};
use std::fmt::Write as _;

use crate::util::json::Json;

/// One metric's baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct GateDelta {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// `(current - baseline) / baseline` (positive = slower).
    pub delta_frac: f64,
}

/// Outcome of gating one `BENCH_perf.json` against a baseline.
#[derive(Debug, Clone, Default)]
pub struct GateOutcome {
    /// Metrics present in both files and compared.
    pub checked: usize,
    /// Beyond the band in the slow direction.
    pub regressions: Vec<GateDelta>,
    /// Beyond the band in the fast direction (informational; a candidate
    /// for a deliberate baseline refresh).
    pub improvements: Vec<GateDelta>,
    /// Baseline keys missing from the current run (a silently dropped
    /// benchmark is treated as a failure, not a pass).
    pub missing: Vec<String>,
    /// Current-run keys absent from the baseline. Informational only —
    /// a freshly added benchmark has no history to regress against —
    /// but listed in the report so new metrics get pinned deliberately
    /// (`od-moe bench --write-baseline`) instead of staying ungated.
    pub new_metrics: Vec<String>,
    /// The baseline was a bootstrap placeholder; nothing was compared.
    pub bootstrap: bool,
}

impl GateOutcome {
    /// True iff the gate allows the change through.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }

    /// Human-readable report for the CLI.
    pub fn report(&self, band: f64) -> String {
        let mut out = String::new();
        if self.bootstrap {
            out.push_str(
                "perf gate: baseline is a bootstrap placeholder — nothing compared.\n\
                 Pin it with `od-moe bench --write-baseline` and commit the file.\n",
            );
            return out;
        }
        let _ = writeln!(
            out,
            "perf gate: {} metric(s) checked, band ±{:.1}%: {} regression(s), \
             {} improvement(s), {} missing",
            self.checked,
            100.0 * band,
            self.regressions.len(),
            self.improvements.len(),
            self.missing.len(),
        );
        for d in &self.regressions {
            let _ = writeln!(
                out,
                "  REGRESSION {:<44} {:>12.6} -> {:>12.6} ({:+.1}%)",
                d.name,
                d.baseline,
                d.current,
                100.0 * d.delta_frac
            );
        }
        for d in &self.improvements {
            let _ = writeln!(
                out,
                "  improved   {:<44} {:>12.6} -> {:>12.6} ({:+.1}%)",
                d.name,
                d.baseline,
                d.current,
                100.0 * d.delta_frac
            );
        }
        for name in &self.missing {
            let _ = writeln!(out, "  MISSING    {name} (in baseline, not produced by this run)");
        }
        for name in &self.new_metrics {
            let _ = writeln!(out, "  new        {name} (not in baseline; ungated until pinned)");
        }
        if !self.passed() {
            out.push_str(
                "intentional change? regenerate with `od-moe bench --write-baseline` \
                 and commit the updated baseline.\n",
            );
        }
        out
    }
}

/// Compare the `"virtual"` sections of two `BENCH_perf.json` documents.
/// `band` is the relative noise band (e.g. 0.02 = ±2%).
pub fn gate(current: &Json, baseline: &Json, band: f64) -> Result<GateOutcome> {
    if !(0.0..1.0).contains(&band) {
        bail!("noise band must be in [0, 1), got {band}");
    }
    let mut out = GateOutcome::default();
    if let Ok(b) = baseline.get("bootstrap") {
        if *b == Json::Bool(true) {
            out.bootstrap = true;
            return Ok(out);
        }
    }
    let base = baseline.get("virtual")?.as_obj()?;
    let cur = current.get("virtual")?.as_obj()?;
    for (name, bv) in base {
        let b = bv.as_f64()?;
        let Some(cv) = cur.get(name) else {
            out.missing.push(name.clone());
            continue;
        };
        let c = cv.as_f64()?;
        out.checked += 1;
        let delta_frac = (c - b) / b.abs().max(1e-12);
        let d = GateDelta { name: name.clone(), baseline: b, current: c, delta_frac };
        if delta_frac > band {
            out.regressions.push(d);
        } else if delta_frac < -band {
            out.improvements.push(d);
        }
    }
    for name in cur.keys() {
        if !base.contains_key(name) {
            out.new_metrics.push(name.clone());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn perf(pairs: &[(&str, f64)]) -> Json {
        let virt: std::collections::BTreeMap<String, Json> =
            pairs.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect();
        let mut top = std::collections::BTreeMap::new();
        top.insert("virtual".to_string(), Json::Obj(virt));
        Json::Obj(top)
    }

    #[test]
    fn identical_runs_pass() {
        let a = perf(&[("decode/uniform", 100.0), ("serve/p99", 250.0)]);
        let g = gate(&a, &a, 0.02).unwrap();
        assert!(g.passed());
        assert_eq!(g.checked, 2);
        assert!(g.regressions.is_empty() && g.improvements.is_empty());
    }

    #[test]
    fn injected_slowdown_beyond_band_fails() {
        // The acceptance-criterion test: a synthetic 10% slowdown on one
        // metric must trip a 2% band.
        let base = perf(&[("decode/uniform", 100.0), ("serve/p99", 250.0)]);
        let cur = perf(&[("decode/uniform", 110.0), ("serve/p99", 250.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(!g.passed());
        assert_eq!(g.regressions.len(), 1);
        assert_eq!(g.regressions[0].name, "decode/uniform");
        assert!((g.regressions[0].delta_frac - 0.10).abs() < 1e-12);
        assert!(g.report(0.02).contains("REGRESSION decode/uniform"), "{}", g.report(0.02));
    }

    #[test]
    fn slowdown_within_band_passes() {
        let base = perf(&[("decode/uniform", 100.0)]);
        let cur = perf(&[("decode/uniform", 101.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(g.passed(), "1% is inside a 2% band");
    }

    #[test]
    fn speedup_is_reported_but_passes() {
        let base = perf(&[("decode/uniform", 100.0)]);
        let cur = perf(&[("decode/uniform", 80.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(g.passed());
        assert_eq!(g.improvements.len(), 1);
    }

    #[test]
    fn dropped_benchmark_fails() {
        let base = perf(&[("decode/uniform", 100.0), ("gone", 5.0)]);
        let cur = perf(&[("decode/uniform", 100.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(!g.passed());
        assert_eq!(g.missing, vec!["gone".to_string()]);
    }

    #[test]
    fn new_benchmark_in_current_is_fine_and_listed() {
        let base = perf(&[("decode/uniform", 100.0)]);
        let cur = perf(&[("decode/uniform", 100.0), ("brand_new", 1.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(g.passed(), "a new metric must never fail the gate");
        assert_eq!(g.new_metrics, vec!["brand_new".to_string()]);
        assert!(g.report(0.02).contains("new        brand_new"), "{}", g.report(0.02));
    }

    #[test]
    fn bootstrap_baseline_skips_comparison() {
        let base = Json::parse(r#"{"bootstrap": true}"#).unwrap();
        let cur = perf(&[("decode/uniform", 100.0)]);
        let g = gate(&cur, &base, 0.02).unwrap();
        assert!(g.bootstrap && g.passed());
        assert!(g.report(0.02).contains("bootstrap"));
    }

    #[test]
    fn bad_band_rejected() {
        let a = perf(&[]);
        assert!(gate(&a, &a, 1.5).is_err());
    }
}
