//! Observability layer: critical-path attribution, a unified metrics
//! registry, and the perf-regression gate behind `od-moe bench`
//! (DESIGN.md §11).
//!
//! Three pillars, all virtual-time-native and dependency-free:
//!
//! * [`attribution`] — walk a [`crate::trace::Trace`] after a decode and
//!   decompose every token (and layer) into binding phases, with two
//!   machine-checked invariants: phase times partition the measured
//!   iteration latency, and the critical path partitions the makespan.
//!   Surfaced by `od-moe decode --attribution` and aggregated per rate ×
//!   fleet into `BENCH_attrib.json` by the serve harness.
//! * [`registry`] — named counters/gauges/histograms with one JSONL
//!   export schema shared by `decode`, `serve`, and `plan`
//!   (`METRICS_<cmd>.jsonl`), replacing ad-hoc counter plumbing.
//! * [`gate`] — the `od-moe bench --ci` regression gate: diff the
//!   deterministic `"virtual"` section of `BENCH_perf.json` against the
//!   committed baseline with a relative noise band, exit nonzero on a
//!   regression or a silently dropped benchmark.

pub mod attribution;
pub mod gate;
pub mod registry;

pub use attribution::{
    attribute, critical_path, decompose, CpSegment, DecodeAttribution, LayerSlice, Phase,
    TokenAttribution, NPHASES,
};
pub use gate::{gate, GateDelta, GateOutcome};
pub use registry::Registry;
