//! Expert-cache policies for the offloading baselines (paper §2.2).
//!
//! OD-MoE itself is cache*less*; these policies exist to reproduce the
//! systems it is compared against: LRU (Mixtral-Offloading/AdapMoE), LFU
//! (MoE-Infinity), and HOBBIT's mixed-precision variant where evictions
//! prefer low-precision copies.

use std::collections::HashMap;

/// A (layer, expert) cache key.
pub type ExpertKey = (usize, usize);

/// Eviction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Lru,
    Lfu,
}

/// Fixed-capacity expert cache with LRU/LFU eviction.
///
/// Capacity is in *expert slots* (the baselines size their GPU pools in
/// whole experts). `touch` marks use; `insert` evicts as needed and
/// reports the victims (the engine charges eviction/load time).
#[derive(Debug)]
pub struct ExpertCache {
    capacity: usize,
    policy: Policy,
    /// key -> (last_use_tick, use_count)
    entries: HashMap<ExpertKey, (u64, u64)>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl ExpertCache {
    pub fn new(capacity: usize, policy: Policy) -> Self {
        Self { capacity, policy, entries: HashMap::new(), tick: 0, hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Record an access (for hit/miss stats + recency/frequency state).
    /// Returns true on hit.
    pub fn touch(&mut self, key: ExpertKey) -> bool {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.0 = self.tick;
            e.1 += 1;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Insert `key`, evicting per policy if full. Returns evicted keys.
    pub fn insert(&mut self, key: ExpertKey) -> Vec<ExpertKey> {
        self.tick += 1;
        if self.entries.contains_key(&key) {
            return Vec::new();
        }
        let mut evicted = Vec::new();
        while self.entries.len() >= self.capacity && self.capacity > 0 {
            let victim = *match self.policy {
                Policy::Lru => self.entries.iter().min_by_key(|(_, v)| v.0).unwrap().0,
                Policy::Lfu => self
                    .entries
                    .iter()
                    .min_by_key(|(_, v)| (v.1, v.0))
                    .unwrap()
                    .0,
            };
            self.entries.remove(&victim);
            evicted.push(victim);
        }
        if self.capacity > 0 {
            self.entries.insert(key, (self.tick, 1));
        }
        evicted
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn clear_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    pub fn keys(&self) -> impl Iterator<Item = &ExpertKey> {
        self.entries.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = ExpertCache::new(2, Policy::Lru);
        c.insert((0, 0));
        c.insert((0, 1));
        c.touch((0, 0)); // 0 most recent
        let ev = c.insert((0, 2));
        assert_eq!(ev, vec![(0, 1)]);
        assert!(c.contains((0, 0)) && c.contains((0, 2)));
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut c = ExpertCache::new(2, Policy::Lfu);
        c.insert((0, 0));
        c.insert((0, 1));
        c.touch((0, 0));
        c.touch((0, 0));
        c.touch((0, 1));
        let ev = c.insert((0, 2));
        assert_eq!(ev, vec![(0, 1)]);
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = ExpertCache::new(4, Policy::Lru);
        assert!(!c.touch((1, 1)));
        c.insert((1, 1));
        assert!(c.touch((1, 1)));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hit_rate(), 0.5);
    }

    #[test]
    fn capacity_respected() {
        let mut c = ExpertCache::new(3, Policy::Lru);
        for e in 0..10 {
            c.insert((0, e));
            assert!(c.len() <= 3);
        }
    }

    #[test]
    fn reinsert_is_noop() {
        let mut c = ExpertCache::new(2, Policy::Lru);
        c.insert((0, 0));
        let ev = c.insert((0, 0));
        assert!(ev.is_empty());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = ExpertCache::new(0, Policy::Lru);
        c.insert((0, 0));
        assert!(c.is_empty());
        assert!(!c.touch((0, 0)));
    }
}
