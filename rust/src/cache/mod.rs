//! Expert residency: baseline cache pools and the tiered cache.
//!
//! Two layers live here. [`ExpertCache`] reproduces the single-pool
//! LRU/LFU caches of the offloading baselines the paper compares against
//! (Mixtral-Offloading/AdapMoE, MoE-Infinity). [`TieredCache`] is the
//! optional GPU-hot / CPU-warm / SSD-cold residency subsystem layered on
//! top of OD-MoE's on-demand streaming (DESIGN.md §12): per-worker tiers
//! with per-tier expert-slot budgets, pluggable eviction
//! ([`TierPolicy::Lru`], [`TierPolicy::Sieve`], and the SEP-informed
//! [`TierPolicy::ReuseDistance`]), and a demotion chain hot → warm →
//! cold → out. A GPU-hot hit skips the expert stream entirely, an
//! SSD-cold hit stages over the worker's storage link first, and warm
//! hits and misses take the unchanged on-demand path. The disabled
//! config (every budget 0) constructs no tier state at all, which is how
//! budget 0 stays bit-identical — tokens AND timings — to the cacheless
//! engine.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// A (layer, expert) cache key.
pub type ExpertKey = (usize, usize);

/// Eviction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Lru,
    Lfu,
}

/// Fixed-capacity expert cache with LRU/LFU eviction.
///
/// Capacity is in *expert slots* (the baselines size their GPU pools in
/// whole experts). `touch` marks use; `insert` evicts as needed and
/// reports the victims (the engine charges eviction/load time).
#[derive(Debug)]
pub struct ExpertCache {
    capacity: usize,
    policy: Policy,
    /// key -> (last_use_tick, use_count)
    entries: HashMap<ExpertKey, (u64, u64)>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl ExpertCache {
    pub fn new(capacity: usize, policy: Policy) -> Self {
        Self { capacity, policy, entries: HashMap::new(), tick: 0, hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, key: ExpertKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Record an access (for hit/miss stats + recency/frequency state).
    /// Returns true on hit.
    pub fn touch(&mut self, key: ExpertKey) -> bool {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.0 = self.tick;
            e.1 += 1;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Insert `key`, evicting per policy if full. Returns evicted keys.
    pub fn insert(&mut self, key: ExpertKey) -> Vec<ExpertKey> {
        self.tick += 1;
        if self.entries.contains_key(&key) {
            return Vec::new();
        }
        let mut evicted = Vec::new();
        while self.entries.len() >= self.capacity && self.capacity > 0 {
            let victim = *match self.policy {
                Policy::Lru => self.entries.iter().min_by_key(|(_, v)| v.0).unwrap().0,
                Policy::Lfu => self
                    .entries
                    .iter()
                    .min_by_key(|(_, v)| (v.1, v.0))
                    .unwrap()
                    .0,
            };
            self.entries.remove(&victim);
            evicted.push(victim);
        }
        if self.capacity > 0 {
            self.entries.insert(key, (self.tick, 1));
        }
        evicted
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn clear_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    pub fn keys(&self) -> impl Iterator<Item = &ExpertKey> {
        self.entries.keys()
    }
}

// ---------------------------------------------------------------------------
// Tiered residency subsystem (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// Residency tier of a cached expert, ordered hottest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierLevel {
    /// Resident in GPU memory: a hit skips the expert stream entirely.
    GpuHot,
    /// Resident in host DRAM — the same place on-demand streams load
    /// from, so a warm hit takes the standard PCIe chunk train.
    CpuWarm,
    /// Resident on local SSD: a hit first stages over the worker's
    /// storage link (its own `Resource`), then the PCIe train.
    SsdCold,
}

/// Pluggable eviction policy for the tiered cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierPolicy {
    /// Evict the least-recently-used entry.
    Lru,
    /// SIEVE-style second chance: a hand scans insertion order, sparing
    /// (and un-marking) visited entries, evicting the first unvisited.
    Sieve,
    /// Predicted-reuse-distance: entries SEP predicts within the
    /// lookahead window have a finite reuse distance and are never
    /// victims; the rest (distance ∞) evict in LRU order. If every
    /// resident expert is predicted-soon, the incoming key — itself the
    /// farthest-reuse entry — is refused instead.
    ReuseDistance,
}

impl TierPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lru" => Ok(Self::Lru),
            "sieve" => Ok(Self::Sieve),
            "reuse" | "reuse-distance" => Ok(Self::ReuseDistance),
            other => bail!("unknown cache policy {other:?} (expected lru|sieve|reuse)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Lru => "lru",
            Self::Sieve => "sieve",
            Self::ReuseDistance => "reuse",
        }
    }
}

/// Per-worker tier budgets, in expert slots (experts are uniform-size
/// within a precision, so slot counts — not bytes — are the natural
/// budget unit; `metrics::memory` converts to bytes for the audit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// GPU-hot slots. These bytes stay allocated on the worker's ledger.
    pub hot: usize,
    /// CPU-warm slots (host DRAM).
    pub warm: usize,
    /// SSD-cold slots.
    pub cold: usize,
    pub policy: TierPolicy,
}

impl CacheConfig {
    /// The cacheless default: no tier state is constructed at all, so
    /// the engine's budget-0 paths are byte-for-byte the seed paths.
    pub fn disabled() -> Self {
        Self { hot: 0, warm: 0, cold: 0, policy: TierPolicy::Lru }
    }

    pub fn enabled(&self) -> bool {
        self.hot + self.warm + self.cold > 0
    }

    pub fn label(&self) -> String {
        format!("{}:h{}w{}c{}", self.policy.label(), self.hot, self.warm, self.cold)
    }
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

#[derive(Debug, Clone)]
struct TierEntry {
    key: ExpertKey,
    /// SIEVE visited bit (set by touch, cleared by the scanning hand).
    visited: bool,
    /// Last-use tick (global per tier; unique, so victim choice is
    /// deterministic without tie-breaks).
    tick: u64,
}

/// Where an insert left the incoming key.
enum Placed {
    /// Stored; if the tier was full, the displaced victim.
    Stored(Option<ExpertKey>),
    /// Not stored: zero capacity, or every resident entry is protected
    /// under [`TierPolicy::ReuseDistance`].
    Dropped,
}

/// One tier: insertion-ordered entries (oldest first) + policy state.
#[derive(Debug)]
struct Tier {
    capacity: usize,
    policy: TierPolicy,
    entries: Vec<TierEntry>,
    /// SIEVE hand: index into `entries` where the next scan starts.
    hand: usize,
    tick: u64,
}

impl Tier {
    fn new(capacity: usize, policy: TierPolicy) -> Self {
        Self { capacity, policy, entries: Vec::new(), hand: 0, tick: 0 }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn contains(&self, key: ExpertKey) -> bool {
        self.entries.iter().any(|e| e.key == key)
    }

    /// Refresh recency/visited state; true on hit.
    fn touch(&mut self, key: ExpertKey) -> bool {
        self.tick += 1;
        let tick = self.tick;
        match self.entries.iter_mut().find(|e| e.key == key) {
            Some(e) => {
                e.tick = tick;
                e.visited = true;
                true
            }
            None => false,
        }
    }

    fn remove(&mut self, key: ExpertKey) -> bool {
        match self.entries.iter().position(|e| e.key == key) {
            Some(i) => {
                self.entries.remove(i);
                // Keep the hand on the entry it pointed at (everything
                // after `i` shifted left by one).
                if self.hand > i {
                    self.hand -= 1;
                }
                true
            }
            None => false,
        }
    }

    /// Choose a victim index per policy, or None to refuse admission.
    fn victim(&mut self, protected: &[ExpertKey]) -> Option<usize> {
        debug_assert!(!self.entries.is_empty());
        match self.policy {
            TierPolicy::Lru => self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.tick)
                .map(|(i, _)| i),
            TierPolicy::Sieve => {
                if self.hand >= self.entries.len() {
                    self.hand = 0;
                }
                // Terminates: each visited entry is un-marked exactly
                // once before the hand can revisit it.
                loop {
                    if self.entries[self.hand].visited {
                        self.entries[self.hand].visited = false;
                        self.hand = (self.hand + 1) % self.entries.len();
                    } else {
                        return Some(self.hand);
                    }
                }
            }
            TierPolicy::ReuseDistance => self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| !protected.contains(&e.key))
                .min_by_key(|(_, e)| e.tick)
                .map(|(i, _)| i),
        }
    }

    /// Insert `key` (must not already be present), evicting if full.
    fn insert(&mut self, key: ExpertKey, protected: &[ExpertKey]) -> Placed {
        debug_assert!(!self.contains(key));
        if self.capacity == 0 {
            return Placed::Dropped;
        }
        self.tick += 1;
        let evicted = if self.entries.len() >= self.capacity {
            match self.victim(protected) {
                Some(i) => {
                    let v = self.entries.remove(i);
                    if self.hand > i {
                        self.hand -= 1;
                    }
                    Some(v.key)
                }
                None => return Placed::Dropped,
            }
        } else {
            None
        };
        self.entries.push(TierEntry { key, visited: false, tick: self.tick });
        Placed::Stored(evicted)
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.hand = 0;
    }
}

/// Outcome of [`TieredCache::install`]; drives the engine's GPU ledger.
#[derive(Debug)]
pub struct Install {
    /// The installed key is GPU-resident: its bytes stay allocated.
    pub hot_resident: bool,
    /// Keys that just lost GPU residency (demoted or dropped): the
    /// engine must release their bytes.
    pub evicted_hot: Vec<ExpertKey>,
}

/// Per-worker tiered expert cache (DESIGN.md §12).
///
/// `lookup` classifies an access (and counts hit/miss stats); `install`
/// runs at *compute* time — only experts that were actually used enter
/// the cache, so mispredicted streams never pollute it — promoting the
/// key to GPU-hot and demoting victims down the hot → warm → cold → out
/// chain. All internal state is `Vec`-ordered: identical op sequences
/// produce identical evictions on every run.
#[derive(Debug)]
pub struct TieredCache {
    hot: Tier,
    warm: Tier,
    cold: Tier,
    pub hot_hits: u64,
    pub warm_hits: u64,
    pub cold_hits: u64,
    pub misses: u64,
}

impl TieredCache {
    pub fn new(cfg: &CacheConfig) -> Self {
        Self {
            hot: Tier::new(cfg.hot, cfg.policy),
            warm: Tier::new(cfg.warm, cfg.policy),
            cold: Tier::new(cfg.cold, cfg.policy),
            hot_hits: 0,
            warm_hits: 0,
            cold_hits: 0,
            misses: 0,
        }
    }

    /// Classify an access and refresh the hit tier's recency state.
    /// Promotion is deferred to [`Self::install`] (compute time).
    pub fn lookup(&mut self, key: ExpertKey) -> Option<TierLevel> {
        if self.hot.touch(key) {
            self.hot_hits += 1;
            Some(TierLevel::GpuHot)
        } else if self.warm.touch(key) {
            self.warm_hits += 1;
            Some(TierLevel::CpuWarm)
        } else if self.cold.touch(key) {
            self.cold_hits += 1;
            Some(TierLevel::SsdCold)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Total accesses; always equals hot+warm+cold hits + misses.
    pub fn touches(&self) -> u64 {
        self.hot_hits + self.warm_hits + self.cold_hits + self.misses
    }

    pub fn contains_hot(&self, key: ExpertKey) -> bool {
        self.hot.contains(key)
    }

    /// Evict `key` from the hot tier only (lower tiers keep their
    /// copies). The upgrade-reload path of the runtime precision
    /// controller (DESIGN.md §14) uses this to drop a resident that was
    /// installed from a downgraded stream before re-streaming it at full
    /// precision; returns whether an entry was actually dropped so the
    /// caller can release its memory accounting.
    pub fn remove_hot(&mut self, key: ExpertKey) -> bool {
        self.hot.remove(key)
    }

    pub fn hot_len(&self) -> usize {
        self.hot.len()
    }

    pub fn warm_len(&self) -> usize {
        self.warm.len()
    }

    pub fn cold_len(&self) -> usize {
        self.cold.len()
    }

    /// Install a just-computed expert, promoting it to the hottest tier
    /// with room and demoting victims down the chain. `protected` is
    /// SEP's lookahead set ([`TierPolicy::ReuseDistance`] only; lower
    /// tiers ignore it — protection is about avoiding GPU reload
    /// stalls, and refusing a *demotion* would drop the entry outright).
    pub fn install(&mut self, key: ExpertKey, protected: &[ExpertKey]) -> Install {
        if self.hot.contains(key) {
            self.hot.touch(key);
            return Install { hot_resident: true, evicted_hot: Vec::new() };
        }
        // Promotion: the key leaves any lower tier it occupied.
        self.warm.remove(key);
        self.cold.remove(key);
        match self.hot.insert(key, protected) {
            Placed::Stored(victim) => {
                let mut evicted_hot = Vec::new();
                if let Some(v) = victim {
                    evicted_hot.push(v);
                    self.demote_to_warm(v);
                }
                Install { hot_resident: true, evicted_hot }
            }
            Placed::Dropped => {
                // Refused from (or no) GPU tier: the key was still just
                // used, so it enters the warm chain instead.
                self.demote_to_warm(key);
                Install { hot_resident: false, evicted_hot: Vec::new() }
            }
        }
    }

    fn demote_to_warm(&mut self, key: ExpertKey) {
        if let Placed::Stored(Some(v)) = self.warm.insert(key, &[]) {
            // Warm victim falls to cold; the cold victim falls out.
            let _ = self.cold.insert(v, &[]);
        }
    }

    /// Worker fail-stop: all tiers vanish with the node (stats are
    /// cumulative and survive — the ledger is zeroed by `Node::fail`,
    /// so no per-key dealloc happens here).
    pub fn drop_all(&mut self) {
        self.hot.clear();
        self.warm.clear();
        self.cold.clear();
    }

    /// Full reset for replay determinism: contents and stats.
    pub fn reset(&mut self) {
        self.drop_all();
        self.hot.tick = 0;
        self.warm.tick = 0;
        self.cold.tick = 0;
        self.hot_hits = 0;
        self.warm_hits = 0;
        self.cold_hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = ExpertCache::new(2, Policy::Lru);
        c.insert((0, 0));
        c.insert((0, 1));
        c.touch((0, 0)); // 0 most recent
        let ev = c.insert((0, 2));
        assert_eq!(ev, vec![(0, 1)]);
        assert!(c.contains((0, 0)) && c.contains((0, 2)));
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut c = ExpertCache::new(2, Policy::Lfu);
        c.insert((0, 0));
        c.insert((0, 1));
        c.touch((0, 0));
        c.touch((0, 0));
        c.touch((0, 1));
        let ev = c.insert((0, 2));
        assert_eq!(ev, vec![(0, 1)]);
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = ExpertCache::new(4, Policy::Lru);
        assert!(!c.touch((1, 1)));
        c.insert((1, 1));
        assert!(c.touch((1, 1)));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hit_rate(), 0.5);
    }

    #[test]
    fn capacity_respected() {
        let mut c = ExpertCache::new(3, Policy::Lru);
        for e in 0..10 {
            c.insert((0, e));
            assert!(c.len() <= 3);
        }
    }

    #[test]
    fn reinsert_is_noop() {
        let mut c = ExpertCache::new(2, Policy::Lru);
        c.insert((0, 0));
        let ev = c.insert((0, 0));
        assert!(ev.is_empty());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = ExpertCache::new(0, Policy::Lru);
        c.insert((0, 0));
        assert!(c.is_empty());
        assert!(!c.touch((0, 0)));
    }

    #[test]
    fn lfu_tie_breaks_by_recency_deterministically() {
        // Equal use counts: the stalest (lowest tick) entry loses, and
        // the choice must not depend on HashMap iteration order.
        for _ in 0..8 {
            let mut c = ExpertCache::new(3, Policy::Lfu);
            c.insert((0, 0)); // tick 1
            c.insert((0, 1)); // tick 2
            c.insert((0, 2)); // tick 3 — all counts equal (1)
            let ev = c.insert((0, 3));
            assert_eq!(ev, vec![(0, 0)]);
        }
    }

    #[test]
    fn eviction_order_is_replay_deterministic() {
        // Same pseudo-random touch/insert sequence twice -> identical
        // eviction streams (ticks are unique, so min_by_key has no
        // HashMap-order-dependent ties).
        let run = |policy: Policy| -> Vec<ExpertKey> {
            let mut c = ExpertCache::new(4, policy);
            let mut out = Vec::new();
            let mut x = 0x9e3779b9u64;
            for _ in 0..200 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let e = (x >> 33) as usize % 12;
                if x % 3 == 0 {
                    c.touch((0, e));
                } else {
                    out.extend(c.insert((0, e)));
                }
            }
            out
        };
        assert_eq!(run(Policy::Lru), run(Policy::Lru));
        assert_eq!(run(Policy::Lfu), run(Policy::Lfu));
    }

    // ---- tiered cache ----

    fn tiered(hot: usize, warm: usize, cold: usize, policy: TierPolicy) -> TieredCache {
        TieredCache::new(&CacheConfig { hot, warm, cold, policy })
    }

    #[test]
    fn disabled_config_is_the_default() {
        assert_eq!(CacheConfig::default(), CacheConfig::disabled());
        assert!(!CacheConfig::disabled().enabled());
        assert!(CacheConfig { hot: 1, ..CacheConfig::disabled() }.enabled());
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [TierPolicy::Lru, TierPolicy::Sieve, TierPolicy::ReuseDistance] {
            assert_eq!(TierPolicy::parse(p.label()).unwrap(), p);
        }
        assert!(TierPolicy::parse("mru").is_err());
    }

    #[test]
    fn install_promotes_and_demotes_down_the_chain() {
        let mut t = tiered(1, 1, 1, TierPolicy::Lru);
        let a = (0, 0);
        let b = (0, 1);
        let c = (0, 2);
        let d = (0, 3);
        assert!(t.install(a, &[]).hot_resident); // hot=[a]
        let inst = t.install(b, &[]); // a demotes to warm
        assert!(inst.hot_resident);
        assert_eq!(inst.evicted_hot, vec![a]);
        assert_eq!(t.lookup(a), Some(TierLevel::CpuWarm));
        let _ = t.install(c, &[]); // b->warm, a->cold
        assert_eq!(t.lookup(a), Some(TierLevel::SsdCold));
        let _ = t.install(d, &[]); // c->warm, b->cold, a drops out
        assert_eq!(t.lookup(a), None);
        assert_eq!(t.lookup(d), Some(TierLevel::GpuHot));
        assert_eq!(t.hot_len() + t.warm_len() + t.cold_len(), 3);
    }

    #[test]
    fn promotion_removes_from_lower_tier() {
        let mut t = tiered(1, 2, 0, TierPolicy::Lru);
        let _ = t.install((0, 0), &[]);
        let _ = t.install((0, 1), &[]); // (0,0) -> warm
        assert_eq!(t.lookup((0, 0)), Some(TierLevel::CpuWarm));
        let _ = t.install((0, 0), &[]); // promote back; (0,1) -> warm
        assert_eq!(t.lookup((0, 0)), Some(TierLevel::GpuHot));
        assert_eq!(t.warm_len(), 1);
        assert!(!t.contains_hot((0, 1)));
    }

    #[test]
    fn reuse_distance_refuses_when_all_protected() {
        let mut t = tiered(2, 1, 0, TierPolicy::ReuseDistance);
        let _ = t.install((1, 0), &[]);
        let _ = t.install((2, 0), &[]);
        let protected = [(1, 0), (2, 0)];
        let inst = t.install((3, 0), &protected);
        assert!(!inst.hot_resident, "all-protected hot tier must refuse admission");
        assert!(inst.evicted_hot.is_empty());
        assert!(t.contains_hot((1, 0)) && t.contains_hot((2, 0)));
        // The refused key still lands in the warm chain.
        assert_eq!(t.lookup((3, 0)), Some(TierLevel::CpuWarm));
    }

    #[test]
    fn sieve_spares_visited_entries() {
        let mut t = tiered(2, 0, 0, TierPolicy::Sieve);
        let _ = t.install((0, 0), &[]);
        let _ = t.install((0, 1), &[]);
        t.lookup((0, 0)); // visited bit on (0,0)
        let inst = t.install((0, 2), &[]);
        assert_eq!(inst.evicted_hot, vec![(0, 1)], "visited (0,0) gets a second chance");
        assert!(t.contains_hot((0, 0)) && t.contains_hot((0, 2)));
    }

    #[test]
    fn drop_all_keeps_stats_reset_clears_them() {
        let mut t = tiered(2, 0, 0, TierPolicy::Lru);
        let _ = t.install((0, 0), &[]);
        t.lookup((0, 0));
        t.lookup((9, 9));
        assert_eq!((t.hot_hits, t.misses), (1, 1));
        t.drop_all();
        assert_eq!(t.hot_len(), 0);
        assert_eq!((t.hot_hits, t.misses), (1, 1), "fail-stop keeps cumulative stats");
        t.reset();
        assert_eq!(t.touches(), 0);
    }
}
