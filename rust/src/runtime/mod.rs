//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client. This is the only place the crate touches `xla`.
//!
//! Python runs only at build time (`make artifacts`); every request-path
//! computation goes through the executables compiled here.

pub mod device;

use std::cell::Cell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::ModelConfig;

pub use device::DeviceModel;

/// Expert-FFN batch sizes the AOT step specialized executables for
/// (must match `python/compile/aot.py::EXPERT_FFN_SIZES`).
pub const EXPERT_FFN_SIZES: [usize; 7] = [1, 4, 8, 16, 32, 64, 128];
/// Prefill prompt lengths with specialized main-block executables.
pub const PREFILL_SIZES: [usize; 2] = [16, 128];

/// Execution counters (perf accounting, EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub executions: Cell<u64>,
    pub host_bytes_uploaded: Cell<u64>,
}

/// Outputs of one `main_block_decode` call (see python/compile/model.py).
#[derive(Debug, Clone)]
pub struct MainBlockOut {
    /// Residual stream leaving attention `[1, d]` — experts add onto this.
    pub x_resid: Vec<f32>,
    /// Post-attention normalized hidden `[1, d]` — shipped to workers.
    pub h_norm: Vec<f32>,
    /// Router softmax weights over the top-k selection `[k]`.
    pub route_w: Vec<f32>,
    /// Selected expert ids `[k]`, descending router weight.
    pub route_idx: Vec<i32>,
    /// New KV rows `[n_kv, head_dim]` to commit into the host cache.
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// Outputs of one prefill main-block call over a T-token prompt.
#[derive(Debug, Clone)]
pub struct PrefillBlockOut {
    pub x_resid: Vec<f32>,  // [T, d]
    pub h_norm: Vec<f32>,   // [T, d]
    pub route_w: Vec<f32>,  // [T, k]
    pub route_idx: Vec<i32>, // [T, k]
    pub k_all: Vec<f32>,    // [T, n_kv, head_dim]
    pub v_all: Vec<f32>,    // [T, n_kv, head_dim]
}

/// The compiled model runtime: PJRT CPU client + one executable per
/// artifact. Cheap to share behind a reference; engines typically hold
/// `&Runtime` plus their own [`DeviceModel`] weight buffers.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub cfg: ModelConfig,
    pub artifact_dir: PathBuf,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Load and compile every artifact under `artifact_dir`.
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let cfg = ModelConfig::load_and_verify(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        let mut names: Vec<String> = vec!["main_block_decode".into(), "lm_head".into()];
        names.extend(EXPERT_FFN_SIZES.iter().map(|t| format!("expert_ffn_t{t}")));
        names.extend(PREFILL_SIZES.iter().map(|t| format!("main_block_prefill_t{t}")));
        for name in names {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name, exe);
        }
        Ok(Self { client, exes, cfg, artifact_dir: dir, stats: RuntimeStats::default() })
    }

    /// Load from the repo-default `artifacts/` directory (next to Cargo.toml).
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("ODMOE_ARTIFACTS")
            .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
        Self::load(dir)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("no executable named {name}"))
    }

    /// Upload an f32 host tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats
            .host_bytes_uploaded
            .set(self.stats.host_bytes_uploaded.get() + (data.len() * 4) as u64);
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Upload an i32 host tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.stats
            .host_bytes_uploaded
            .set(self.stats.host_bytes_uploaded.get() + (data.len() * 4) as u64);
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    /// Execute a named artifact on device buffers, returning the decomposed
    /// output tuple as literals.
    fn run(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        self.stats.executions.set(self.stats.executions.get() + 1);
        let out = exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    fn f32s(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("f32 literal: {e:?}"))
    }

    fn i32s(lit: &xla::Literal) -> Result<Vec<i32>> {
        lit.to_vec::<i32>().map_err(|e| anyhow!("i32 literal: {e:?}"))
    }

    /// Non-expert per-layer decode step (the paper's main-node task `M_l`).
    ///
    /// `layer` indexes into `dm`'s per-layer weight buffers; `x` is the
    /// `[1, d]` residual stream; the KV cache (`[max_seq, n_kv, hd]` each)
    /// holds `pos` valid rows.
    pub fn main_block_decode(
        &self,
        dm: &DeviceModel,
        layer: usize,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        pos: usize,
    ) -> Result<MainBlockOut> {
        let cfg = &self.cfg;
        let xb = self.upload_f32(x, &[1, cfg.d_model])?;
        let kb = self.upload_f32(k_cache, &[cfg.max_seq_len, cfg.n_kv_heads, cfg.head_dim])?;
        let vb = self.upload_f32(v_cache, &[cfg.max_seq_len, cfg.n_kv_heads, cfg.head_dim])?;
        let pb = self.upload_i32(&[pos as i32], &[1])?;
        let lw = &dm.layers[layer];
        let args: Vec<&xla::PjRtBuffer> = vec![
            &xb, &lw.attn_norm, &lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.ffn_norm, &lw.w_gate,
            &kb, &vb, &pb,
        ];
        let out = self.run("main_block_decode", &args)?;
        anyhow::ensure!(out.len() == 6, "main_block_decode: expected 6 outputs");
        Ok(MainBlockOut {
            x_resid: Self::f32s(&out[0])?,
            h_norm: Self::f32s(&out[1])?,
            route_w: Self::f32s(&out[2])?,
            route_idx: Self::i32s(&out[3])?,
            k_new: Self::f32s(&out[4])?,
            v_new: Self::f32s(&out[5])?,
        })
    }

    /// Expert FFN (`EC_l` worker task) for a batch of `t` tokens. `t` must
    /// be one of [`EXPERT_FFN_SIZES`]; `h` is `[t, d]` row-major.
    pub fn expert_ffn(
        &self,
        dm: &DeviceModel,
        layer: usize,
        expert: usize,
        h: &[f32],
        t: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            EXPERT_FFN_SIZES.contains(&t),
            "no expert_ffn executable for t={t}"
        );
        let hb = self.upload_f32(h, &[t, self.cfg.d_model])?;
        let ew = &dm.experts[layer][expert];
        let out = self.run(
            &format!("expert_ffn_t{t}"),
            &[&hb, &ew.w1, &ew.w3, &ew.w2],
        )?;
        Self::f32s(&out[0])
    }

    /// Prefill main block over a `t`-token prompt (t in [`PREFILL_SIZES`]).
    pub fn main_block_prefill(
        &self,
        dm: &DeviceModel,
        layer: usize,
        x: &[f32],
        t: usize,
    ) -> Result<PrefillBlockOut> {
        anyhow::ensure!(
            PREFILL_SIZES.contains(&t),
            "no prefill executable for t={t}"
        );
        let xb = self.upload_f32(x, &[t, self.cfg.d_model])?;
        let lw = &dm.layers[layer];
        let args: Vec<&xla::PjRtBuffer> = vec![
            &xb, &lw.attn_norm, &lw.wq, &lw.wk, &lw.wv, &lw.wo, &lw.ffn_norm, &lw.w_gate,
        ];
        let out = self.run(&format!("main_block_prefill_t{t}"), &args)?;
        anyhow::ensure!(out.len() == 6, "prefill: expected 6 outputs");
        Ok(PrefillBlockOut {
            x_resid: Self::f32s(&out[0])?,
            h_norm: Self::f32s(&out[1])?,
            route_w: Self::f32s(&out[2])?,
            route_idx: Self::i32s(&out[3])?,
            k_all: Self::f32s(&out[4])?,
            v_all: Self::f32s(&out[5])?,
        })
    }

    /// Final norm + logits + greedy argmax. Returns `(logits[vocab], token)`.
    pub fn lm_head(&self, dm: &DeviceModel, x: &[f32]) -> Result<(Vec<f32>, u32)> {
        let xb = self.upload_f32(x, &[1, self.cfg.d_model])?;
        let out = self.run("lm_head", &[&xb, &dm.final_norm, &dm.w_out])?;
        let logits = Self::f32s(&out[0])?;
        let tok = Self::i32s(&out[1])?[0];
        anyhow::ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab_size);
        Ok((logits, tok as u32))
    }
}
