//! Device-resident weight buffers.
//!
//! Uploading every weight matrix once per [`crate::runtime::Runtime`] user
//! and reusing the `PjRtBuffer`s across all calls keeps the per-step host
//! traffic down to activations + KV cache (~130 KiB) instead of re-shipping
//! ~180 KiB of weights per layer call — the single biggest L3 hot-path win
//! (EXPERIMENTS.md §Perf).

use anyhow::Result;

use crate::model::{ModelConfig, WeightStore};
use crate::runtime::Runtime;

/// Per-layer non-expert weights on device.
pub struct DeviceLayer {
    pub attn_norm: xla::PjRtBuffer,
    pub wq: xla::PjRtBuffer,
    pub wk: xla::PjRtBuffer,
    pub wv: xla::PjRtBuffer,
    pub wo: xla::PjRtBuffer,
    pub ffn_norm: xla::PjRtBuffer,
    pub w_gate: xla::PjRtBuffer,
}

/// One expert's weights on device.
pub struct DeviceExpert {
    pub w1: xla::PjRtBuffer,
    pub w3: xla::PjRtBuffer,
    pub w2: xla::PjRtBuffer,
}

/// A full [`WeightStore`] uploaded to the PJRT device.
///
/// Note this is a *numerics* convenience: whether an expert is "loaded" on
/// a simulated node's GPU is tracked by the cluster simulator's memory
/// ledgers, not by this struct — CPU PJRT has no real VRAM to meter.
pub struct DeviceModel {
    pub layers: Vec<DeviceLayer>,
    pub experts: Vec<Vec<DeviceExpert>>,
    pub final_norm: xla::PjRtBuffer,
    pub w_out: xla::PjRtBuffer,
}

impl DeviceModel {
    /// Upload every tensor of `ws` to the device.
    pub fn upload(rt: &Runtime, ws: &WeightStore) -> Result<Self> {
        let c: &ModelConfig = &ws.cfg;
        let mut layers = Vec::with_capacity(c.n_layers);
        let mut experts = Vec::with_capacity(c.n_layers);
        for l in 0..c.n_layers {
            let lw = &ws.layers[l];
            layers.push(DeviceLayer {
                attn_norm: rt.upload_f32(&lw.attn_norm, &[c.d_model])?,
                wq: rt.upload_f32(&lw.wq, &[c.d_model, c.q_dim()])?,
                wk: rt.upload_f32(&lw.wk, &[c.d_model, c.kv_dim()])?,
                wv: rt.upload_f32(&lw.wv, &[c.d_model, c.kv_dim()])?,
                wo: rt.upload_f32(&lw.wo, &[c.q_dim(), c.d_model])?,
                ffn_norm: rt.upload_f32(&lw.ffn_norm, &[c.d_model])?,
                w_gate: rt.upload_f32(&lw.w_gate, &[c.d_model, c.n_experts])?,
            });
            let mut le = Vec::with_capacity(c.n_experts);
            for e in 0..c.n_experts {
                let ew = &ws.experts[l][e];
                le.push(DeviceExpert {
                    w1: rt.upload_f32(&ew.w1, &[c.d_model, c.d_ff])?,
                    w3: rt.upload_f32(&ew.w3, &[c.d_model, c.d_ff])?,
                    w2: rt.upload_f32(&ew.w2, &[c.d_ff, c.d_model])?,
                });
            }
            experts.push(le);
        }
        Ok(Self {
            layers,
            experts,
            final_norm: rt.upload_f32(&ws.final_norm, &[c.d_model])?,
            w_out: rt.upload_f32(&ws.w_out, &[c.d_model, c.vocab_size])?,
        })
    }
}
