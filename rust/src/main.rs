//! `od-moe` CLI — leader entrypoint for the OD-MoE reproduction.
//!
//! Subcommands map 1:1 onto the paper's evaluation (see DESIGN.md §5):
//!
//! ```text
//! od-moe serve      [--requests N] [--rate R] [--rates R1,R2,..]   load-test serving
//!                   [--policy fcfs|sjf|edf] [--replicas N] [--max-batch N]
//!                   [--arrival poisson|bursty|trace|closed]
//!                   [--slo-ttft-ms MS] [--slo-tpot-ms MS] [--tenants N]
//!                   [--preempt-ms MS] [--mem-gb G]
//!                   [--batch-sweep [--batches B1,B2,..] [--distinct-prompts]]
//!                   [--fail worker3@500,shadow@800] [--fail-replica 0@500]
//!                   [--failover-sweep [--max-failed K] [--fail-at-ms MS]]
//! od-moe decode     [--out-tokens N] [--chunks K] [--prefetch-depth D]
//!                   [--overlap-sweep [--chunks K1,K2,..] [--depths D1,D2,..]]
//!                                                     chunked-streaming decode (§9)
//! od-moe recall     [--prompts N] [--out-tokens N]    SEP recall curves (Fig. 3/6)
//! od-moe speed      [--prompts N] [--out-tokens N]    decoding speed (Fig. 8/9/10)
//! od-moe predictors [--prompts N] [--out-tokens N]    Table 1 comparison
//! od-moe quality    [--prompts N] [--out-tokens N]    Table 2(iii) fidelity
//! od-moe memory                                       Table 2(ii) GPU-memory audit
//!
//! global flags: --artifacts DIR   --seed N
//!
//! `serve --rates 0.5,2,8` sweeps OD-MoE against the fully-cached
//! baseline and writes `BENCH_serve.json` (see `examples/load_test.rs`);
//! `serve --batch-sweep` sweeps batched decode over batch size x arrival
//! rate and writes `BENCH_batch.json` (batch 1 = the sequential
//! baseline); `serve --failover-sweep` decodes under 0..=K fail-stopped
//! workers and writes `BENCH_failover.json` (DESIGN.md §8);
//! `decode --overlap-sweep` sweeps chunked expert streaming over chunk
//! count x prefetch depth and writes `BENCH_overlap.json` (chunks 1 =
//! the monolithic baseline, DESIGN.md §9).
//! ```

use anyhow::{bail, Result};
use odmoe::util::cli::Args;

mod cli;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.subcommand.clone() else {
        eprintln!("usage: od-moe <serve|decode|recall|speed|predictors|quality|memory> [--flags]");
        bail!("missing subcommand");
    };
    let seed = args.u64_or("seed", 42)?;
    if cmd == "memory" {
        // No runtime needed for the static memory audit.
        return cli::memory();
    }
    let rt = match args.get("artifacts") {
        Some(dir) => odmoe::Runtime::load(dir)?,
        None => odmoe::Runtime::load_default()?,
    };
    match cmd.as_str() {
        "serve" => cli::serve(&rt, seed, &args),
        "decode" => cli::decode(&rt, seed, &args),
        "recall" => cli::recall(&rt, seed, &args),
        "speed" => cli::speed(&rt, seed, &args),
        "predictors" => cli::predictors(&rt, seed, &args),
        "quality" => cli::quality(&rt, seed, &args),
        other => bail!("unknown subcommand {other:?}"),
    }
}
