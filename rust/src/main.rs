//! `od-moe` CLI — leader entrypoint for the OD-MoE reproduction.
//!
//! Subcommands map 1:1 onto the paper's evaluation (see DESIGN.md §5);
//! run `od-moe help` for the full flag table. Usage text is *generated*
//! from the `COMMANDS` table below and every invocation validates its
//! flags against the same table (`Args::validate_against`), so the
//! four PRs' accumulated sweep flags (`--rates`, `--batch-sweep`,
//! `--fail*`, `--chunks`, `--overlap-sweep`, `--fleet`/`--plan`) cannot
//! drift from the parser: a flag missing from the table errors out
//! loudly instead of being silently ignored.
//!
//! Artifacts the sweep subcommands write: `BENCH_serve.json`
//! (`serve --rates`), `BENCH_batch.json` (`serve --batch-sweep`),
//! `BENCH_failover.json` (`serve --failover-sweep`), `BENCH_overlap.json`
//! (`decode --overlap-sweep`), `BENCH_cache.json` (`serve --cache-sweep`,
//! DESIGN.md §12), `BENCH_scale.json` (`serve --scale-sweep`,
//! DESIGN.md §13), `BENCH_plan.json` (`plan`, DESIGN.md §10),
//! `BENCH_attrib.json` (`serve --attribution`), `ATTRIB.json`
//! (`decode --attribution`), `BENCH_precision.json`
//! (`serve --precision-sweep`, DESIGN.md §14), `BENCH_autoscale.json`
//! (`serve --autoscale-sweep`, DESIGN.md §15), `BENCH_perf.json`
//! (`bench`), and `METRICS_<cmd>.jsonl` (`--metrics`, DESIGN.md §11).

use anyhow::{bail, Result};
use odmoe::util::cli::{render_usage, Args, CommandSpec, Flag};

mod cli;

const fn val(name: &'static str, value: &'static str, help: &'static str) -> Flag {
    Flag { name, value: Some(value), help }
}

const fn switch(name: &'static str, help: &'static str) -> Flag {
    Flag { name, value: None, help }
}

const GLOBAL_FLAGS: &[Flag] = &[
    val("artifacts", "DIR", "AOT artifact directory (default ./artifacts)"),
    val("seed", "N", "deterministic seed (default 42)"),
    switch("help", "print this flag table"),
];

/// Workload + scheduler flags shared by `serve` and `plan` (parsed by
/// `serve::config_from_args`). Kept as a macro expanding to flag rows so
/// the two subcommands' tables cannot diverge.
macro_rules! workload_flags {
    () => {
        [
            val("requests", "N", "arrivals to generate (default 24)"),
            val("prompts", "N", "legacy alias for --requests"),
            val("rate", "R", "offered arrival rate, req/s (default 2)"),
            val("arrival-gap-ms", "MS", "legacy: fixed gap instead of --rate"),
            val("arrival", "KIND", "poisson|bursty|trace|diurnal|closed (default poisson)"),
            val("clients", "N", "closed-loop client count (default 4)"),
            val("think-ms", "MS", "closed-loop think time (default 500)"),
            val("input-len", "N", "fixed prompt length (default bimodal 16/128)"),
            val("out-tokens", "N", "decode tokens per request (default 16)"),
            val("slo-ttft-ms", "MS", "TTFT SLO budget, raw virtual ms (default 1000)"),
            val("slo-tpot-ms", "MS", "TPOT SLO budget, raw virtual ms (default 150)"),
            val("tenants", "N", "SLO classes: 1 or 2 (default 1)"),
            val("policy", "P", "queue policy fcfs|sjf|edf (default fcfs)"),
            val("replicas", "N", "engine replica slots (default 1)"),
            val("mem-gb", "G", "per-replica admission ledger (default 24)"),
            val("preempt-ms", "MS", "preemption budget (default off)"),
            val("max-batch", "N", "co-scheduled sessions per dispatch (default 1)"),
            switch("shared-prompt", "every request decodes one shared prompt"),
            val("fail-replica", "R@MS", "fail-stop scheduler replicas, e.g. 0@500"),
            val("core", "KIND", "scheduler executor event|round-loop (default event)"),
            val("queue-sample", "N", "queue-depth trace stride (default 1 = every tick)"),
            val("threads", "N", "worker threads for sweep cells (default 1)"),
            val("control", "M", "SLO control loop off|reactive (default off, §15)"),
            val("control-epoch", "MS", "controller epoch length (default 200)"),
            val("control-target-p99", "MS", "controller p99 TTFT target (default 300)"),
            val("control-max-replicas", "N", "controller fleet ceiling (default 8)"),
        ]
    };
    (+ $($extra:expr),* $(,)?) => {{
        const W: [Flag; 26] = workload_flags!();
        const E: &[Flag] = &[$($extra),*];
        const N: usize = W.len() + E.len();
        const OUT: [Flag; N] = {
            let mut out = [Flag { name: "", value: None, help: "" }; N];
            let mut i = 0;
            while i < W.len() {
                out[i] = W[i];
                i += 1;
            }
            let mut j = 0;
            while j < E.len() {
                out[W.len() + j] = E[j];
                j += 1;
            }
            out
        };
        &OUT
    }};
}

const SERVE_FLAGS: &[Flag] = workload_flags![+
    val("shadow", "P", "shadow precision fp16|int8|nf4 (default int8)"),
    val("token-period", "N", "SEP token-alignment period (default 1)"),
    val("kv-period", "N", "SEP KV-alignment period (default 1)"),
    val("chunks", "K", "expert transfer chunks (default 1 = monolithic)"),
    val("prefetch-depth", "D", "speculative staging depth (default 0)"),
    val("rates", "R1,R2,..", "rate sweep vs fully-cached; writes BENCH_serve.json"),
    switch("batch-sweep", "batch x rate sweep; writes BENCH_batch.json"),
    val("batches", "B1,B2,..", "batch sizes for --batch-sweep (default 1,2,4,8)"),
    switch("distinct-prompts", "batch sweep without the shared prompt"),
    val("fail", "SPEC", "engine faults, e.g. worker3@500,shadow@800ms"),
    switch("failover-sweep", "decode under 0..=K dead workers; BENCH_failover.json"),
    val("max-failed", "K", "failover sweep ceiling (default min(workers-1, 4))"),
    val("fail-at-ms", "MS", "failover sweep fault instant (default 0)"),
    val("fleet", "SPEC", "heterogeneous fleet, e.g. rtx3080:4,jetson:4,nano:2"),
    val("plan", "FILE", "run the deployment chosen in BENCH_plan.json"),
    switch("attribution", "per-rate attribution sweep; writes BENCH_attrib.json"),
    val("cache-hot", "N", "GPU-hot tier budget, expert slots (default 0 = cacheless)"),
    val("cache-warm", "N", "CPU-warm tier budget, expert slots (default 0)"),
    val("cache-cold", "N", "SSD-cold tier budget, expert slots (default 0)"),
    val("cache-policy", "P", "eviction policy lru|sieve|reuse (default lru)"),
    switch("cache-sweep", "hot-budget sweep; writes BENCH_cache.json (§12)"),
    val("cache-grid", "H1,H2,..", "budgets for --cache-sweep (default 0,1,2,4,8)"),
    val("precision-policy", "P", "runtime load precision static|slack|slack-importance (§14)"),
    switch("precision-skip", "let hopeless deadlines skip low-weight experts (honest drift)"),
    switch("precision-sweep", "policy x fleet x rate frontier; writes BENCH_precision.json"),
    val("precision-grid", "P1,P2,..", "policies for --precision-sweep (static always included)"),
    val("precision-fleets", "F1|F2", "fleets for --precision-sweep, | separated (uniform = base)"),
    switch("scale-sweep", "session-count scaling sweep; writes BENCH_scale.json (§13)"),
    val("scale-sessions", "N1,N2,..", "sizes for --scale-sweep (default 1000,10000,100000,1000000)"),
    val("scale-round-cap", "N", "largest size the round-loop oracle also runs (default 10000)"),
    switch("omit-wall", "drop wall-clock fields from BENCH_scale.json (determinism diffs)"),
    switch("autoscale-sweep", "drift scenarios x {static,reactive}; writes BENCH_autoscale.json (§15)"),
    switch("metrics", "export the metrics registry to METRICS_serve.jsonl"),
];

const DECODE_FLAGS: &[Flag] = &[
    val("out-tokens", "N", "decode tokens (default 24)"),
    val("shadow", "P", "shadow precision fp16|int8|nf4 (default int8)"),
    val("chunks", "K", "transfer chunks; with --overlap-sweep a K1,K2,.. list"),
    val("prefetch-depth", "D", "speculative staging depth (default 0)"),
    switch("overlap-sweep", "chunk x depth sweep; writes BENCH_overlap.json"),
    val("depths", "D1,D2,..", "depths for --overlap-sweep (default 0,1)"),
    val("fleet", "SPEC", "heterogeneous fleet, e.g. rtx3080:4,jetson:4,nano:2"),
    val("plan", "FILE", "decode on the deployment chosen in BENCH_plan.json"),
    switch("attribution", "per-token critical-path table; writes ATTRIB.json"),
    val("cache-hot", "N", "GPU-hot tier budget, expert slots (default 0 = cacheless)"),
    val("cache-warm", "N", "CPU-warm tier budget, expert slots (default 0)"),
    val("cache-cold", "N", "SSD-cold tier budget, expert slots (default 0)"),
    val("cache-policy", "P", "eviction policy lru|sieve|reuse (default lru)"),
    switch("metrics", "export the metrics registry to METRICS_decode.jsonl"),
];

const BENCH_FLAGS: &[Flag] = &[
    switch("ci", "gate the virtual section against the baseline; nonzero on regression"),
    switch("write-baseline", "pin the current virtual section as the committed baseline"),
    val("baseline", "FILE", "baseline path (default rust/benches/perf_baseline.json)"),
    val("band", "F", "relative noise band for --ci (default 0.02)"),
    val("out", "FILE", "output path (default BENCH_perf.json)"),
    val("samples", "N", "wall-clock invocations per microbench (default 7)"),
    val("iters", "N", "iterations per invocation (default 100)"),
];

const EVAL_FLAGS: &[Flag] = &[
    val("prompts", "N", "prompt count"),
    val("out-tokens", "N", "decode tokens per prompt"),
];

const MEMORY_FLAGS: &[Flag] = &[
    val("fleet", "SPEC", "audit a heterogeneous fleet instead of the presets"),
    val("precision", "P", "transfer precision for the fleet audit (default fp16)"),
    val("max-batch", "N", "batched residency bound for the fleet audit (default 1)"),
    val("prefetch-depth", "D", "staging depth for the fleet audit (default 0)"),
    val("cache-hot", "N", "GPU-hot cache slots added to the bound (default 0)"),
];

const PLAN_FLAGS: &[Flag] = workload_flags![+
    val("fleet", "SPEC", "fleet to plan over (default rtx3080:4,jetson:4,nano:2)"),
    val("slo-p99", "MS", "target p99 TPOT, raw virtual ms (default 250)"),
    val("precisions", "P1,P2,..", "transfer precisions to search (default fp16,int8,nf4)"),
    val("chunk-grid", "K1,K2,..", "chunk counts to search (default 1,8)"),
    val("depth-grid", "D1,D2,..", "prefetch depths to search (default 0,1)"),
    val("replica-grid", "R1,R2,..", "replica counts to search (default 1)"),
    val("cache-grid", "H1,H2,..", "GPU-hot cache budgets to search (default 0)"),
    val("policy-grid", "P1,P2,..", "runtime precision policies to search (default static)"),
    switch("metrics", "export planner + engine metrics to METRICS_plan.jsonl"),
];

const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "serve",
        summary: "load-test serving through the continuous scheduler",
        flags: SERVE_FLAGS,
    },
    CommandSpec {
        name: "decode",
        summary: "chunked-streaming decode (DESIGN.md §9)",
        flags: DECODE_FLAGS,
    },
    CommandSpec {
        name: "plan",
        summary: "SLO-driven fleet deployment planner; writes BENCH_plan.json (§10)",
        flags: PLAN_FLAGS,
    },
    CommandSpec {
        name: "recall",
        summary: "SEP recall curves (Fig. 3/6)",
        flags: EVAL_FLAGS,
    },
    CommandSpec {
        name: "speed",
        summary: "decoding speed comparison (Fig. 8/9/10)",
        flags: EVAL_FLAGS,
    },
    CommandSpec {
        name: "predictors",
        summary: "predictor comparison (Table 1)",
        flags: EVAL_FLAGS,
    },
    CommandSpec {
        name: "quality",
        summary: "output fidelity (Table 2(iii))",
        flags: EVAL_FLAGS,
    },
    CommandSpec {
        name: "memory",
        summary: "GPU-memory audit (Table 2(ii)); --fleet for a class audit",
        flags: MEMORY_FLAGS,
    },
    CommandSpec {
        name: "bench",
        summary: "perf benches + regression gate; writes BENCH_perf.json (§11)",
        flags: BENCH_FLAGS,
    },
];

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.subcommand.clone() else {
        if args.has("help") {
            print!("{}", render_usage(COMMANDS, GLOBAL_FLAGS));
            return Ok(());
        }
        eprint!("{}", render_usage(COMMANDS, GLOBAL_FLAGS));
        bail!("missing subcommand");
    };
    if cmd == "help" {
        print!("{}", render_usage(COMMANDS, GLOBAL_FLAGS));
        return Ok(());
    }
    let Some(spec) = COMMANDS.iter().find(|c| c.name == cmd) else {
        eprint!("{}", render_usage(COMMANDS, GLOBAL_FLAGS));
        bail!("unknown subcommand {cmd:?}");
    };
    if args.has("help") {
        print!("{}", spec.usage());
        return Ok(());
    }
    args.validate_against(spec, GLOBAL_FLAGS)?;
    let seed = args.u64_or("seed", 42)?;
    if cmd == "memory" {
        // No runtime needed for the static memory audit.
        return cli::memory(&args);
    }
    if cmd == "bench" {
        // Runtime-free: virtual-time metrics + wall-clock microbenches.
        return cli::bench(&args);
    }
    if cmd == "serve" && args.has("scale-sweep") {
        // Runtime-free: the scale sweep drives the synthetic service only
        // (measuring an engine 10^6 times would swamp the scheduler cost
        // under test), so skip the PJRT artifact load entirely.
        return cli::scale(seed, &args);
    }
    if cmd == "serve" && args.has("autoscale-sweep") {
        // Runtime-free for the same reason: the autoscale sweep compares
        // the static fleet against the control loop on the synthetic
        // service, where drift effects dominate engine detail.
        return cli::autoscale(seed, &args);
    }
    let rt = match args.get("artifacts") {
        Some(dir) => odmoe::Runtime::load(dir)?,
        None => odmoe::Runtime::load_default()?,
    };
    match cmd.as_str() {
        "serve" => cli::serve(&rt, seed, &args),
        "decode" => cli::decode(&rt, seed, &args),
        "plan" => cli::plan(&rt, seed, &args),
        "recall" => cli::recall(&rt, seed, &args),
        "speed" => cli::speed(&rt, seed, &args),
        "predictors" => cli::predictors(&rt, seed, &args),
        "quality" => cli::quality(&rt, seed, &args),
        other => bail!("unknown subcommand {other:?}"),
    }
}
