//! CLI subcommand implementations. Each maps onto one of the paper's
//! evaluations; the benches under `rust/benches/` reuse the same library
//! harnesses with the full parameter grids.

use anyhow::{bail, Result};
use odmoe::cluster::HardwareProfile;
use odmoe::coordinator::baselines::{CpuEngine, FullyCachedEngine, OffloadConfig, OffloadEngine};
use odmoe::coordinator::{Engine, OdMoeConfig, OdMoeEngine, Request, Server};
use odmoe::metrics::memory as memaudit;
use odmoe::model::{Precision, WeightStore};
use odmoe::predictor::{AlignmentConfig, GateLookahead, MultiLayerGate, RandomPredictor, Statistical};
use odmoe::util::cli::Args;
use odmoe::util::table::{sparkline, Table};
use odmoe::workload::{fidelity, recall, speed, Corpus};
use odmoe::Runtime;

fn parse_precision(s: &str) -> Result<Precision> {
    Ok(match s {
        "fp32" => Precision::Fp32,
        "fp16" => Precision::Fp16,
        "int8" => Precision::Int8,
        "nf4" => Precision::Nf4,
        other => bail!("unknown precision {other:?} (fp32|fp16|int8|nf4)"),
    })
}

fn parse_period(s: &str) -> Result<usize> {
    if s == "inf" || s == "never" {
        return Ok(usize::MAX);
    }
    Ok(s.parse()?)
}

/// `od-moe serve`: end-to-end OD-MoE serving through the FCFS request
/// server (requests arrive at `--arrival-gap-ms` intervals).
pub fn serve(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 4)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let input_len = a.usize_or("input-len", 16)?;
    let gap = a.f64_or("arrival-gap-ms", 100.0)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let cfg = OdMoeConfig {
        shadow_precision: parse_precision(a.get_or("shadow", "int8"))?,
        align: AlignmentConfig {
            token_period: parse_period(a.get_or("token-period", "1"))?,
            kv_period: parse_period(a.get_or("kv-period", "1"))?,
        },
        ..OdMoeConfig::default()
    };
    let mut engine = OdMoeEngine::new(rt, ws, cfg)?;
    println!("engine: {}", engine.name());
    let corpus = Corpus::generate(seed, prompts, input_len, rt.cfg.vocab_size as u32);

    let mut server = Server::new(&mut engine);
    for (i, prompt) in corpus.prompts.iter().enumerate() {
        server.submit(Request {
            id: i as u64,
            prompt: prompt.clone(),
            out_tokens,
            arrival_ms: i as f64 * gap,
        });
    }
    let (done, stats) = server.run()?;

    let mut t = Table::new(&["req", "queued (ms)", "ttft (ms)", "total (ms)", "stall (ms)", "tokens"]);
    for c in &done {
        let toks: Vec<String> = c.tokens.iter().take(8).map(|t| t.to_string()).collect();
        t.row(&[
            format!("#{}", c.id),
            format!("{:.1}", c.queued_ms),
            format!("{:.1}", c.ttft_ms),
            format!("{:.1}", c.total_ms),
            format!("{:.1}", c.stall_ms),
            format!("{}…", toks.join(" ")),
        ]);
    }
    t.print();
    println!(
        "\nserved {} requests | {} tokens | {:.2} tok/s end-to-end | mean queue {:.1} ms | p95 latency {:.1} ms",
        stats.served,
        stats.total_tokens,
        stats.tokens_per_s(),
        stats.mean_queue_ms,
        stats.p95_total_ms
    );
    Ok(())
}

/// `od-moe recall`: Fig. 3-style recall curves.
pub fn recall(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 8)?;
    let out_tokens = a.usize_or("out-tokens", 64)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 1, prompts, 16, rt.cfg.vocab_size as u32);
    let precisions = [Precision::Fp16, Precision::Int8, Precision::Nf4];
    let aligns = [
        ("unaligned", AlignmentConfig::none()),
        ("token-only", AlignmentConfig::token_only()),
        ("token+kv", AlignmentConfig::every_iteration()),
    ];
    let mut t = Table::new(&["shadow", "alignment", "recall (Eq.3)", "curve"]);
    for p in precisions {
        for (label, align) in aligns {
            let stats = recall::sep_recall(rt, &ws, p, align, &corpus, out_tokens)?;
            t.row(&[
                p.label().to_string(),
                label.to_string(),
                format!("{:.4}", stats.recall()),
                sparkline(&stats.curve()),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// `od-moe speed`: decode-speed comparison across engines (Table 2(i) core).
pub fn speed(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 2)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let l = rt.cfg.n_layers;

    let mut rows: Vec<(String, speed::SpeedCell)> = Vec::new();
    {
        let mut e = FullyCachedEngine::new(rt, ws.clone())?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push(("transformers".into(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    {
        let mut e = OdMoeEngine::new(rt, ws.clone(), OdMoeConfig::default())?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push((e.name(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    for cfg in [
        OffloadConfig::mixtral_offloading(l),
        OffloadConfig::moe_infinity(l),
        OffloadConfig::hobbit(l),
        OffloadConfig::adapmoe(l),
    ] {
        let name = cfg.system.to_string();
        let mut e = OffloadEngine::new(rt, ws.clone(), cfg)?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push((name, speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    {
        let mut e = CpuEngine::new(rt, ws)?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push(("llama.cpp".into(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }

    let mut t = Table::new(&["engine", "ttft ms (paper-scale)", "decode tok/s", "output tok/s"]);
    for (name, cell) in &rows {
        t.row(&[
            name.clone(),
            format!("{:.0}", cell.scaled.mean_ttft_ms()),
            format!("{:.3}", cell.scaled.decode_tps()),
            format!("{:.3}", cell.scaled.output_tps()),
        ]);
    }
    t.print();
    println!("\npaper Table 2 decode averages: transformers 4.89, od-moe 3.69, adapmoe 3.13,");
    println!("mixtral-offloading 2.24, llama.cpp 0.82, hobbit 0.79, moe-infinity 0.69 tok/s");
    Ok(())
}

/// `od-moe predictors`: Table 1 comparison.
pub fn predictors(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 4)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 3, prompts, 16, rt.cfg.vocab_size as u32);
    let cfg = &rt.cfg;

    let mut t = Table::new(&["predictor", "recall", "lookahead", "paper ref"]);
    let mut add = |name: &str, r: f64, look: String, paper: &str| {
        t.row(&[name.to_string(), format!("{r:.4}"), look, paper.to_string()]);
    };

    let mut gl = GateLookahead::new(&ws);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut gl, &corpus, out_tokens)?;
    add("gate-lookahead (AdapMoE/DAOP)", r, "1".into(), "0.86 / 0.84");

    let mut ml = MultiLayerGate::new(&ws, 4);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut ml, &corpus, out_tokens)?;
    add("multi-layer gate (HOBBIT)", r, "4".into(), "0.91");

    let mut st = Statistical::new(cfg.n_layers, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut st, &corpus, out_tokens)?;
    add("statistical (EdgeMoE/fMoE)", r, "any".into(), "~0.80-0.85 (hit rate)");

    let mut rp = RandomPredictor::new(seed, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut rp, &corpus, out_tokens)?;
    add("random (control)", r, "any".into(), "k/E = 0.25");

    for p in [Precision::Fp16, Precision::Int8, Precision::Nf4] {
        let stats = recall::sep_recall(
            rt,
            &ws,
            p,
            AlignmentConfig::every_iteration(),
            &corpus,
            out_tokens,
        )?;
        let paper = match p {
            Precision::Fp16 => "0.9994",
            Precision::Int8 => "0.9734",
            _ => "0.9567",
        };
        add(
            &format!("SEP {} (ours)", p.label()),
            stats.recall(),
            "full model".into(),
            paper,
        );
    }
    t.print();
    Ok(())
}

/// `od-moe quality`: Table 2(iii) output-fidelity comparison.
pub fn quality(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 4)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 4, prompts, 16, rt.cfg.vocab_size as u32);
    let reference = fidelity::reference(rt, &ws, &corpus, out_tokens)?;
    let l = rt.cfg.n_layers;

    let mut t = Table::new(&["engine", "token match", "mean KL", "diverged prompts"]);
    let mut eval = |name: &str, engine: &mut dyn Engine| -> Result<()> {
        let fid = fidelity::evaluate(engine, &reference, &corpus, out_tokens)?;
        let div = fid.first_divergence.iter().filter(|d| d.is_some()).count();
        t.row(&[
            name.to_string(),
            format!("{:.4}", fid.token_match_rate()),
            format!("{:.6}", fid.mean_kl()),
            format!("{div}/{}", corpus.prompts.len()),
        ]);
        Ok(())
    };

    let mut od = OdMoeEngine::new(rt, ws.clone(), OdMoeConfig::default())?;
    eval("od-moe (full precision)", &mut od)?;
    for cfg in [
        OffloadConfig::moe_infinity(l),
        OffloadConfig::mixtral_offloading(l),
        OffloadConfig::hobbit(l),
        OffloadConfig::adapmoe(l),
    ] {
        let name = cfg.system.to_string();
        let mut e = OffloadEngine::new(rt, ws.clone(), cfg)?;
        eval(&name, &mut e)?;
    }
    t.print();
    println!("\n(paper Table 2(iii): OD-MoE matches Transformers on all benchmarks;");
    println!(" quantizing/skipping baselines lose accuracy across the board)");
    Ok(())
}

/// `od-moe memory`: Table 2(ii) audit.
pub fn memory() -> Result<()> {
    let p = HardwareProfile::rtx3090();
    let mut t = Table::new(&["system", "GPU memory (GB)", "paper (GB)"]);
    let audits = [
        (memaudit::odmoe(&p, 8), "60"),
        (memaudit::fully_cached(&p), "180"),
        (memaudit::offloading("mixtral-offloading", &p, 64, 0.143, 0.35), "11"),
        (memaudit::offloading("moe-infinity", &p, 42, 0.5, 0.35), "21.5"),
        (memaudit::offloading("hobbit", &p, 110, 0.25, 0.35), "22"),
        (memaudit::offloading("adapmoe", &p, 52, 0.143, 0.35), "8"),
        (memaudit::cpu_only(), "N/A"),
    ];
    for (audit, paper) in audits {
        t.row(&[
            audit.system.to_string(),
            format!("{:.1}", audit.total_gb()),
            paper.to_string(),
        ]);
    }
    t.print();
    println!();
    let od = memaudit::odmoe(&p, 8);
    for (node, bytes) in &od.per_node {
        println!("  od-moe {node}: {:.2} GB", bytes / 1e9);
    }
    Ok(())
}
