//! CLI subcommand implementations. Each maps onto one of the paper's
//! evaluations; the benches under `rust/benches/` reuse the same library
//! harnesses with the full parameter grids.

use anyhow::{ensure, Context, Result};
use odmoe::cache::{CacheConfig, TierPolicy};
use odmoe::cluster::{Cluster, HardwareProfile, NodeClass};
use odmoe::control::{classify, ControlConfig, ControlState, EpochObservation, Pressure};
use odmoe::coordinator::baselines::{CpuEngine, FullyCachedEngine, OffloadConfig, OffloadEngine};
use odmoe::coordinator::{
    BatchEngine, Engine, FailureSpec, OdMoeConfig, OdMoeEngine, PrecisionController,
    PrecisionPolicy,
};
use odmoe::fleet::{planner, FleetSpec, PlanChoice, PlanGrid, PlanMeasurement};
use odmoe::metrics::memory as memaudit;
use odmoe::model::{Precision, WeightStore};
use odmoe::predictor::{
    AlignPeriod, AlignmentConfig, GateLookahead, MultiLayerGate, RandomPredictor, Statistical,
};
use odmoe::serve::{
    attrib_json, attribution_sweep, autoscale_json, autoscale_sweep, batch_sweep, batch_sweep_json,
    cache_json, cache_sweep, config_from_args, failover_json, failover_sweep, overlap_json,
    overlap_sweep, parse_batches,
    parse_cache_budgets, parse_chunk_counts, parse_depths, parse_fleet_grid, parse_policy_grid,
    parse_rates, parse_scale_sessions, precision_json, precision_sweep, rate_sweep, run_streamed,
    scale_json, scale_sweep, scale_workload, sweep_json, write_bench, ArrivalModel, AttribPoint,
    BatchEngineService, BatchPoint, CachePoint, FailoverPoint, Histogram, OverlapPoint,
    PrecisionCell, PrecisionMeasurement, Scheduler, SchedulerConfig, ServeReport, ServiceModel,
    SessionOutcome, SyntheticService, WorkloadSpec, SCALE_SAMPLE_CAP,
};
use odmoe::telemetry::{self, Phase, Registry};
use odmoe::trace::EventKind;
use odmoe::util::bench as bench_util;
use odmoe::util::cli::Args;
use odmoe::util::json::{num, obj, Json};
use odmoe::util::table::{sparkline, Table};
use odmoe::workload::{fidelity, recall, speed, Corpus};
use odmoe::Runtime;

fn parse_precision(s: &str) -> Result<Precision> {
    Precision::parse(s)
}

/// Parse the tiered-cache flags (`--cache-hot/--cache-warm/--cache-cold`
/// slot budgets + `--cache-policy lru|sieve|reuse`) into a
/// [`CacheConfig`]. All budgets default to 0 — the cacheless seed engine
/// (DESIGN.md §12's budget-0 bit-identity contract).
fn parse_cache_flags(a: &Args) -> Result<CacheConfig> {
    Ok(CacheConfig {
        hot: a.usize_or("cache-hot", 0)?,
        warm: a.usize_or("cache-warm", 0)?,
        cold: a.usize_or("cache-cold", 0)?,
        policy: TierPolicy::parse(a.get_or("cache-policy", "lru"))?,
    })
}

/// Apply `--fleet <spec>` / `--plan <file>` to an engine config (+ the
/// scheduler's replica count for a plan): the one place the two flags
/// are interpreted, shared by `serve` and `decode` so a chosen plan runs
/// identically through either. A plan supplies the fleet and transfer
/// precision unconditionally, but its chunks/depth/replicas/runtime
/// precision policy are *defaults*: an explicitly passed `--chunks`/
/// `--prefetch-depth`/`--replicas`/`--precision-policy` wins, so
/// overriding one knob of a plan does not silently discard the flag.
/// Returns a banner describing what was applied.
fn apply_fleet_flags(
    a: &Args,
    cfg: &mut OdMoeConfig,
    replicas: Option<&mut usize>,
) -> Result<Option<String>> {
    anyhow::ensure!(
        !(a.has("plan") && a.get("plan").is_none()),
        "--plan needs a file path (e.g. --plan BENCH_plan.json)"
    );
    anyhow::ensure!(
        !(a.has("fleet") && a.get("fleet").is_none()),
        "--fleet needs a spec (e.g. --fleet rtx3080:4,jetson:4,nano:2)"
    );
    match (a.get("plan"), a.get("fleet")) {
        (Some(_), Some(_)) => anyhow::bail!("--plan and --fleet are mutually exclusive"),
        (Some(path), None) => {
            let choice = PlanChoice::load(std::path::Path::new(path))?;
            cfg.profile = choice.scaled_profile(&cfg.profile);
            if a.get("chunks").is_none() {
                cfg.chunks = choice.chunks;
            }
            if a.get("prefetch-depth").is_none() {
                cfg.prefetch_depth = choice.prefetch_depth;
            }
            if a.get("cache-hot").is_none() {
                cfg.cache.hot = choice.cache_hot;
            }
            if a.get("precision-policy").is_none() {
                cfg.precision_policy = choice.policy;
            }
            cfg.n_workers = choice.fleet.n_nodes();
            let cache_note = if choice.cache_hot > 0 {
                format!(" | hot cache {}", choice.cache_hot)
            } else {
                String::new()
            };
            let policy_note = if choice.policy == PrecisionPolicy::Static {
                String::new()
            } else {
                format!(" | runtime {}", choice.policy.label())
            };
            let banner = format!(
                "plan: fleet {} | {} transfers{policy_note} | chunks {} | depth {}{cache_note} | {} replica(s) | claimed p99 tpot {:.1} ms",
                choice.fleet.label(),
                choice.precision.label(),
                choice.chunks,
                choice.prefetch_depth,
                choice.replicas,
                choice.claimed_tpot_p99_ms,
            );
            cfg.fleet = Some(choice.fleet);
            if let Some(r) = replicas {
                if a.get("replicas").is_none() {
                    *r = choice.replicas;
                }
            }
            Ok(Some(banner))
        }
        (None, Some(spec)) => {
            let fleet = FleetSpec::parse(spec)?;
            cfg.n_workers = fleet.n_nodes();
            let banner = format!("fleet: {}", fleet.label());
            cfg.fleet = Some(fleet);
            Ok(Some(banner))
        }
        (None, None) => Ok(None),
    }
}

fn parse_period(s: &str) -> Result<AlignPeriod> {
    if s == "inf" || s == "never" {
        return Ok(AlignPeriod::Never);
    }
    let n: usize = s.parse()?;
    ensure!(n >= 1, "alignment period must be >= 1 (or inf/never), got {n}");
    Ok(AlignPeriod::Every(n))
}

/// Export a registry as `METRICS_<source>.jsonl` (the one JSONL schema
/// shared by `decode`, `serve`, and `plan` — DESIGN.md §11).
fn write_metrics(source: &str, reg: &Registry) -> Result<()> {
    let path = format!("METRICS_{source}.jsonl");
    std::fs::write(&path, reg.export_jsonl(source)).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

/// Reject out-of-range `--fail worker<N>` targets with a CLI error
/// before they reach the engine's (programmer-facing) asserts.
fn validate_failures(specs: &[FailureSpec], n_workers: usize) -> Result<()> {
    for f in specs {
        if let FailureSpec::Worker { worker, .. } = f {
            anyhow::ensure!(
                *worker < n_workers,
                "--fail worker{worker} out of range (cluster has {n_workers} workers)"
            );
        }
    }
    Ok(())
}

/// `od-moe serve`: load-test OD-MoE through the continuous scheduler.
/// One rate by default; `--rates 0.5,2,8` sweeps OD-MoE against the
/// fully-cached baseline and writes `BENCH_serve.json`; `--batch-sweep`
/// sweeps `--batches` x `--rates` with batched dispatch and writes
/// `BENCH_batch.json` (requests share one prompt unless
/// `--distinct-prompts` — shared routing is where load amortization is
/// maximal). `--max-batch N` batches any of the other modes.
///
/// Failure injection (DESIGN.md §8): `--fail worker3@500,shadow@800ms`
/// fail-stops engine nodes on the virtual clock (tokens never change;
/// only timing degrades), `--fail-replica 0@500` fail-stops a scheduler
/// replica (its sessions re-queue), and `--failover-sweep` decodes one
/// session at 0..=`--max-failed` dead workers and writes the
/// deterministic `BENCH_failover.json`.
///
/// Fleets (DESIGN.md §10): `--fleet rtx3080:4,jetson:4,nano:2` serves on
/// a heterogeneous cluster (per-class durations, capability-aware
/// slots); `--plan BENCH_plan.json` re-runs the deployment `od-moe plan`
/// chose — fleet, transfer precision, chunks, depth, cache budget, and
/// replicas.
///
/// Tiered cache (DESIGN.md §12): `--cache-hot/--cache-warm/--cache-cold`
/// give each worker GPU-hot / CPU-warm / SSD-cold residency budgets
/// under `--cache-policy lru|sieve|reuse` (all 0 = the cacheless seed
/// engine, bit-identical tokens AND timings); `--cache-sweep` decodes
/// one session at every `--cache-grid` GPU-hot budget and writes the
/// deterministic `BENCH_cache.json`.
///
/// Runtime mixed precision (DESIGN.md §14): `--precision-policy
/// static|slack|slack-importance` selects per-load transfer precision
/// from deadline slack and routing importance (`static` = the seed
/// engine, bit-identical tokens AND timings); `--precision-skip` lets a
/// hopeless deadline honestly skip the least-important expert;
/// `--precision-sweep` decodes every `--precision-grid` policy x
/// `--precision-fleets` fleet x `--rates` rate and writes the
/// deterministic `BENCH_precision.json` speed-vs-quality frontier.
pub fn serve(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let (mut spec, mut sched, rate) = config_from_args(a, rt.cfg.vocab_size as u32)?;
    let threads = a.usize_or("threads", 1)?;
    anyhow::ensure!(threads >= 1, "--threads must be >= 1, got {threads}");
    if threads > 1 {
        // Engine-backed sweeps measure through one mutable engine
        // instance, so their cells are inherently serial; the runtime-free
        // `--scale-sweep` path (dispatched before the artifact load) is
        // where `--threads` buys wall-clock.
        println!("note: --threads parallelizes --scale-sweep; engine-backed sweeps run serially");
    }
    let ws = WeightStore::generate(&rt.cfg, seed);
    let mut cfg = OdMoeConfig {
        shadow_precision: parse_precision(a.get_or("shadow", "int8"))?,
        align: AlignmentConfig {
            token_period: parse_period(a.get_or("token-period", "1"))?,
            kv_period: parse_period(a.get_or("kv-period", "1"))?,
        },
        chunks: a.usize_or("chunks", 1)?,
        prefetch_depth: a.usize_or("prefetch-depth", 0)?,
        cache: parse_cache_flags(a)?,
        precision_policy: PrecisionPolicy::parse(a.get_or("precision-policy", "static"))?,
        precision_skip: a.has("precision-skip"),
        ..OdMoeConfig::default()
    };
    anyhow::ensure!(cfg.chunks >= 1, "--chunks must be >= 1");
    // `--fleet rtx3080:4,jetson:4,nano:2` runs on a heterogeneous
    // fleet; `--plan BENCH_plan.json` re-runs the planner's chosen
    // deployment (fleet + precision + chunks + depth + replicas).
    if let Some(banner) = apply_fleet_flags(a, &mut cfg, Some(&mut sched.n_replicas))? {
        println!("{banner}");
    }

    if a.has("failover-sweep") {
        let max_failed = a.usize_or("max-failed", (cfg.n_workers - 1).min(4))?;
        anyhow::ensure!(
            max_failed < cfg.n_workers,
            "--max-failed {max_failed} leaves no survivor among {} workers",
            cfg.n_workers
        );
        let fail_at = a.f64_or("fail-at-ms", 0.0)?;
        let out_tokens = a.usize_or("out-tokens", 16)?;
        // A `--fail` plan is a fixed fault background for every sweep
        // point (including the k = 0 baseline); the sweep kills workers
        // 0..k on top of it.
        let background = match a.get("fail") {
            Some(s) => FailureSpec::parse_list(s)?,
            None => Vec::new(),
        };
        validate_failures(&background, cfg.n_workers)?;
        let mut doomed: Vec<usize> = background
            .iter()
            .filter_map(|f| match f {
                FailureSpec::Worker { worker, .. } => Some(*worker),
                FailureSpec::Shadow { .. } => None,
            })
            .collect();
        doomed.extend(0..max_failed);
        doomed.sort_unstable();
        doomed.dedup();
        anyhow::ensure!(
            doomed.len() < cfg.n_workers,
            "--failover-sweep plus --fail would leave no surviving worker among {}",
            cfg.n_workers
        );
        let prompt = Corpus::generate(seed ^ 5, 1, 16, rt.cfg.vocab_size as u32)
            .prompts
            .pop()
            .expect("one prompt");
        let points = failover_sweep(max_failed, |k| {
            let mut e = OdMoeEngine::new(rt, ws.clone(), cfg.clone())?;
            for &f in &background {
                e.inject_failure(f);
            }
            for w in 0..k {
                e.inject_failure(FailureSpec::Worker { worker: w, at_ms: fail_at });
            }
            e.run_batch(&[(prompt.as_slice(), out_tokens)])
        })?;
        print_failover(&points);
        let path = std::path::Path::new("BENCH_failover.json");
        write_bench(
            path,
            &failover_json(&points, seed, cfg.n_workers, rt.cfg.top_k, fail_at, out_tokens),
        )?;
        println!("\nwrote {}", path.display());
        return Ok(());
    }

    // `--cache-sweep` (DESIGN.md §12): decode one session at every
    // GPU-hot budget in `--cache-grid` (budget 0 — the cacheless seed
    // engine — is always present as the pin) on a fresh engine per
    // point, report ms/token and loads/token against the cacheless
    // baseline and the fully-cached ceiling, and write the deterministic
    // `BENCH_cache.json` locating the crossover between pure OD-MoE,
    // tiered residency, and a fully-cached deployment. `--cache-warm`/
    // `--cache-cold`/`--cache-policy` shape the non-swept tiers; a
    // `--fail` plan is a fixed fault background for every point.
    if a.has("cache-sweep") {
        let budgets = parse_cache_budgets(a.get_or("cache-grid", "0,1,2,4,8"))?;
        let out_tokens = a.usize_or("out-tokens", 16)?;
        let background = match a.get("fail") {
            Some(s) => FailureSpec::parse_list(s)?,
            None => Vec::new(),
        };
        validate_failures(&background, cfg.n_workers)?;
        let prompt = Corpus::generate(seed ^ 8, 1, 16, rt.cfg.vocab_size as u32)
            .prompts
            .pop()
            .expect("one prompt");
        // Fully-cached ceiling on the same session (never cache-tiered).
        let fc_ms_per_token = {
            let mut e = FullyCachedEngine::new(rt, ws.clone())?;
            let res = e.run_batch(&[(prompt.as_slice(), out_tokens)])?;
            res.sessions[0].decode_ms / res.decode_tokens as f64
        };
        let points = cache_sweep(&budgets, fc_ms_per_token, |budget| {
            // Budget 0 is the cacheless engine itself — no tiers at all,
            // not a zero-capacity cache — so the pin really compares
            // against the seed code path.
            let cache = if budget == 0 {
                CacheConfig::disabled()
            } else {
                CacheConfig { hot: budget, ..cfg.cache }
            };
            let mut e = OdMoeEngine::new(rt, ws.clone(), OdMoeConfig { cache, ..cfg.clone() })?;
            for &f in &background {
                e.inject_failure(f);
            }
            e.run_batch(&[(prompt.as_slice(), out_tokens)])
        })?;
        print_cache(&points);
        let fleet_label = cfg
            .fleet
            .as_ref()
            .map_or_else(|| format!("uniform:{}", cfg.n_workers), |f| f.label());
        let path = std::path::Path::new("BENCH_cache.json");
        write_bench(
            path,
            &cache_json(
                &points,
                seed,
                &budgets,
                &fleet_label,
                cfg.cache.policy.label(),
                out_tokens,
                fc_ms_per_token,
            ),
        )?;
        println!("\nwrote {}", path.display());
        return Ok(());
    }

    // `--precision-sweep` (DESIGN.md §14): decode every (fleet x rate x
    // policy) cell's workload on a fresh engine — the whole request set
    // as one co-scheduled batch — and report the speed-vs-quality
    // frontier of runtime mixed-precision expert loading: ms/token,
    // per-tier load counts, skip/upgrade counts, accrued quality debt,
    // and fidelity against the single-node full-precision reference on a
    // fixed corpus. The `static` cell of each (fleet, rate) is the seed
    // engine itself (no controller is built), so every speedup is read
    // against the bit-identical baseline; `tokens_match_static` makes
    // token drift (possible only via honest `--precision-skip` skips)
    // explicit in `BENCH_precision.json`.
    if a.has("precision-sweep") {
        let policies =
            parse_policy_grid(a.get_or("precision-grid", "static,slack,slack-importance"))?;
        let fleets = parse_fleet_grid(a.get_or("precision-fleets", "uniform|jetson:4,nano:2"))?;
        let rates = parse_rates(a.get_or("rates", "2"))?;
        let out_tokens = a.usize_or("out-tokens", 8)?;
        let skip = a.has("precision-skip");
        // One fixed corpus + single-node reference for every cell, so
        // fidelity deltas are attributable to the policy alone.
        let corpus = Corpus::generate(seed ^ 11, 2, 16, rt.cfg.vocab_size as u32);
        let reference = fidelity::reference(rt, &ws, &corpus, out_tokens)?;
        let cells = precision_sweep(&fleets, &policies, &rates, |fleet, policy, rate| {
            let mut c = cfg.clone();
            c.precision_policy = policy;
            c.precision_skip = skip;
            if fleet == "uniform" {
                c.fleet = None;
            } else {
                let f = FleetSpec::parse(fleet)?;
                c.n_workers = f.n_nodes();
                c.fleet = Some(f);
            }
            let mut e = OdMoeEngine::new(rt, ws.clone(), c)?;
            let reqs = spec.with_rate(rate).generate(seed);
            let batch: Vec<(&[u32], usize)> =
                reqs.iter().map(|r| (r.prompt.as_slice(), r.out_tokens)).collect();
            let res = e.run_batch(&batch)?;
            let reg = e.registry();
            // The static engine builds no controller and ticks no tier
            // counters; its loads all stream at the deployed precision
            // (tier 0) by construction.
            let loads = if policy == PrecisionPolicy::Static {
                [res.expert_loads, 0, 0]
            } else {
                [
                    reg.counter("engine.loads_fp16"),
                    reg.counter("engine.loads_int8"),
                    reg.counter("engine.loads_nf4"),
                ]
            };
            let skipped_experts = reg.counter("engine.skipped_experts");
            let upgrade_reloads = reg.counter("engine.upgrade_reloads");
            let quality_debt_frac = reg.gauge("engine.quality_debt_frac").unwrap_or(0.0);
            let fid = fidelity::evaluate(&mut e, &reference, &corpus, out_tokens)?;
            Ok(PrecisionMeasurement {
                decode_ms: res.decode_span_ms,
                decode_tokens: res.decode_tokens,
                loads,
                skipped_experts,
                upgrade_reloads,
                quality_debt_frac,
                token_match_rate: fid.token_match_rate(),
                mean_kl: fid.mean_kl(),
                tokens: res.sessions.first().map(|s| s.tokens.clone()).unwrap_or_default(),
            })
        })?;
        print_precision(&cells);
        let path = std::path::Path::new("BENCH_precision.json");
        write_bench(
            path,
            &precision_json(&cells, seed, &fleets, &policies, &rates, out_tokens),
        )?;
        println!("\nwrote {}", path.display());
        return Ok(());
    }

    // `--attribution` (DESIGN.md §11): decode every rate's workload on a
    // trace-enabled engine (the whole request set as one co-scheduled
    // batch, so per-iteration spans cover all sessions), attribute each
    // token's time to its binding phase, and aggregate per rate into the
    // deterministic `BENCH_attrib.json`.
    if a.has("attribution") {
        let rates = parse_rates(a.get_or("rates", "2"))?;
        ensure!(
            !matches!(spec.model, ArrivalModel::ClosedLoop { .. }) || rates.len() <= 1,
            "closed-loop workloads are self-clocked: attribute one rate or use an open-loop \
             arrival model"
        );
        let mut e = OdMoeEngine::new(rt, ws.clone(), cfg)?;
        if let Some(s) = a.get("fail") {
            let specs = FailureSpec::parse_list(s)?;
            validate_failures(&specs, e.cfg.n_workers)?;
            for f in specs {
                e.inject_failure(f);
            }
        }
        e.enable_trace();
        let points = attribution_sweep(&rates, |rate| {
            let reqs = spec.with_rate(rate).generate(seed);
            let batch: Vec<(&[u32], usize)> =
                reqs.iter().map(|r| (r.prompt.as_slice(), r.out_tokens)).collect();
            e.run_batch(&batch)?;
            let attrib = telemetry::attribute(&e.cluster.trace, e.token_spans());
            Ok((reqs.len(), attrib))
        })?;
        print_attrib(&points);
        let fleet_label = e
            .cfg
            .fleet
            .as_ref()
            .map_or_else(|| format!("uniform:{}", e.cfg.n_workers), |f| f.label());
        let path = std::path::Path::new("BENCH_attrib.json");
        write_bench(path, &attrib_json(&points, seed, &fleet_label))?;
        println!("\nwrote {}", path.display());
        if a.has("metrics") {
            write_metrics("serve", e.registry())?;
        }
        return Ok(());
    }

    let mut engine = OdMoeEngine::new(rt, ws.clone(), cfg)?;
    if let Some(s) = a.get("fail") {
        let specs = FailureSpec::parse_list(s)?;
        validate_failures(&specs, engine.cfg.n_workers)?;
        for f in specs {
            engine.inject_failure(f);
        }
    }

    if a.has("batch-sweep") {
        let batches = parse_batches(a.get_or("batches", "1,2,4,8"))?;
        let rates = parse_rates(a.get_or("rates", "2,8"))?;
        spec.shared_prompt = !a.has("distinct-prompts");
        let mut baseline = FullyCachedEngine::new(rt, ws)?;
        let mut od_svc = BatchEngineService::new(&mut engine);
        let mut ref_svc = BatchEngineService::new(&mut baseline);
        let mut systems: Vec<(String, &mut dyn ServiceModel)> =
            vec![("od-moe".into(), &mut od_svc), ("transformers".into(), &mut ref_svc)];
        let results = batch_sweep(&mut systems, &spec, &batches, &rates, &sched, seed)?;
        print_batch_sweep(&results);
        let path = std::path::Path::new("BENCH_batch.json");
        write_bench(path, &batch_sweep_json(&results, &spec, &batches, &rates, &sched, seed))?;
        println!("\nwrote {}", path.display());
        if a.has("metrics") {
            write_metrics("serve", engine.registry())?;
        }
        return Ok(());
    }

    if let Some(rates) = a.get("rates") {
        let rates = parse_rates(rates)?;
        let mut baseline = FullyCachedEngine::new(rt, ws)?;
        let mut od_svc = BatchEngineService::new(&mut engine);
        let mut ref_svc = BatchEngineService::new(&mut baseline);
        let mut systems: Vec<(String, &mut dyn ServiceModel)> =
            vec![("od-moe".into(), &mut od_svc), ("transformers".into(), &mut ref_svc)];
        let results = rate_sweep(&mut systems, &spec, &rates, &sched, seed)?;
        print_sweep(&results);
        let path = std::path::Path::new("BENCH_serve.json");
        write_bench(path, &sweep_json(&results, &spec, &rates, &sched, seed))?;
        println!("\nwrote {}", path.display());
        if a.has("metrics") {
            write_metrics("serve", engine.registry())?;
        }
        return Ok(());
    }

    println!(
        "engine: {} | policy {} | {} replica(s) | max batch {} | {} arrivals @ {:.2} req/s",
        engine.name(),
        sched.policy.label(),
        sched.n_replicas,
        sched.max_batch,
        spec.model.label(),
        rate
    );
    let reqs = spec.generate(seed);
    let mut service = BatchEngineService::new(&mut engine);
    let outcome = Scheduler::run(&sched, &mut service, &reqs)?;
    let names: Vec<String> = spec.tenants.iter().map(|t| t.name.clone()).collect();
    let report = ServeReport::from_outcome("od-moe", rate, &outcome, &names);

    let mut t = Table::new(&[
        "req", "tenant", "queued (ms)", "ttft (ms)", "e2e (ms)", "tok", "outcome", "slo",
    ]);
    for r in &outcome.records {
        t.row(&[
            format!("#{}", r.id),
            names.get(r.tenant).cloned().unwrap_or_default(),
            format!("{:.1}", r.queued_ms()),
            r.ttft_ms().map_or("-".into(), |v| format!("{v:.1}")),
            format!("{:.1}", r.e2e_ms()),
            format!("{}/{}", r.tokens.len(), r.requested_tokens),
            match r.outcome {
                SessionOutcome::Completed => "ok".into(),
                SessionOutcome::Preempted => "preempted".into(),
                SessionOutcome::Rejected => "REJECTED".into(),
            },
            if r.slo_met() { "met".into() } else { "miss".to_string() },
        ]);
    }
    t.print();
    println!(
        "\nserved {}/{} | {:.2} tok/s | goodput {:.2} tok/s | slo {:.0}% | ttft p50/p95/p99 = {:.0}/{:.0}/{:.0} ms | mean queue depth {:.2}",
        report.completed,
        report.offered,
        report.throughput_tok_s,
        report.goodput_tok_s,
        report.slo_attainment * 100.0,
        report.ttft.p50,
        report.ttft.p95,
        report.ttft.p99,
        report.mean_queue_depth,
    );
    if a.has("metrics") {
        // Engine-level counters plus the scheduler's outcome metrics, one
        // merged export: the registry is the shared vocabulary.
        let mut reg = engine.registry().clone();
        reg.counter_add("scheduler.offered", report.offered as u64);
        reg.counter_add("scheduler.completed", report.completed as u64);
        reg.gauge_set("scheduler.goodput_tok_s", report.goodput_tok_s);
        reg.gauge_set("scheduler.slo_attainment", report.slo_attainment);
        for r in &outcome.records {
            reg.observe("scheduler.e2e_ms", r.e2e_ms());
        }
        write_metrics("serve", &reg)?;
    }
    Ok(())
}

/// The `serve --attribution` per-rate summary table.
fn print_attrib(points: &[AttribPoint]) {
    let mut t = Table::new(&["rate req/s", "sessions", "tokens", "token ms", "bound", "share"]);
    for p in points {
        let bound = p.bound();
        let total = p.total_ms();
        let share = if total > 0.0 { p.phase_ms[bound.idx()] / total } else { 0.0 };
        t.row(&[
            format!("{:.2}", p.rate),
            format!("{}", p.sessions),
            format!("{}", p.tokens),
            format!("{:.1}", total),
            bound.name().to_string(),
            format!("{:.0}%", 100.0 * share),
        ]);
    }
    t.print();
}

fn print_failover(points: &[FailoverPoint]) {
    let mut t = Table::new(&[
        "failed workers", "decode (ms)", "slowdown", "stall (ms)", "loads/token", "failovers",
        "tokens",
    ]);
    for p in points {
        t.row(&[
            format!("{}", p.failed_workers),
            format!("{:.1}", p.decode_ms),
            format!("{:.3}x", p.slowdown),
            format!("{:.1}", p.stall_ms),
            format!("{:.2}", p.loads_per_token),
            format!("{}", p.failovers),
            if p.tokens_match_healthy { "identical".into() } else { "DIVERGED".to_string() },
        ]);
    }
    t.print();
}

fn print_batch_sweep(results: &[(String, Vec<BatchPoint>)]) {
    let mut t = Table::new(&[
        "system", "max batch", "rate req/s", "tok/s", "goodput tok/s", "ttft p95", "p99 tpot",
        "loads/token", "mean batch",
    ]);
    for (name, points) in results {
        for p in points {
            let (loads, mean_b) = match &p.stats {
                Some(s) => {
                    (format!("{:.2}", s.loads_per_token()), format!("{:.2}", s.mean_batch()))
                }
                None => ("-".into(), "-".into()),
            };
            t.row(&[
                name.clone(),
                format!("{}", p.max_batch),
                format!("{:.2}", p.report.rate_per_s),
                format!("{:.2}", p.report.throughput_tok_s),
                format!("{:.2}", p.report.goodput_tok_s),
                format!("{:.0}", p.report.ttft.p95),
                format!("{:.0}", p.report.tpot.p99),
                loads,
                mean_b,
            ]);
        }
    }
    t.print();
}

fn print_cache(points: &[CachePoint]) {
    let mut t = Table::new(&[
        "hot budget", "ms/token", "of fully-cached", "loads/token", "stall (ms)", "tokens",
    ]);
    for p in points {
        t.row(&[
            format!("{}", p.budget),
            format!("{:.2}", p.ms_per_token),
            format!("{:.1}%", p.frac_of_fully_cached * 100.0),
            format!("{:.2}", p.loads_per_token),
            format!("{:.1}", p.stall_ms),
            if p.tokens_match_baseline { "identical".into() } else { "DIVERGED".to_string() },
        ]);
    }
    t.print();
}

fn print_precision(cells: &[PrecisionCell]) {
    let mut t = Table::new(&[
        "fleet", "rate", "policy", "ms/token", "vs static", "fp16/int8/nf4", "skips", "upgrades",
        "debt", "match %", "mean KL", "tokens",
    ]);
    for c in cells {
        t.row(&[
            c.fleet.clone(),
            format!("{:.2}", c.rate),
            c.policy.label().to_string(),
            format!("{:.2}", c.ms_per_token),
            format!("{:.3}x", c.speedup_vs_static),
            format!("{}/{}/{}", c.meas.loads[0], c.meas.loads[1], c.meas.loads[2]),
            format!("{}", c.meas.skipped_experts),
            format!("{}", c.meas.upgrade_reloads),
            format!("{:.4}", c.meas.quality_debt_frac),
            format!("{:.1}", c.meas.token_match_rate * 100.0),
            format!("{:.4}", c.meas.mean_kl),
            if c.tokens_match_static { "identical".into() } else { "DIVERGED".to_string() },
        ]);
    }
    t.print();
}

fn print_sweep(results: &[(String, Vec<ServeReport>)]) {
    let mut t = Table::new(&[
        "system", "rate req/s", "tok/s", "goodput tok/s", "slo %", "ttft p50", "ttft p95",
        "ttft p99", "p99 tpot",
    ]);
    for (name, points) in results {
        for p in points {
            t.row(&[
                name.clone(),
                format!("{:.2}", p.rate_per_s),
                format!("{:.2}", p.throughput_tok_s),
                format!("{:.2}", p.goodput_tok_s),
                format!("{:.0}", p.slo_attainment * 100.0),
                format!("{:.0}", p.ttft.p50),
                format!("{:.0}", p.ttft.p95),
                format!("{:.0}", p.ttft.p99),
                format!("{:.0}", p.tpot.p99),
            ]);
        }
    }
    t.print();
}

/// `od-moe decode`: single-session decode under chunked expert streaming
/// (DESIGN.md §9). By default runs one session at `--chunks K`
/// `--prefetch-depth D` and prints ms/token against the fully-cached
/// ceiling; `--overlap-sweep` sweeps `--chunks 1,2,4,8` x `--depths 0,1`
/// and writes the deterministic `BENCH_overlap.json` (the monolithic
/// chunks-1/depth-0 point is bit-identical — tokens AND timings — to the
/// pre-chunking engine; every point's token stream is checked against
/// it). Baseline engines are untouched by chunking, so the
/// fraction-of-fully-cached comparison stays fair.
/// `--cache-hot/--cache-warm/--cache-cold/--cache-policy` enable the
/// tiered expert cache (DESIGN.md §12) and print its hit/miss tallies.
pub fn decode(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let out_tokens = a.usize_or("out-tokens", 24)?;
    anyhow::ensure!(out_tokens >= 2, "--out-tokens must be >= 2 to measure decode");
    let ws = WeightStore::generate(&rt.cfg, seed);
    let prompt = Corpus::generate(seed ^ 6, 1, 16, rt.cfg.vocab_size as u32)
        .prompts
        .pop()
        .expect("one prompt");
    let mut base_cfg = OdMoeConfig {
        shadow_precision: parse_precision(a.get_or("shadow", "int8"))?,
        cache: parse_cache_flags(a)?,
        ..OdMoeConfig::default()
    };
    anyhow::ensure!(
        !(a.has("overlap-sweep") && a.has("plan")),
        "--overlap-sweep sweeps chunks/depths itself; run --plan without it"
    );
    anyhow::ensure!(
        !(a.has("overlap-sweep") && a.has("attribution")),
        "--attribution attributes the single-session decode; run it without --overlap-sweep"
    );
    if let Some(banner) = apply_fleet_flags(a, &mut base_cfg, None)? {
        println!("{banner}");
    }

    // Fully-cached ceiling on the same session (untouched by chunking).
    let fc_ms_per_token = {
        let mut e = FullyCachedEngine::new(rt, ws.clone())?;
        let res = e.run_batch(&[(prompt.as_slice(), out_tokens)])?;
        res.sessions[0].decode_ms / res.decode_tokens as f64
    };

    if a.has("overlap-sweep") {
        let chunk_counts = parse_chunk_counts(a.get_or("chunks", "1,2,4,8"))?;
        let depths = parse_depths(a.get_or("depths", "0,1"))?;
        let points = overlap_sweep(&chunk_counts, &depths, fc_ms_per_token, |chunks, depth| {
            let cfg = OdMoeConfig { chunks, prefetch_depth: depth, ..base_cfg.clone() };
            let mut e = OdMoeEngine::new(rt, ws.clone(), cfg)?;
            e.run_batch(&[(prompt.as_slice(), out_tokens)])
        })?;
        print_overlap(&points);
        let path = std::path::Path::new("BENCH_overlap.json");
        write_bench(
            path,
            &overlap_json(&points, seed, &chunk_counts, &depths, out_tokens, fc_ms_per_token),
        )?;
        println!("\nwrote {}", path.display());
        return Ok(());
    }

    // Defaults fall back to the base config so a `--plan`'s chunk count
    // and staging depth survive unless explicitly overridden.
    let cfg = OdMoeConfig {
        chunks: a.usize_or("chunks", base_cfg.chunks)?,
        prefetch_depth: a.usize_or("prefetch-depth", base_cfg.prefetch_depth)?,
        ..base_cfg
    };
    anyhow::ensure!(cfg.chunks >= 1, "--chunks must be >= 1");
    let mut e = OdMoeEngine::new(rt, ws, cfg)?;
    if a.has("attribution") {
        e.enable_trace();
    }
    let name = e.name();
    let res = e.run_batch(&[(prompt.as_slice(), out_tokens)])?;
    let s = &res.sessions[0];
    let ms_per_token = s.decode_ms / res.decode_tokens as f64;
    println!(
        "{name}: {:.2} ms/token ({:.1}% of fully-cached) | stall {:.1} ms | \
         {:.2} loads/token | {} aborted stream(s)",
        ms_per_token,
        100.0 * fc_ms_per_token / ms_per_token,
        s.stall_ms,
        res.loads_per_token(),
        res.aborted_loads,
    );
    if e.cfg.cache.enabled() {
        let (hot, warm, cold, misses) = e.cache_stats();
        println!("cache: {hot} hot / {warm} warm / {cold} cold hit(s), {misses} miss(es)");
    }
    // `--attribution` (DESIGN.md §11): walk the trace and print the exact
    // per-token time decomposition (phases partition each token's
    // latency; the critical path partitions the makespan).
    if a.has("attribution") {
        let attrib = telemetry::attribute(&e.cluster.trace, e.token_spans());
        print!("{}", attrib.render_table());
        let path = std::path::Path::new("ATTRIB.json");
        write_bench(path, &attrib.to_json())?;
        println!("wrote {}", path.display());
    }
    if a.has("metrics") {
        write_metrics("decode", e.registry())?;
    }
    Ok(())
}

fn print_overlap(points: &[OverlapPoint]) {
    let mut t = Table::new(&[
        "chunks", "prefetch depth", "ms/token", "of fully-cached", "stall (ms)", "aborts",
        "tokens",
    ]);
    for p in points {
        t.row(&[
            format!("{}", p.chunks),
            format!("{}", p.prefetch_depth),
            format!("{:.2}", p.ms_per_token),
            format!("{:.1}%", p.frac_of_fully_cached * 100.0),
            format!("{:.1}", p.stall_ms),
            format!("{}", p.aborted_loads),
            if p.tokens_match_baseline { "identical".into() } else { "DIVERGED".to_string() },
        ]);
    }
    t.print();
}

/// `od-moe recall`: Fig. 3-style recall curves.
pub fn recall(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 8)?;
    let out_tokens = a.usize_or("out-tokens", 64)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 1, prompts, 16, rt.cfg.vocab_size as u32);
    let precisions = [Precision::Fp16, Precision::Int8, Precision::Nf4];
    let aligns = [
        ("unaligned", AlignmentConfig::none()),
        ("token-only", AlignmentConfig::token_only()),
        ("token+kv", AlignmentConfig::every_iteration()),
    ];
    let mut t = Table::new(&["shadow", "alignment", "recall (Eq.3)", "curve"]);
    for p in precisions {
        for (label, align) in aligns {
            let stats = recall::sep_recall(rt, &ws, p, align, &corpus, out_tokens)?;
            t.row(&[
                p.label().to_string(),
                label.to_string(),
                format!("{:.4}", stats.recall()),
                sparkline(&stats.curve()),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// `od-moe speed`: decode-speed comparison across engines (Table 2(i) core).
pub fn speed(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 2)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let l = rt.cfg.n_layers;

    let mut rows: Vec<(String, speed::SpeedCell)> = Vec::new();
    {
        let mut e = FullyCachedEngine::new(rt, ws.clone())?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push(("transformers".into(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    {
        let mut e = OdMoeEngine::new(rt, ws.clone(), OdMoeConfig::default())?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push((e.name(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    for cfg in [
        OffloadConfig::mixtral_offloading(l),
        OffloadConfig::moe_infinity(l),
        OffloadConfig::hobbit(l),
        OffloadConfig::adapmoe(l),
    ] {
        let name = cfg.system.to_string();
        let mut e = OffloadEngine::new(rt, ws.clone(), cfg)?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push((name, speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }
    {
        let mut e = CpuEngine::new(rt, ws)?;
        let corpus = Corpus::generate(seed ^ 2, prompts, 16, rt.cfg.vocab_size as u32);
        rows.push(("llama.cpp".into(), speed::run_speed_cell(&mut e, &corpus, out_tokens)?));
    }

    let mut t = Table::new(&["engine", "ttft ms (paper-scale)", "decode tok/s", "output tok/s"]);
    for (name, cell) in &rows {
        t.row(&[
            name.clone(),
            format!("{:.0}", cell.scaled.mean_ttft_ms()),
            format!("{:.3}", cell.scaled.decode_tps()),
            format!("{:.3}", cell.scaled.output_tps()),
        ]);
    }
    t.print();
    println!("\npaper Table 2 decode averages: transformers 4.89, od-moe 3.69, adapmoe 3.13,");
    println!("mixtral-offloading 2.24, llama.cpp 0.82, hobbit 0.79, moe-infinity 0.69 tok/s");
    Ok(())
}

/// `od-moe predictors`: Table 1 comparison.
pub fn predictors(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 4)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 3, prompts, 16, rt.cfg.vocab_size as u32);
    let cfg = &rt.cfg;

    let mut t = Table::new(&["predictor", "recall", "lookahead", "paper ref"]);
    let mut add = |name: &str, r: f64, look: String, paper: &str| {
        t.row(&[name.to_string(), format!("{r:.4}"), look, paper.to_string()]);
    };

    let mut gl = GateLookahead::new(&ws);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut gl, &corpus, out_tokens)?;
    add("gate-lookahead (AdapMoE/DAOP)", r, "1".into(), "0.86 / 0.84");

    let mut ml = MultiLayerGate::new(&ws, 4);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut ml, &corpus, out_tokens)?;
    add("multi-layer gate (HOBBIT)", r, "4".into(), "0.91");

    let mut st = Statistical::new(cfg.n_layers, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut st, &corpus, out_tokens)?;
    add("statistical (EdgeMoE/fMoE)", r, "any".into(), "~0.80-0.85 (hit rate)");

    let mut rp = RandomPredictor::new(seed, cfg.n_experts, cfg.top_k);
    let (r, _) = recall::baseline_recall(rt, &ws, &mut rp, &corpus, out_tokens)?;
    add("random (control)", r, "any".into(), "k/E = 0.25");

    for p in [Precision::Fp16, Precision::Int8, Precision::Nf4] {
        let stats = recall::sep_recall(
            rt,
            &ws,
            p,
            AlignmentConfig::every_iteration(),
            &corpus,
            out_tokens,
        )?;
        let paper = match p {
            Precision::Fp16 => "0.9994",
            Precision::Int8 => "0.9734",
            _ => "0.9567",
        };
        add(
            &format!("SEP {} (ours)", p.label()),
            stats.recall(),
            "full model".into(),
            paper,
        );
    }
    t.print();
    Ok(())
}

/// `od-moe quality`: Table 2(iii) output-fidelity comparison.
pub fn quality(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let prompts = a.usize_or("prompts", 4)?;
    let out_tokens = a.usize_or("out-tokens", 32)?;
    let ws = WeightStore::generate(&rt.cfg, seed);
    let corpus = Corpus::generate(seed ^ 4, prompts, 16, rt.cfg.vocab_size as u32);
    let reference = fidelity::reference(rt, &ws, &corpus, out_tokens)?;
    let l = rt.cfg.n_layers;

    let mut t = Table::new(&["engine", "token match", "mean KL", "diverged prompts"]);
    let mut eval = |name: &str, engine: &mut dyn Engine| -> Result<()> {
        let fid = fidelity::evaluate(engine, &reference, &corpus, out_tokens)?;
        let div = fid.first_divergence.iter().filter(|d| d.is_some()).count();
        t.row(&[
            name.to_string(),
            format!("{:.4}", fid.token_match_rate()),
            format!("{:.6}", fid.mean_kl()),
            format!("{div}/{}", corpus.prompts.len()),
        ]);
        Ok(())
    };

    let mut od = OdMoeEngine::new(rt, ws.clone(), OdMoeConfig::default())?;
    eval("od-moe (full precision)", &mut od)?;
    for cfg in [
        OffloadConfig::moe_infinity(l),
        OffloadConfig::mixtral_offloading(l),
        OffloadConfig::hobbit(l),
        OffloadConfig::adapmoe(l),
    ] {
        let name = cfg.system.to_string();
        let mut e = OffloadEngine::new(rt, ws.clone(), cfg)?;
        eval(&name, &mut e)?;
    }
    t.print();
    println!("\n(paper Table 2(iii): OD-MoE matches Transformers on all benchmarks;");
    println!(" quantizing/skipping baselines lose accuracy across the board)");
    Ok(())
}

/// `od-moe memory`: Table 2(ii) audit. With `--fleet` (plus optional
/// `--precision`/`--max-batch`/`--prefetch-depth`/`--cache-hot`), audits
/// a heterogeneous fleet per node against each class's memory budget
/// instead of the paper presets — `--cache-hot N` adds the tiered
/// cache's N GPU-resident expert payloads to every worker's bound
/// (DESIGN.md §12).
pub fn memory(a: &Args) -> Result<()> {
    let p = HardwareProfile::rtx3090();
    if let Some(spec) = a.get("fleet") {
        let fleet = FleetSpec::parse(spec)?;
        let precision = parse_precision(a.get_or("precision", "fp16"))?;
        let max_batch = a.usize_or("max-batch", 1)?;
        let depth = a.usize_or("prefetch-depth", 0)?;
        let cache_hot = a.usize_or("cache-hot", 0)?;
        let scaled = planner::precision_scaled(&p, precision);
        let audit = memaudit::odmoe_fleet(
            &scaled,
            &fleet,
            memaudit::PAPER_TOP_K,
            max_batch,
            depth,
            cache_hot,
        );
        let budgets: Vec<f64> = fleet.node_classes().iter().map(|c| c.mem_bytes).collect();
        let mut t = Table::new(&["node", "GPU memory (GB)", "budget (GB)", "fits"]);
        for (i, (node, bytes)) in audit.per_node.iter().enumerate() {
            // First two rows are main/shadow (no class budget).
            let budget = i.checked_sub(2).map(|w| budgets[w]);
            t.row(&[
                node.clone(),
                format!("{:.2}", bytes / 1e9),
                budget.map_or("-".into(), |b| format!("{:.1}", b / 1e9)),
                budget.map_or("-".into(), |b| {
                    if *bytes <= b { "yes".into() } else { "OVER".to_string() }
                }),
            ]);
        }
        t.print();
        println!(
            "\nfleet {} | {} transfers | max batch {max_batch} | depth {depth} | hot cache {cache_hot} | total {:.1} GB",
            fleet.label(),
            precision.label(),
            audit.total_gb()
        );
        return Ok(());
    }
    let mut t = Table::new(&["system", "GPU memory (GB)", "paper (GB)"]);
    let audits = [
        (memaudit::odmoe(&p, 8), "60"),
        (memaudit::odmoe_batched(&p, 8, 2, 4), "-"),
        (memaudit::fully_cached(&p), "180"),
        (memaudit::offloading("mixtral-offloading", &p, 64, 0.143, 0.35), "11"),
        (memaudit::offloading("moe-infinity", &p, 42, 0.5, 0.35), "21.5"),
        (memaudit::offloading("hobbit", &p, 110, 0.25, 0.35), "22"),
        (memaudit::offloading("adapmoe", &p, 52, 0.143, 0.35), "8"),
        (memaudit::cpu_only(), "N/A"),
    ];
    for (audit, paper) in audits {
        t.row(&[
            audit.system.to_string(),
            format!("{:.1}", audit.total_gb()),
            paper.to_string(),
        ]);
    }
    t.print();
    println!();
    let od = memaudit::odmoe(&p, 8);
    for (node, bytes) in &od.per_node {
        println!("  od-moe {node}: {:.2} GB", bytes / 1e9);
    }
    Ok(())
}

/// `od-moe plan`: the SLO-driven fleet deployment planner (DESIGN.md
/// §10). Searches (class subset, transfer precision, chunk count,
/// prefetch depth, replica count, GPU-hot cache budget — `--cache-grid`,
/// default 0 only — and runtime precision policy — `--policy-grid`,
/// default static only) over `--fleet`, pruning candidates whose classes
/// miss their Eq. (1) window (judged at best-case NF4 stream size when a
/// non-static policy could downgrade at runtime) or memory budget
/// (hot-cached experts count toward the floor), and scores survivors by
/// running the real engine
/// through the serving scheduler in virtual time on the same workload
/// grammar as `od-moe serve`. Emits the deterministic `BENCH_plan.json`
/// (Pareto frontier + chosen plan); `od-moe serve --plan
/// BENCH_plan.json` re-runs the choice directly.
pub fn plan(rt: &Runtime, seed: u64, a: &Args) -> Result<()> {
    let threads = a.usize_or("threads", 1)?;
    ensure!(threads >= 1, "--threads must be >= 1, got {threads}");
    if threads > 1 {
        // Candidate scoring borrows one PJRT runtime mutably (`eval` is
        // FnMut over a single measuring engine), so the planner search
        // stays serial regardless of --threads.
        println!("note: plan candidate scoring runs serially (one measuring runtime)");
    }
    let fleet = FleetSpec::parse(a.get_or("fleet", "rtx3080:4,jetson:4,nano:2"))?;
    let slo_p99 = a.f64_or("slo-p99", 250.0)?;
    let (spec, sched, rate) = config_from_args(a, rt.cfg.vocab_size as u32)?;
    let grid = PlanGrid {
        precisions: a
            .get_or("precisions", "fp16,int8,nf4")
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| parse_precision(s.trim()))
            .collect::<Result<_>>()?,
        chunk_counts: parse_chunk_counts(a.get_or("chunk-grid", "1,8"))?,
        depths: parse_depths(a.get_or("depth-grid", "0,1"))?,
        replicas: parse_batches(a.get_or("replica-grid", "1"))?,
        cache_budgets: parse_cache_budgets(a.get_or("cache-grid", "0"))?,
        policies: parse_policy_grid(a.get_or("policy-grid", "static"))?,
    };
    let ws = WeightStore::generate(&rt.cfg, seed);
    let base = OdMoeConfig::default().profile;
    let group_size = rt.cfg.top_k;
    let out_tokens = a.usize_or("out-tokens", 16)?;
    ensure!(
        out_tokens >= 2,
        "--out-tokens must be >= 2 so the planner can measure decode (TPOT needs a second token)"
    );
    let probe_prompt = Corpus::generate(seed ^ 7, 1, 16, rt.cfg.vocab_size as u32)
        .prompts
        .pop()
        .expect("one probe prompt");
    let tenant_names: Vec<String> = spec.tenants.iter().map(|t| t.name.clone()).collect();

    println!(
        "planning over {} | target p99 tpot {slo_p99} ms | {} req @ {rate} req/s | max batch {}",
        fleet.label(),
        spec.n_requests,
        sched.max_batch
    );
    let max_batch = sched.max_batch;
    // Aggregate every measured candidate's engine counters (loads,
    // aborts, failovers) into one registry for `--metrics`.
    let mut plan_reg = Registry::new();
    let report = planner::search(&fleet, &base, group_size, max_batch, slo_p99, &grid, |cand| {
        let cfg = OdMoeConfig {
            n_workers: cand.fleet.n_nodes(),
            chunks: cand.chunks,
            prefetch_depth: cand.prefetch_depth,
            profile: cand.scaled_profile(&base),
            fleet: Some(cand.fleet.clone()),
            // hot == 0 is exactly CacheConfig::disabled(): the cacheless
            // grid point runs the seed engine, not a zero-slot cache.
            cache: CacheConfig { hot: cand.cache_hot, ..CacheConfig::disabled() },
            precision_policy: cand.policy,
            ..OdMoeConfig::default()
        };
        let mut engine = OdMoeEngine::new(rt, ws.clone(), cfg)?;
        // Memory probe: one full-batch decode captures the honest
        // per-node ledger peaks the budget check runs against.
        let probe: Vec<(&[u32], usize)> =
            vec![(probe_prompt.as_slice(), out_tokens); sched.max_batch];
        engine.run_batch(&probe)?;
        let main_peak_bytes = engine.cluster.main.gpu_bytes_peak as f64;
        let shadow_peak_bytes = engine.cluster.shadow.gpu_bytes_peak as f64;
        let worker_peak_bytes: Vec<f64> =
            engine.cluster.workers.iter().map(|w| w.gpu_bytes_peak as f64).collect();
        // Latency: the serving scheduler at the candidate's replica
        // count, same workload for every candidate (same seed). The
        // candidate's hot-tier bytes are reserved out of the admission
        // budget, exactly as `serve --cache-hot` would.
        let reserved = (cand.cache_hot as f64 * cand.scaled_profile(&base).expert_bytes) as u64;
        let cand_sched = SchedulerConfig {
            n_replicas: cand.replicas,
            memory: sched.memory.with_reservation(reserved),
            ..sched.clone()
        };
        let reqs = spec.generate(seed);
        let mut svc = BatchEngineService::new(&mut engine);
        let outcome = Scheduler::run(&cand_sched, &mut svc, &reqs)?;
        let rep = ServeReport::from_outcome("plan", rate, &outcome, &tenant_names);
        plan_reg.merge(engine.registry());
        let mut decode_ms = 0.0;
        let mut decode_tokens = 0u64;
        for r in &outcome.records {
            if let Some(ft) = r.first_token_ms {
                if r.tokens.len() > 1 {
                    decode_ms += r.finish_ms - ft;
                    decode_tokens += (r.tokens.len() - 1) as u64;
                }
            }
        }
        ensure!(decode_tokens > 0, "plan workload produced no decode tokens");
        Ok(PlanMeasurement {
            ms_per_token: decode_ms / decode_tokens as f64,
            ttft_p99_ms: rep.ttft.p99,
            tpot_p99_ms: rep.tpot.p99,
            slo_attainment: rep.slo_attainment,
            main_peak_bytes,
            shadow_peak_bytes,
            worker_peak_bytes,
        })
    })?;

    let mut t = Table::new(&[
        "fleet", "prec", "policy", "chunks", "depth", "hot", "repl", "ms/tok", "p99 tpot", "GB",
        "cost", "mem", "slo", "pareto",
    ]);
    for (i, pt) in report.points.iter().enumerate() {
        let marker = if report.chosen == Some(i) { " <= CHOSEN" } else { "" };
        t.row(&[
            pt.candidate.fleet.label(),
            pt.candidate.precision.label().to_string(),
            pt.candidate.policy.label().to_string(),
            format!("{}", pt.candidate.chunks),
            format!("{}", pt.candidate.prefetch_depth),
            format!("{}", pt.candidate.cache_hot),
            format!("{}", pt.candidate.replicas),
            format!("{:.1}", pt.meas.ms_per_token),
            format!("{:.0}", pt.meas.tpot_p99_ms),
            format!("{:.1}", pt.total_gpu_bytes / 1e9),
            format!("{:.2}", pt.cost),
            if pt.mem_ok { "ok".into() } else { "OVER".to_string() },
            if pt.meets_slo { "met".into() } else { "miss".to_string() },
            format!("{}{marker}", if pt.pareto { "*" } else { "" }),
        ]);
    }
    t.print();
    println!(
        "\n{} candidate(s) measured, {} pruned analytically",
        report.points.len(),
        report.pruned
    );
    match report.chosen_point() {
        Some(p) => println!(
            "chosen: {} — p99 tpot {:.0} ms (target {slo_p99}), cost {:.2}",
            p.candidate.label(),
            p.meas.tpot_p99_ms,
            p.cost
        ),
        None => println!(
            "no candidate meets the SLO within budget — relax --slo-p99 or grow the fleet"
        ),
    }
    let path = std::path::Path::new("BENCH_plan.json");
    write_bench(path, &planner::plan_json(&report, &fleet, &grid, seed))?;
    println!("wrote {}", path.display());
    if a.has("metrics") {
        plan_reg.counter_add("plan.candidates_measured", report.points.len() as u64);
        plan_reg.counter_add("plan.pruned", report.pruned as u64);
        if let Some(p) = report.chosen_point() {
            plan_reg.gauge_set("plan.chosen_tpot_p99_ms", p.meas.tpot_p99_ms);
            plan_reg.gauge_set("plan.chosen_cost", p.cost);
        }
        write_metrics("plan", &plan_reg)?;
    }
    Ok(())
}

/// `od-moe serve --scale-sweep`: session-count scaling of the scheduler
/// itself (DESIGN.md §13). Runtime-free — every cell drives the
/// synthetic service, so the measured cost is the scheduler core, not an
/// engine. The event core runs at every size in `--scale-sessions`; the
/// round-loop oracle also runs at sizes up to `--scale-round-cap`, where
/// its linear dispatch scan (quadratic in eligible sessions) is still
/// affordable — the gap between the two columns is the point of the
/// sweep. Cells fan out across `--threads` scoped workers and merge by
/// cell index; everything in `BENCH_scale.json` except the `wall_*`
/// keys is deterministic per seed at any thread count (`--omit-wall`
/// drops those, which is how CI diffs two runs).
pub fn scale(seed: u64, a: &Args) -> Result<()> {
    let sizes = parse_scale_sessions(a.get_or("scale-sessions", "1000,10000,100000,1000000"))?;
    let round_cap = a.usize_or("scale-round-cap", 10_000)?;
    let threads = a.usize_or("threads", 1)?;
    ensure!(threads >= 1, "--threads must be >= 1, got {threads}");
    println!(
        "scale sweep: sessions {sizes:?} | round-loop oracle up to {round_cap} | \
         {threads} thread(s)"
    );
    let cells = scale_sweep(&sizes, round_cap, threads, seed)?;
    let mut t = Table::new(&[
        "sessions", "core", "completed", "requeued", "events", "ev/virt-s", "arena MB", "wall ms",
        "e2e p99",
    ]);
    for c in &cells {
        let eps = match c.events {
            Some(e) if c.makespan_ms > 0.0 => format!("{:.0}", e as f64 * 1000.0 / c.makespan_ms),
            _ => "-".to_string(),
        };
        t.row(&[
            format!("{}", c.sessions),
            c.core.label().to_string(),
            format!("{}", c.completed),
            format!("{}", c.requeued),
            c.events.map_or("-".to_string(), |e| format!("{e}")),
            eps,
            c.arena_bytes.map_or("-".to_string(), |b| format!("{:.1}", b as f64 / 1e6)),
            format!("{:.0}", c.wall_ms),
            format!("{:.1}{}", c.e2e.p99, if c.exact_percentiles { "" } else { "~" }),
        ]);
    }
    t.print();
    let include_wall = !a.has("omit-wall");
    let path = std::path::Path::new("BENCH_scale.json");
    write_bench(path, &scale_json(&cells, &sizes, round_cap, seed, include_wall))?;
    println!("\nwrote {}", path.display());
    if a.has("metrics") {
        let mut reg = Registry::new();
        for c in &cells {
            let k = format!("scale.{}.{}", c.core.label(), c.sessions);
            reg.gauge_set(&format!("{k}.makespan_ms"), c.makespan_ms);
            reg.gauge_set(&format!("{k}.wall_ms"), c.wall_ms);
            if let Some(e) = c.events {
                reg.counter_add(&format!("{k}.events"), e);
            }
        }
        write_metrics("serve_scale", &reg)?;
    }
    Ok(())
}

/// `od-moe serve --autoscale-sweep`: the SLO control loop under traffic
/// drift (DESIGN.md §15). Runtime-free — every cell drives the demand-
/// tagged synthetic service, so the measured cost is the controller, not
/// an engine. Each of the three drift scenarios (diurnal swing, flash
/// crowd, rolling replica failure) is served twice on the *same* arrival
/// stream: by the static 2-replica fleet and by the reactive controller,
/// whose replica-ms, replication bytes, and quality debt ride next to
/// its latency wins in `BENCH_autoscale.json`. Deterministic per
/// `--seed`, byte for byte.
pub fn autoscale(seed: u64, a: &Args) -> Result<()> {
    let requests = a.usize_or("requests", 160)?;
    let rate = a.f64_or("rate", 24.0)?;
    println!("autoscale sweep: {requests} requests at {rate}/s base rate | seed {seed}");
    let cells = autoscale_sweep(requests, rate, seed)?;
    let mut t = Table::new(&[
        "scenario", "mode", "done", "p99 ttft", "goodput", "slo", "replica-ms", "acts",
    ]);
    for c in &cells {
        let acts = match &c.control {
            Some(r) => format!(
                "+{} -{} r{} x{}",
                r.scale_ups, r.scale_downs, r.reliefs, r.replications
            ),
            None => "-".to_string(),
        };
        t.row(&[
            c.scenario.clone(),
            c.mode.to_string(),
            format!("{}", c.report.completed),
            format!("{:.0}", c.report.ttft.p99),
            format!("{:.0}", c.report.goodput_tok_s),
            format!("{:.2}", c.report.slo_attainment),
            format!("{:.0}", c.replica_ms),
            acts,
        ]);
    }
    t.print();
    let path = std::path::Path::new("BENCH_autoscale.json");
    write_bench(path, &autoscale_json(&cells, requests, rate, seed))?;
    println!("\nwrote {}", path.display());
    if a.has("metrics") {
        let mut reg = Registry::new();
        for c in &cells {
            let k = format!("autoscale.{}.{}", c.scenario, c.mode);
            reg.gauge_set(&format!("{k}.ttft_p99_ms"), c.report.ttft.p99);
            reg.gauge_set(&format!("{k}.slo_attainment"), c.report.slo_attainment);
            reg.gauge_set(&format!("{k}.replica_ms"), c.replica_ms);
            if let Some(r) = &c.control {
                reg.counter_add(&format!("{k}.scale_ups"), r.scale_ups as u64);
                reg.counter_add(&format!("{k}.scale_downs"), r.scale_downs as u64);
                reg.counter_add(&format!("{k}.reliefs"), r.reliefs as u64);
                reg.counter_add(&format!("{k}.replications"), r.replications as u64);
            }
        }
        write_metrics("serve_autoscale", &reg)?;
    }
    Ok(())
}

/// Book a 16-layer round-robin expert stream (LAN dispatch, chunked
/// load, pipelined FFN tiles, LAN return) on a trace-enabled cluster.
/// Purely virtual-time and deterministic; returns the cluster (for
/// attribution/microbench reuse) and the pipeline makespan.
fn stream_pipeline(classes: Vec<NodeClass>, chunks: usize) -> (Cluster, f64) {
    let mut c = Cluster::with_classes(HardwareProfile::rtx3090(), classes);
    c.trace.enabled = true;
    let n = c.workers.len();
    let expert_bytes = 48.0 * 1024.0 * 1024.0;
    let embed_bytes = 16.0 * 1024.0;
    let mut t = 0.0;
    for l in 0..16 {
        let w = l % n;
        let arrival = c.lan_send(t, embed_bytes, "embed");
        let tr = c.expert_load_chunked(w, arrival, expert_bytes, chunks, EventKind::ExpertLoad);
        let (_, compute_end) = c.expert_compute_chunked(w, tr.start, 0.6, &tr.chunk_ends);
        t = c.lan_send(compute_end, embed_bytes, "embed-back");
    }
    (c, t)
}

/// `od-moe bench`: the perf benchmark runner + regression gate
/// (DESIGN.md §11). Runtime-free (no PJRT artifacts needed).
///
/// `BENCH_perf.json` has two sections: `"virtual"` holds deterministic
/// virtual-time metrics — chunked-stream makespans on uniform and mixed
/// fleets, scheduler sweep percentiles through the synthetic service, and
/// the attribution decomposition of the stream trace — byte-identical
/// given `--seed`. `"wall"` holds wall-clock microbench distributions
/// (mean/p50/p95 plus min/max/stddev over `--samples` invocations of
/// `--iters` iterations); machine-dependent and never gated.
///
/// `--ci` diffs the virtual section against the committed baseline
/// (`--baseline`, default `rust/benches/perf_baseline.json`) with a
/// relative `--band` noise band and exits nonzero on a regression or a
/// silently dropped metric. `--write-baseline` pins the current numbers —
/// the documented escape hatch for intentional perf changes (commit the
/// refreshed file).
pub fn bench(a: &Args) -> Result<()> {
    let seed = a.u64_or("seed", 42)?;
    let band = a.f64_or("band", 0.02)?;
    let samples = a.usize_or("samples", 7)?;
    let iters = a.usize_or("iters", 100)?;
    ensure!(samples >= 2, "--samples must be >= 2 to report a distribution");
    ensure!(iters >= 1, "--iters must be >= 1");

    // "virtual" section: deterministic virtual-time metrics — the only
    // numbers the gate compares.
    let mut virt: Vec<(String, f64)> = Vec::new();
    let fleets: [(&str, Vec<NodeClass>); 2] = [
        ("uniform-3090x4", vec![NodeClass::rtx3090(); 4]),
        (
            "mixed-3090x2-jetsonx2",
            vec![
                NodeClass::rtx3090(),
                NodeClass::jetson(),
                NodeClass::rtx3090(),
                NodeClass::jetson(),
            ],
        ),
    ];
    for (name, classes) in &fleets {
        for chunks in [1usize, 4] {
            let (_, makespan) = stream_pipeline(classes.clone(), chunks);
            virt.push((format!("stream/{name}/c{chunks}/makespan_ms"), makespan));
        }
    }
    // Attribution of the uniform 4-chunk stream: the decomposition and
    // critical path are gated metrics themselves (and double as the
    // microbench workload below).
    let (cluster, end) = stream_pipeline(vec![NodeClass::rtx3090(); 4], 4);
    let phase_ms = telemetry::decompose(&cluster.trace, 0.0, end);
    virt.push(("attrib/uniform-c4/expert_load_ms".into(), phase_ms[Phase::ExpertLoad.idx()]));
    virt.push(("attrib/uniform-c4/idle_ms".into(), phase_ms[Phase::Idle.idx()]));
    let cp = telemetry::critical_path(&cluster.trace, 0.0, end);
    virt.push(("attrib/uniform-c4/critical_segments".into(), cp.len() as f64));

    // Scheduler percentiles through the synthetic service.
    let spec = WorkloadSpec::poisson(4.0, 32, 256);
    let tenant_names: Vec<String> = spec.tenants.iter().map(|t| t.name.clone()).collect();
    let sched = SchedulerConfig { n_replicas: 2, max_batch: 2, ..SchedulerConfig::default() };
    for rate in [2.0, 8.0] {
        let reqs = spec.with_rate(rate).generate(seed);
        let mut svc = SyntheticService::new(5.0, 0.05, 3.0).with_batch_marginal(0.3);
        let outcome = Scheduler::run(&sched, &mut svc, &reqs)?;
        let rep = ServeReport::from_outcome("bench", rate, &outcome, &tenant_names);
        virt.push((format!("sched/poisson-r{rate}/ttft_p99_ms"), rep.ttft.p99));
        virt.push((format!("sched/poisson-r{rate}/tpot_p99_ms"), rep.tpot.p99));
    }

    // Event-core throughput on a closed-loop scale workload: heap pops
    // per *virtual* second is deterministic, so it is gatable (the
    // wall-clock flavor lives in `BENCH_scale.json` and never is). The
    // key is registered in the committed baseline behind the bootstrap
    // flag so the gate picks it up the moment a real baseline is pinned.
    let scale_sched = SchedulerConfig {
        n_replicas: 4,
        max_batch: 4,
        queue_sample_stride: 64,
        ..SchedulerConfig::default()
    };
    let scale_reqs = scale_workload(2_000, 500, seed);
    {
        let mut svc = SyntheticService::new(2.0, 0.1, 1.0).with_batch_marginal(0.2);
        let stats = run_streamed(&scale_sched, &mut svc, &scale_reqs, SCALE_SAMPLE_CAP)?;
        ensure!(stats.makespan_ms > 0.0, "scale workload produced an empty schedule");
        virt.push((
            "scheduler_events_per_sec".into(),
            stats.events as f64 * 1000.0 / stats.makespan_ms,
        ));
    }

    // Precision-controller tier tallies (DESIGN.md §14): drive the pure
    // slack/importance selector over a fixed (start offset x importance)
    // grid per fleet class and count the transfer tier each load would
    // take. Exact small integers from the closed-form duration model —
    // the committed baseline pins them, and
    // `rust/benches/baseline_mirror.py` recomputes them independently of
    // this crate (every comparison in the grid clears its boundary by
    // >= 0.1 ms, so the tallies are robust, not knife-edge).
    {
        let base = HardwareProfile::rtx3090();
        let classes =
            [NodeClass::rtx3090(), NodeClass::rtx3080(), NodeClass::jetson(), NodeClass::nano()];
        for class in &classes {
            let p = class.worker_profile(&base);
            let ctl = PrecisionController::from_profiles(
                &[&p],
                base.expert_bytes,
                4,
                4,
                PrecisionPolicy::SlackImportance,
                false,
            );
            let win = ctl.window_ms(0);
            let mut counts = [0u64; 3];
            for si in 0..8 {
                let start = win * si as f64 / 8.0;
                for imp in [0.1, 0.3, 0.5, 0.7, 0.9] {
                    counts[ctl.select(0, start, win, imp, 0, 0)] += 1;
                }
            }
            for (tier, label) in ["fp16", "int8", "nf4"].iter().enumerate() {
                virt.push((
                    format!("precision/{}/loads_{label}", class.name),
                    counts[tier] as f64,
                ));
            }
        }
    }

    // SLO-controller decision tallies (DESIGN.md §15): `classify` over a
    // fixed observation grid, plus a scripted 16-epoch traffic episode
    // (ramp into overload past the 4-replica budget, then drain) replayed
    // through `ControlState::observe`. Exact integers — every grid
    // operand sits off its threshold boundary — pinned in the committed
    // baseline and recomputed independently by
    // `rust/benches/baseline_mirror.py`. Decision-level counts: an epoch
    // under budget-exhausted pressure counts one relief even where the
    // runtime would hold its relief scale steady.
    let control_cfg = ControlConfig {
        target_p99_ttft_ms: 100.0,
        min_replicas: 1,
        max_replicas: 4,
        dispatch_width: 4,
        ..ControlConfig::default()
    };
    let episode_p99 = [
        40.0, 90.0, 150.0, 220.0, 260.0, 240.0, 200.0, 150.0, 110.0, 70.0, 45.0, 40.0, 35.0,
        30.0, 30.0, 30.0,
    ];
    let episode_queue = [0usize, 2, 6, 14, 20, 18, 12, 8, 4, 2, 1, 0, 0, 0, 0, 0];
    let episode_busy = [
        0.3, 0.5, 0.8, 0.95, 0.97, 0.9, 0.85, 0.7, 0.6, 0.45, 0.3, 0.2, 0.2, 0.2, 0.2, 0.2,
    ];
    let replay_episode = |cfg: &ControlConfig| {
        let mut st = ControlState::default();
        let mut live = 2usize;
        let (mut ups, mut downs, mut reliefs, mut tightens) = (0u64, 0u64, 0u64, 0u64);
        for i in 0..episode_p99.len() {
            let obs = EpochObservation {
                p99_ttft_ms: episode_p99[i],
                queue_depth: episode_queue[i],
                live_replicas: live,
                busy_frac: episode_busy[i],
                completed: 0,
            };
            let d = st.observe(cfg, &obs);
            live = (live as i64 + d.replica_delta as i64) as usize;
            if d.replica_delta > 0 {
                ups += 1;
            }
            if d.replica_delta < 0 {
                downs += 1;
            }
            if d.precision_relief {
                reliefs += 1;
            }
            if d.tighten_admission {
                tightens += 1;
            }
        }
        (ups, downs, reliefs, tightens, live)
    };
    {
        let (mut over, mut calm, mut hold) = (0u64, 0u64, 0u64);
        for ratio in [0.4, 0.8, 1.1, 1.3, 1.6, 2.2] {
            for queue in [0usize, 2, 6, 12, 24] {
                for busy in [0.2, 0.55, 0.9] {
                    let obs = EpochObservation {
                        p99_ttft_ms: ratio * control_cfg.target_p99_ttft_ms,
                        queue_depth: queue,
                        live_replicas: 2,
                        busy_frac: busy,
                        completed: 0,
                    };
                    match classify(&control_cfg, &obs) {
                        Pressure::Over => over += 1,
                        Pressure::Calm => calm += 1,
                        Pressure::Neutral => hold += 1,
                    }
                }
            }
        }
        virt.push(("control/grid_pressure".into(), over as f64));
        virt.push(("control/grid_calm".into(), calm as f64));
        virt.push(("control/grid_hold".into(), hold as f64));
        let (ups, downs, reliefs, tightens, live) = replay_episode(&control_cfg);
        virt.push(("control/episode_scale_ups".into(), ups as f64));
        virt.push(("control/episode_scale_downs".into(), downs as f64));
        virt.push(("control/episode_reliefs".into(), reliefs as f64));
        virt.push(("control/episode_tightens".into(), tightens as f64));
        virt.push(("control/episode_final_live".into(), live as f64));
    }

    let mut t = Table::new(&["virtual metric (gated)", "value"]);
    for (k, v) in &virt {
        t.row(&[k.clone(), format!("{v:.4}")]);
    }
    t.print();

    // "wall" section: wall-clock microbench distributions (informational;
    // machine-dependent, so never gated).
    println!();
    bench_util::header();
    let mut wall: Vec<bench_util::Summary> = Vec::new();
    wall.push(bench_util::run("telemetry/decompose/16-layer-trace", samples, iters, || {
        std::hint::black_box(telemetry::decompose(&cluster.trace, 0.0, end));
    }));
    wall.push(bench_util::run("telemetry/critical-path/16-layer-trace", samples, iters, || {
        std::hint::black_box(telemetry::critical_path(&cluster.trace, 0.0, end));
    }));
    wall.push(bench_util::run("metrics/histogram-256-push-summary", samples, iters, || {
        let mut h = Histogram::default();
        let mut x = seed | 1;
        for _ in 0..256 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            h.push((x >> 33) as f64);
        }
        std::hint::black_box(h.summary());
    }));
    wall.push(bench_util::run("control/epoch-decision/16-epoch-episode", samples, iters, || {
        std::hint::black_box(replay_episode(&control_cfg));
    }));
    let micro_reqs = scale_workload(512, 128, seed);
    wall.push(bench_util::run("sched/event-core/512-session-run", samples, iters, || {
        let mut svc = SyntheticService::new(2.0, 0.1, 1.0).with_batch_marginal(0.2);
        std::hint::black_box(
            run_streamed(&scale_sched, &mut svc, &micro_reqs, SCALE_SAMPLE_CAP)
                .expect("event core microbench"),
        );
    }));
    let virt_obj = obj(virt.iter().map(|(k, v)| (k.as_str(), num(*v))).collect());
    let virt_text = virt_obj.to_string();
    wall.push(bench_util::run("json/parse-virtual-section", samples, iters, || {
        std::hint::black_box(Json::parse(&virt_text).expect("valid json"));
    }));
    for s in &wall {
        s.print();
    }

    let doc = obj(vec![
        ("bench", Json::Str("perf".into())),
        ("schema", Json::Str("odmoe.bench.v1".into())),
        ("seed", Json::Num(seed as f64)),
        ("virtual", virt_obj.clone()),
        ("wall", Json::Arr(wall.iter().map(|s| s.to_json()).collect())),
    ]);
    let out = a.get_or("out", "BENCH_perf.json");
    write_bench(std::path::Path::new(out), &doc)?;
    println!("\nwrote {out}");

    let baseline_path = a.get_or("baseline", "rust/benches/perf_baseline.json");
    if a.has("write-baseline") {
        let base =
            obj(vec![("schema", Json::Str("odmoe.bench.v1".into())), ("virtual", virt_obj)]);
        write_bench(std::path::Path::new(baseline_path), &base)?;
        println!("pinned baseline {baseline_path}");
        return Ok(());
    }
    if a.has("ci") {
        let text = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading baseline {baseline_path} (pin: --write-baseline)"))?;
        let baseline = Json::parse(&text)?;
        let outcome = telemetry::gate(&doc, &baseline, band)?;
        print!("{}", outcome.report(band));
        if !outcome.passed() {
            anyhow::bail!(
                "perf gate failed: {} regression(s), {} missing metric(s)",
                outcome.regressions.len(),
                outcome.missing.len()
            );
        }
    }
    Ok(())
}
