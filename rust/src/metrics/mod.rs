//! Evaluation metrics: recall (paper Eqs. 2–3), speed aggregation,
//! GPU-memory audit (Table 2(ii)), and output fidelity (Table 2(iii)
//! substitution — see DESIGN.md §2).

pub mod memory;

use crate::cluster::Ms;

/// Recall accumulator for expert-activation prediction, following the
/// paper's Eqs. (2)–(3): `c(q,n,l)` correctly-predicted experts out of
/// `k*L` per (prompt, token), bucketed by output-token index `n`.
#[derive(Debug, Clone)]
pub struct RecallStats {
    top_k: usize,
    n_layers: usize,
    /// Per token index: (sum of c(q,n,l) over q,l ; number of prompts seen).
    per_token: Vec<(u64, u64)>,
}

impl RecallStats {
    pub fn new(top_k: usize, n_layers: usize) -> Self {
        Self { top_k, n_layers, per_token: Vec::new() }
    }

    /// Record one (prompt, token) observation: `correct[l]` = number of
    /// correctly predicted experts at layer `l` (0..=top_k each).
    pub fn record_token(&mut self, token_idx: usize, correct_per_layer: &[usize]) {
        assert_eq!(correct_per_layer.len(), self.n_layers);
        if self.per_token.len() <= token_idx {
            self.per_token.resize(token_idx + 1, (0, 0));
        }
        let c: u64 = correct_per_layer.iter().map(|&c| {
            assert!(c <= self.top_k, "c(q,n,l) > k");
            c as u64
        }).sum();
        let slot = &mut self.per_token[token_idx];
        slot.0 += c;
        slot.1 += 1;
    }

    /// Eq. (2): recall for output-token index `n`.
    pub fn recall_at(&self, n: usize) -> Option<f64> {
        let (c, q) = *self.per_token.get(n)?;
        if q == 0 {
            return None;
        }
        Some(c as f64 / (self.top_k * self.n_layers) as f64 / q as f64)
    }

    /// Eq. (3): overall recall across all observed tokens.
    pub fn recall(&self) -> f64 {
        let c: u64 = self.per_token.iter().map(|&(c, _)| c).sum();
        let q: u64 = self.per_token.iter().map(|&(_, q)| q).sum();
        if q == 0 {
            return 0.0;
        }
        c as f64 / (self.top_k * self.n_layers) as f64 / q as f64
    }

    /// Recall-vs-token-index curve (Fig. 3 series).
    pub fn curve(&self) -> Vec<f64> {
        (0..self.per_token.len())
            .map(|n| self.recall_at(n).unwrap_or(f64::NAN))
            .collect()
    }

    pub fn max_token(&self) -> usize {
        self.per_token.len()
    }
}

/// Count of correctly predicted experts: |predicted ∩ actual| (order and
/// router weights are irrelevant for loading).
pub fn correct_count(predicted: &[usize], actual: &[usize]) -> usize {
    actual.iter().filter(|e| predicted.contains(e)).count()
}

/// Speed statistics for one engine run.
#[derive(Debug, Clone, Default)]
pub struct SpeedStats {
    /// Time-to-first-token per prompt (prefill latency), ms.
    pub ttft_ms: Vec<Ms>,
    /// Decode time per prompt (excluding prefill), ms, with token count.
    pub decode: Vec<(Ms, usize)>,
}

impl SpeedStats {
    pub fn record(&mut self, ttft: Ms, decode_ms: Ms, out_tokens: usize) {
        self.ttft_ms.push(ttft);
        self.decode.push((decode_ms, out_tokens));
    }

    pub fn mean_ttft_ms(&self) -> f64 {
        mean(&self.ttft_ms)
    }

    /// Decoding throughput (paper's primary metric): decoded tokens per
    /// second of decode time, averaged over prompts.
    pub fn decode_tps(&self) -> f64 {
        let per: Vec<f64> = self
            .decode
            .iter()
            .filter(|(ms, n)| *ms > 0.0 && *n > 0)
            .map(|(ms, n)| *n as f64 / (ms / 1000.0))
            .collect();
        mean(&per)
    }

    /// Output throughput over the whole request (prefill + decode).
    pub fn output_tps(&self) -> f64 {
        let per: Vec<f64> = self
            .ttft_ms
            .iter()
            .zip(&self.decode)
            .filter(|(t, (d, n))| *t + d > 0.0 && *n > 0)
            .map(|(t, (d, n))| *n as f64 / ((t + d) / 1000.0))
            .collect();
        mean(&per)
    }

    pub fn decode_tps_std(&self) -> f64 {
        let per: Vec<f64> = self
            .decode
            .iter()
            .filter(|(ms, n)| *ms > 0.0 && *n > 0)
            .map(|(ms, n)| *n as f64 / (ms / 1000.0))
            .collect();
        std_dev(&per)
    }
}

/// Output-fidelity comparison vs the FP32 reference (Table 2(iii) proxy).
#[derive(Debug, Clone, Default)]
pub struct Fidelity {
    /// Exact-match decisions (token agreed with reference).
    pub token_matches: usize,
    pub token_total: usize,
    /// Sum of KL(ref || engine) over compared steps (natural log).
    pub kl_sum: f64,
    pub kl_steps: usize,
    /// First token index at which the stream diverged, per prompt.
    pub first_divergence: Vec<Option<usize>>,
}

impl Fidelity {
    pub fn token_match_rate(&self) -> f64 {
        if self.token_total == 0 {
            return 1.0;
        }
        self.token_matches as f64 / self.token_total as f64
    }

    pub fn mean_kl(&self) -> f64 {
        if self.kl_steps == 0 {
            return 0.0;
        }
        self.kl_sum / self.kl_steps as f64
    }

    /// Record one decode step: reference vs engine logits + tokens.
    pub fn record_step(&mut self, ref_logits: &[f32], logits: &[f32], ref_tok: u32, tok: u32) {
        self.token_total += 1;
        if ref_tok == tok {
            self.token_matches += 1;
        }
        self.kl_sum += kl_divergence(ref_logits, logits);
        self.kl_steps += 1;
    }
}

/// KL(p || q) between softmax distributions of two logit vectors.
pub fn kl_divergence(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    assert_eq!(p_logits.len(), q_logits.len());
    let p = softmax(p_logits);
    let q = softmax(q_logits);
    p.iter()
        .zip(&q)
        .map(|(&pi, &qi)| {
            if pi <= 0.0 {
                0.0
            } else {
                pi * (pi / qi.max(1e-12)).ln()
            }
        })
        .sum()
}

fn softmax(logits: &[f32]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// Nearest-rank percentile (inclusive, `q` in `[0, 1]`): the smallest
/// element with at least `⌈q·n⌉` of the sample at or below it. `values`
/// need not be sorted. Returns 0 on an empty sample.
///
/// This replaces the old ad-hoc `((n - 1) as f64 * q) as usize` indexing,
/// which *truncated* the rank and so under-reported upper quantiles
/// (e.g. p95 of 10 samples picked the 9th value instead of the 10th).
pub fn percentile(values: &[f64], q: f64) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// [`percentile`] over an already-sorted ascending sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

pub fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().sum::<f64>() / v.len() as f64
}

pub fn std_dev(v: &[f64]) -> f64 {
    if v.len() < 2 {
        return 0.0;
    }
    let m = mean(v);
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recall_eq2_eq3() {
        let mut r = RecallStats::new(2, 3);
        // Prompt 1, token 0: all correct (6/6); token 1: half correct.
        r.record_token(0, &[2, 2, 2]);
        r.record_token(1, &[1, 1, 1]);
        // Prompt 2 only reaches token 0 (A(q,n) handling).
        r.record_token(0, &[2, 2, 2]);
        assert_eq!(r.recall_at(0), Some(1.0));
        assert_eq!(r.recall_at(1), Some(0.5));
        assert_eq!(r.recall_at(2), None);
        // Overall: (12 + 3) / (6 * 3 observations) = 15/18.
        assert!((r.recall() - 15.0 / 18.0).abs() < 1e-12);
    }

    #[test]
    fn correct_count_is_set_intersection() {
        assert_eq!(correct_count(&[1, 2], &[2, 1]), 2);
        assert_eq!(correct_count(&[1, 2], &[3, 1]), 1);
        assert_eq!(correct_count(&[4, 5], &[1, 2]), 0);
    }

    #[test]
    fn speed_stats_throughputs() {
        let mut s = SpeedStats::default();
        s.record(1000.0, 4000.0, 8); // 2 tok/s decode, 1.6 tok/s output
        assert!((s.decode_tps() - 2.0).abs() < 1e-9);
        assert!((s.output_tps() - 1.6).abs() < 1e-9);
        assert_eq!(s.mean_ttft_ms(), 1000.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0, "rank clamps to the minimum");
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
        assert_eq!(percentile(&[], 0.95), 0.0);
    }

    #[test]
    fn percentile_fixes_truncation_bias() {
        // 10 samples: nearest-rank p95 is the 10th value (ceil(9.5) = 10);
        // the old truncating index picked the 9th.
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 10.0);
        let old = v[((v.len() - 1) as f64 * 0.95) as usize];
        assert_eq!(old, 9.0, "documents the bug this replaced");
    }

    #[test]
    fn percentile_handles_unsorted_input() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 0.5), 2.0);
    }

    #[test]
    fn kl_zero_for_identical() {
        let l = vec![0.1f32, -0.5, 2.0];
        assert!(kl_divergence(&l, &l).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_for_different() {
        let p = vec![0.0f32, 0.0, 3.0];
        let q = vec![3.0f32, 0.0, 0.0];
        assert!(kl_divergence(&p, &q) > 0.5);
    }

    #[test]
    fn fidelity_rates() {
        let mut f = Fidelity::default();
        let l = vec![0.0f32; 4];
        f.record_step(&l, &l, 1, 1);
        f.record_step(&l, &l, 1, 2);
        assert_eq!(f.token_match_rate(), 0.5);
        assert!(f.mean_kl() < 1e-12);
    }
}
