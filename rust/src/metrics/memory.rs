//! GPU-memory audit at paper scale (Table 2(ii)).
//!
//! Deterministic accounting of what each serving system must keep
//! GPU-resident for Mixtral-8x7B, using the paper's own constants
//! (704 MB FP32 expert, 7 GB non-expert stack, 45 GB INT8 shadow model).

use crate::cluster::HardwareProfile;
use crate::fleet::FleetSpec;

/// GPU-memory breakdown of one serving system, bytes at paper scale.
#[derive(Debug, Clone)]
pub struct MemoryAudit {
    pub system: &'static str,
    pub per_node: Vec<(String, f64)>,
}

impl MemoryAudit {
    pub fn total_gb(&self) -> f64 {
        self.per_node.iter().map(|(_, b)| b).sum::<f64>() / 1e9
    }
}

/// Mixtral-8x7B constants used by the audit.
pub const PAPER_LAYERS: usize = 32;
pub const PAPER_EXPERTS_PER_LAYER: usize = 8;
pub const PAPER_TOP_K: usize = 2;

/// OD-MoE: main node (non-experts) + shadow (quantized full model) + one
/// in-flight expert + workspace per worker. This is the *sequential*
/// audit: single-session decode keeps strict single-expert residency
/// (the cacheless property), which the engine's byte ledger confirms —
/// see `ledger_peaks_reconcile_with_memory_audit` in
/// `rust/tests/batch_props.rs`. Batched decode transiently holds more;
/// report that with [`odmoe_batched`].
pub fn odmoe(p: &HardwareProfile, n_workers: usize) -> MemoryAudit {
    let mut per_node = vec![
        ("main".to_string(), p.nonexpert_bytes),
        ("shadow".to_string(), p.shadow_model_bytes),
    ];
    for i in 0..n_workers {
        per_node.push((format!("worker{i}"), p.expert_bytes + p.activation_bytes));
    }
    MemoryAudit { system: "OD-MoE", per_node }
}

/// OD-MoE worker residency under *batched* decode, reported honestly: a
/// layer can route a B-session batch to `min(top_k * B, 8)` distinct
/// experts, and the engine gates every expert compute of a layer behind
/// all of its loads, so a worker can transiently hold every expert it
/// loads for that layer — `ceil(distinct / group_size)` of them, not the
/// sequential audit's one (DESIGN.md §7). The ledger peak in
/// `rust/tests/batch_props.rs` is reconciled against this bound.
pub fn odmoe_batched(
    p: &HardwareProfile,
    n_workers: usize,
    group_size: usize,
    max_batch: usize,
) -> MemoryAudit {
    assert!(group_size > 0 && max_batch > 0, "need a group and a batch");
    let distinct = (PAPER_TOP_K * max_batch).min(PAPER_EXPERTS_PER_LAYER);
    let in_flight = distinct.div_ceil(group_size) as f64;
    let mut per_node = vec![
        ("main".to_string(), p.nonexpert_bytes),
        ("shadow".to_string(), p.shadow_model_bytes),
    ];
    for i in 0..n_workers {
        per_node.push((
            format!("worker{i}"),
            in_flight * p.expert_bytes + p.activation_bytes,
        ));
    }
    MemoryAudit { system: "OD-MoE (batched)", per_node }
}

/// OD-MoE residency across a heterogeneous fleet (DESIGN.md §10): one
/// entry per node, labelled `class/worker<i>`, bounding the transient
/// per-worker residency at `ceil(distinct / group_size) + depth +
/// cache_hot` staged experts (batched co-residency — see
/// [`odmoe_batched`] — plus the speculative prefetch depth plus the
/// tiered cache's GPU-hot budget, DESIGN.md §12) in *`p`-scaled* expert
/// payloads. Pass the planner candidate's precision-scaled profile to
/// audit a plan; the planner cross-checks engine ledger peaks against
/// this bound and each class's `mem_bytes` budget.
pub fn odmoe_fleet(
    p: &HardwareProfile,
    fleet: &FleetSpec,
    group_size: usize,
    max_batch: usize,
    prefetch_depth: usize,
    cache_hot: usize,
) -> MemoryAudit {
    let bound = fleet_worker_bound_bytes(p, group_size, max_batch, prefetch_depth, cache_hot);
    let mut per_node = vec![
        ("main".to_string(), p.nonexpert_bytes),
        ("shadow".to_string(), p.shadow_model_bytes),
    ];
    for (i, class) in fleet.node_classes().iter().enumerate() {
        per_node.push((format!("{}/worker{i}", class.name), bound));
    }
    MemoryAudit { system: "OD-MoE (fleet)", per_node }
}

/// The per-worker transient residency bound behind [`odmoe_fleet`]:
/// `ceil(distinct / group_size) + prefetch_depth + cache_hot` staged
/// experts (in `p`-scaled payloads) plus workspace — `cache_hot` is the
/// tiered cache's GPU-hot budget in expert slots (0 = cacheless, the
/// seed bound). The single formula the audit, the planner's
/// `ledger_within_audit` cross-check, and the serve scheduler's
/// admission reservation consult — sharing it is what makes those
/// cross-checks meaningful.
pub fn fleet_worker_bound_bytes(
    p: &HardwareProfile,
    group_size: usize,
    max_batch: usize,
    prefetch_depth: usize,
    cache_hot: usize,
) -> f64 {
    assert!(group_size > 0 && max_batch > 0, "need a group and a batch");
    let distinct = (PAPER_TOP_K * max_batch).min(PAPER_EXPERTS_PER_LAYER);
    (distinct.div_ceil(group_size) + prefetch_depth + cache_hot) as f64 * p.expert_bytes
        + p.activation_bytes
}

/// Fully GPU-cached full-precision deployment (Transformers reference).
pub fn fully_cached(p: &HardwareProfile) -> MemoryAudit {
    let experts = (PAPER_LAYERS * PAPER_EXPERTS_PER_LAYER) as f64 * p.expert_bytes_fp32;
    MemoryAudit {
        system: "Transformers",
        per_node: vec![("server".into(), p.nonexpert_bytes + experts)],
    }
}

/// Generic single-GPU offloading system: non-experts + a cache of
/// `cached_experts` at `precision_factor` of FP32 bytes + workspace.
pub fn offloading(
    system: &'static str,
    p: &HardwareProfile,
    cached_experts: usize,
    precision_factor: f64,
    nonexpert_factor: f64,
) -> MemoryAudit {
    let cache = cached_experts as f64 * p.expert_bytes_fp32 * precision_factor;
    MemoryAudit {
        system,
        per_node: vec![(
            "server".into(),
            p.nonexpert_bytes * nonexpert_factor + cache + p.activation_bytes,
        )],
    }
}

/// llama.cpp runs on CPU: zero GPU bytes.
pub fn cpu_only() -> MemoryAudit {
    MemoryAudit { system: "llama.cpp", per_node: vec![("server".into(), 0.0)] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn odmoe_uses_about_one_third_of_fully_cached() {
        let p = HardwareProfile::rtx3090();
        let od = odmoe(&p, 8).total_gb();
        let full = fully_cached(&p).total_gb();
        // Paper: 60 GB vs 180 GB.
        assert!((od - 57.2).abs() < 4.0, "od-moe total {od}");
        assert!((full - 187.0).abs() < 8.0, "fully cached total {full}");
        let ratio = od / full;
        assert!((ratio - 1.0 / 3.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn worker_nodes_stay_under_1gb_plus_expert() {
        let p = HardwareProfile::rtx3090();
        let audit = odmoe(&p, 8);
        for (name, bytes) in &audit.per_node {
            if name.starts_with("worker") {
                // Paper: < 1 GB per worker (one fp32 expert + workspace).
                assert!(*bytes <= 1.1e9, "{name}: {bytes}");
            }
        }
    }

    #[test]
    fn cpu_only_uses_no_gpu() {
        assert_eq!(cpu_only().total_gb(), 0.0);
    }

    #[test]
    fn batched_audit_reduces_to_sequential_at_batch_one() {
        let p = HardwareProfile::rtx3090();
        let seq = odmoe(&p, 8);
        let b1 = odmoe_batched(&p, 8, 2, 1);
        for ((_, a), (_, b)) in seq.per_node.iter().zip(&b1.per_node) {
            assert_eq!(a, b, "batch of one keeps single-expert residency");
        }
    }

    #[test]
    fn batched_audit_grows_with_batch_and_caps_at_experts_per_group() {
        let p = HardwareProfile::rtx3090();
        let worker = |b: usize| odmoe_batched(&p, 8, 2, b).per_node[2].1;
        assert!(worker(2) > worker(1));
        assert!(worker(4) > worker(2));
        // 8 experts / group of 2 -> at most 4 in flight per worker.
        assert_eq!(worker(4), worker(64));
        assert_eq!(worker(64), 4.0 * p.expert_bytes + p.activation_bytes);
    }

    #[test]
    fn fleet_audit_names_classes_and_respects_budgets_at_nf4() {
        let base = HardwareProfile::rtx3090();
        let fleet = FleetSpec::parse("rtx3080:2,nano:1").unwrap();
        // Sequential, no prefetch, full precision: same per-worker bound
        // as the uniform sequential audit.
        let a = odmoe_fleet(&base, &fleet, 2, 1, 0, 0);
        assert_eq!(a.per_node[2].0, "rtx3080/worker0");
        assert_eq!(a.per_node[4].0, "nano/worker2");
        assert_eq!(a.per_node[2].1, base.expert_bytes + base.activation_bytes);
        // nf4-scaled transfers keep even the 1 GB nano inside budget with
        // one staged expert; fp16 with prefetch does not.
        let nf4 = HardwareProfile { expert_bytes: base.expert_bytes * 0.28, ..base.clone() };
        let nano_budget = 1e9;
        let tight = odmoe_fleet(&nf4, &fleet, 2, 1, 1, 0);
        assert!(tight.per_node[4].1 <= nano_budget, "{}", tight.per_node[4].1);
        let loose = odmoe_fleet(&base, &fleet, 2, 1, 1, 0);
        assert!(loose.per_node[4].1 > nano_budget, "fp16 + depth 1 must blow the budget");
        // Batched residency adds on top of prefetch depth.
        let batched = odmoe_fleet(&base, &fleet, 2, 4, 1, 0);
        assert!(batched.per_node[2].1 > loose.per_node[2].1);
    }

    #[test]
    fn cache_hot_budget_adds_expert_payloads_to_the_bound() {
        let p = HardwareProfile::rtx3090();
        let cacheless = fleet_worker_bound_bytes(&p, 2, 1, 0, 0);
        let hot2 = fleet_worker_bound_bytes(&p, 2, 1, 0, 2);
        assert_eq!(hot2, cacheless + 2.0 * p.expert_bytes);
        // The audit mirrors the shared bound per node.
        let fleet = FleetSpec::parse("rtx3080:2,nano:1").unwrap();
        let audit = odmoe_fleet(&p, &fleet, 2, 1, 0, 2);
        assert_eq!(audit.per_node[2].1, hot2);
    }

    #[test]
    fn offloading_memory_scales_with_cache() {
        let p = HardwareProfile::rtx3090();
        let small = offloading("a", &p, 16, 0.25, 0.5).total_gb();
        let big = offloading("b", &p, 64, 0.25, 0.5).total_gb();
        assert!(big > small);
    }
}
