//! Virtual-time edge-cluster simulator.
//!
//! The paper's speed results are scheduling/queueing phenomena over four
//! hardware quantities — main-node compute `t_M`, worker expert compute
//! `t_W`, CPU→GPU expert-load time, and LAN message time. The simulator
//! models each node's GPU and PCIe link plus the shared LAN as *resources
//! with availability timestamps*; engines schedule tasks as
//! `start = max(dependencies, resource_free)`, `end = start + duration`,
//! exactly the dependency structure of the paper's Fig. 2/4/5 timing
//! diagrams. Numerics (which expert, which token) come from real PJRT
//! executions; only durations are simulated. See DESIGN.md §4.

pub mod profile;

pub use profile::{HardwareProfile, NodeClass};

use crate::trace::{EventKind, Trace};

/// Milliseconds of virtual time.
pub type Ms = f64;

/// A serially-reusable resource (a GPU, a PCIe link, the LAN).
///
/// `acquire(earliest, duration)` books the resource for `duration` ms at
/// the first instant >= both `earliest` and the resource's availability,
/// returning the (start, end) of the booking.
///
/// The resource remembers its booked spans (ascending, non-overlapping;
/// back-to-back bookings — e.g. the chunks of one streamed expert
/// transfer — merge into one span), so [`Resource::preempt`] can cancel
/// *every* booking past the preempt instant and reclaim exactly the
/// cancelled time: completed work and idle gaps are never reclaimed, and
/// `busy_total` equals the surviving spans under any preempt sequence.
/// (The old single-`last_start` model could only cancel the most recent
/// booking, which breaks down once a transfer is a train of chunks.)
#[derive(Debug, Clone, Default)]
pub struct Resource {
    free_at: Ms,
    busy_total: Ms,
    /// Booked (start, end) spans, ascending and disjoint; contiguous
    /// bookings are merged so a K-chunk train stays one entry. Spans are
    /// retained until `reset` on purpose: a fail-stop can preempt at an
    /// arbitrarily early instant (e.g. `--fail worker3@0` noticed after
    /// prefill booked far ahead), so any compaction of "old" spans would
    /// leave cancelled time stuck in `busy_total`. The cost is one pair
    /// per non-contiguous booking between resets — tens of KB per
    /// resource on the longest bench runs, and engines reset per
    /// request.
    spans: Vec<(Ms, Ms)>,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn acquire(&mut self, earliest: Ms, duration: Ms) -> (Ms, Ms) {
        // Non-finite bookings are always a modeling bug: an infinite
        // duration pins `free_at` at +inf for the rest of the run (and a
        // later preempt would drive `busy_total` to -inf/NaN). Dead nodes
        // are modeled by [`NodeHealth`] / [`Cluster::fail_worker`], never
        // by infinite durations.
        assert!(
            earliest.is_finite() && duration.is_finite() && duration >= 0.0,
            "non-finite or negative booking (earliest {earliest}, duration {duration}); \
             model dead nodes with NodeHealth, not infinite durations"
        );
        let start = self.free_at.max(earliest);
        let end = start + duration;
        self.free_at = end;
        self.busy_total += duration;
        match self.spans.last_mut() {
            // Back-to-back booking (chunk trains, saturated links): extend.
            Some(last) if last.1 == start => last.1 = end,
            _ => self.spans.push((start, end)),
        }
        (start, end)
    }

    /// Next instant this resource is idle.
    pub fn free_at(&self) -> Ms {
        self.free_at
    }

    /// Cancel everything booked past `at`: the resource becomes free at
    /// `at` if it was booked past it (mispredicted expert loads are
    /// cancelled the moment the gate result disagrees — paper §3.1; node
    /// failures freeze a dead node's resources the same way).
    ///
    /// Reclaims from `busy_total` exactly the booked time inside
    /// `[at, free_at)`: a straddled booking keeps its delivered prefix
    /// (chunks that already landed stay busy — and wasted), bookings that
    /// had not started are cancelled whole, and completed work or idle
    /// gaps before `at` are never touched — `busy_total` stays finite and
    /// non-negative under any preempt sequence.
    pub fn preempt(&mut self, at: Ms) {
        assert!(at.is_finite(), "non-finite preempt instant {at}");
        if self.free_at <= at {
            return;
        }
        let mut reclaimed = 0.0;
        while let Some(&(start, end)) = self.spans.last() {
            if start >= at {
                // Unstarted from `at`'s point of view: cancelled whole.
                reclaimed += end - start;
                self.spans.pop();
            } else {
                if end > at {
                    // In flight at `at`: the delivered prefix survives.
                    reclaimed += end - at;
                    self.spans.last_mut().expect("just peeked").1 = at;
                }
                break;
            }
        }
        self.busy_total = (self.busy_total - reclaimed).max(0.0);
        self.free_at = at;
    }

    /// Total booked time (utilization accounting).
    pub fn busy_total(&self) -> Ms {
        self.busy_total
    }

    pub fn reset(&mut self) {
        self.free_at = 0.0;
        self.busy_total = 0.0;
        self.spans.clear();
    }
}

/// One expert transfer booked as a train of dependent chunks on a
/// worker's PCIe link (DESIGN.md §9). Carries the per-chunk completion
/// times so schedulers can gate expert-compute tiles on individual
/// chunks, abort mid-stream reclaiming only undelivered chunks, and
/// resume a dead worker's stream on its replacement from the first
/// undelivered chunk. A 1-chunk train is exactly the monolithic booking.
#[derive(Debug, Clone)]
pub struct ChunkedTransfer {
    /// Worker whose link carries (and whose memory receives) the stream.
    pub worker: usize,
    /// Start of the first chunk.
    pub start: Ms,
    /// Completion time of each chunk, ascending.
    pub chunk_ends: Vec<Ms>,
    /// The link's `free_at` before this train was booked — the floor an
    /// abort may rewind the link to (never below work queued ahead).
    pub free_before: Ms,
}

impl ChunkedTransfer {
    /// When the last chunk lands (the whole expert is resident).
    pub fn done(&self) -> Ms {
        *self.chunk_ends.last().expect("a transfer has at least one chunk")
    }

    /// When the first chunk lands (expert compute may begin).
    pub fn first_ready(&self) -> Ms {
        self.chunk_ends[0]
    }

    /// Chunks fully delivered by `at` (an in-flight chunk counts as
    /// undelivered — its bytes die with a node that fails mid-chunk).
    pub fn delivered_by(&self, at: Ms) -> usize {
        self.chunk_ends.iter().filter(|&&e| e <= at).count()
    }
}

/// Liveness of one node under fail-stop fault injection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeHealth {
    Healthy,
    /// Fail-stop at the given virtual instant: the node's resources are
    /// frozen at that time, its GPU memory contents are lost, and it
    /// never books work again. (This replaces the old "infinite
    /// slowdown ~ dead link" hack, which pinned `Resource::free_at` at
    /// +inf and corrupted utilization accounting.)
    Failed { at_ms: Ms },
}

/// One edge node: a GPU (compute) + its private CPU→GPU link + a GPU
/// memory ledger in *paper-scale* bytes (Table 2(ii) audit).
#[derive(Debug)]
pub struct Node {
    pub id: usize,
    pub gpu: Resource,
    pub pcie: Resource,
    /// Local-SSD read link (tiered cache's cold tier, DESIGN.md §12).
    /// Inert unless the engine stages cold-tier experts on this node.
    pub ssd: Resource,
    /// Paper-scale bytes currently resident on the GPU.
    pub gpu_bytes_used: u64,
    /// High-water mark of `gpu_bytes_used`.
    pub gpu_bytes_peak: u64,
    /// Straggler injection: multiplies this node's PCIe transfer times
    /// (1.0 = healthy; 3.0 = a degraded link). Must be finite — dead
    /// links are [`NodeHealth::Failed`] via [`Cluster::fail_worker`],
    /// never an infinite slowdown.
    pub pcie_slowdown: f64,
    /// Straggler injection for GPU compute on this node.
    pub gpu_slowdown: f64,
    /// Fail-stop state; consulted by every booking entry point.
    pub health: NodeHealth,
}

impl Node {
    pub fn new(id: usize) -> Self {
        Self {
            id,
            gpu: Resource::new(),
            pcie: Resource::new(),
            ssd: Resource::new(),
            gpu_bytes_used: 0,
            gpu_bytes_peak: 0,
            pcie_slowdown: 1.0,
            gpu_slowdown: 1.0,
            health: NodeHealth::Healthy,
        }
    }

    pub fn is_alive(&self) -> bool {
        self.health == NodeHealth::Healthy
    }

    /// The fail-stop instant, if this node has failed.
    pub fn failed_at(&self) -> Option<Ms> {
        match self.health {
            NodeHealth::Healthy => None,
            NodeHealth::Failed { at_ms } => Some(at_ms),
        }
    }

    /// Fail-stop this node at `at_ms`: freeze both resources at the
    /// failure instant (work booked past it never happened) and drop the
    /// GPU memory contents (the ledger keeps its peak for the audit).
    /// Idempotent — a second failure of a dead node is a no-op.
    pub fn fail(&mut self, at_ms: Ms) {
        assert!(at_ms.is_finite() && at_ms >= 0.0, "bad failure time {at_ms}");
        if !self.is_alive() {
            return;
        }
        self.health = NodeHealth::Failed { at_ms };
        self.gpu.preempt(at_ms);
        self.pcie.preempt(at_ms);
        self.ssd.preempt(at_ms);
        self.gpu_bytes_used = 0;
    }

    pub fn alloc(&mut self, bytes: u64) {
        self.gpu_bytes_used += bytes;
        self.gpu_bytes_peak = self.gpu_bytes_peak.max(self.gpu_bytes_used);
    }

    /// Release `bytes` from the ledger, returning the bytes actually
    /// freed. Debug builds assert on underflow; release builds clamp, and
    /// the shortfall is visible in the return value so callers can detect
    /// ledger drift instead of it silently accumulating.
    pub fn dealloc(&mut self, bytes: u64) -> u64 {
        debug_assert!(self.gpu_bytes_used >= bytes, "GPU memory underflow");
        let freed = bytes.min(self.gpu_bytes_used);
        self.gpu_bytes_used -= freed;
        freed
    }

    pub fn reset(&mut self) {
        self.gpu.reset();
        self.pcie.reset();
        self.ssd.reset();
        self.gpu_bytes_used = 0;
        self.gpu_bytes_peak = 0;
        self.health = NodeHealth::Healthy;
    }
}

/// The simulated testbed: main node, shadow node, `n_workers` workers and
/// the shared LAN. Durations come from the base [`HardwareProfile`] for
/// main/shadow/LAN work and from each worker's [`NodeClass`] for
/// worker-side work: [`Cluster::expert_load_chunked`] and the
/// expert-compute helpers consult the owning node's class profile, so a
/// mixed fleet books honest per-class times. [`Cluster::new`] builds the
/// uniform (single-class) cluster, whose per-worker profiles are
/// field-for-field identical to the base — the shared-profile path is
/// the single-class special case, bit-identical by construction.
#[derive(Debug)]
pub struct Cluster {
    pub profile: HardwareProfile,
    pub main: Node,
    pub shadow: Node,
    pub workers: Vec<Node>,
    /// Shared Ethernet segment (the paper's 1 Gbps LAN).
    pub lan: Resource,
    pub trace: Trace,
    /// Per-worker hardware class (uniform class of `profile` by default).
    classes: Vec<NodeClass>,
    /// Materialized per-worker duration models
    /// ([`NodeClass::worker_profile`] over the base profile), consulted
    /// by every worker-side booking.
    worker_profiles: Vec<HardwareProfile>,
}

impl Cluster {
    pub fn new(profile: HardwareProfile, n_workers: usize) -> Self {
        let uniform = NodeClass::of_profile(&profile);
        Self::with_classes(profile, vec![uniform; n_workers])
    }

    /// A heterogeneous cluster: worker `i` is a node of `classes[i]`.
    /// On a mixed fleet the trace tags each worker node with its class
    /// name so `!`/`p`/LAN lines stay readable (uniform clusters are left
    /// untagged — their rendering is pinned by older tests).
    pub fn with_classes(profile: HardwareProfile, classes: Vec<NodeClass>) -> Self {
        let worker_profiles: Vec<HardwareProfile> =
            classes.iter().map(|c| c.worker_profile(&profile)).collect();
        let mut trace = Trace::new();
        if classes.iter().any(|c| c.name != profile.name) {
            for (i, c) in classes.iter().enumerate() {
                trace.tag_node(2 + i, c.name);
            }
        }
        Self {
            profile,
            main: Node::new(0),
            shadow: Node::new(1),
            workers: (0..classes.len()).map(|i| Node::new(2 + i)).collect(),
            lan: Resource::new(),
            trace,
            classes,
            worker_profiles,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// The duration model of worker `w`'s node class — what every
    /// worker-side booking on `w` consults.
    pub fn worker_profile(&self, w: usize) -> &HardwareProfile {
        &self.worker_profiles[w]
    }

    /// Worker `w`'s hardware class.
    pub fn worker_class(&self, w: usize) -> &NodeClass {
        &self.classes[w]
    }

    /// Extra LAN attach latency for messages to/from worker `w`.
    pub fn lan_extra(&self, w: usize) -> Ms {
        self.classes[w].lan_extra_ms
    }

    /// One expert FFN over a `rows`-token batch on worker `w`'s GPU class
    /// (pre-slowdown base duration; `rows == 1` is exactly the class's
    /// `t_expert_gpu_ms`).
    pub fn expert_ffn_ms(&self, w: usize, rows: usize) -> Ms {
        self.worker_profiles[w].expert_batch_ms(rows)
    }

    pub fn reset(&mut self) {
        self.main.reset();
        self.shadow.reset();
        for w in &mut self.workers {
            w.reset();
        }
        self.lan.reset();
        self.trace.clear();
    }

    /// Book a LAN message of `bytes`, earliest at `earliest`. Returns the
    /// arrival time. Latency is paid per message; the shared segment is
    /// serialized at its bandwidth. The trace records the *booked*
    /// interval (the span the shared segment is actually held for) —
    /// propagation latency delays arrival but does not occupy the wire,
    /// so rendered timelines and trace-derived utilization exclude it.
    pub fn lan_send(&mut self, earliest: Ms, bytes: f64, what: &'static str) -> Ms {
        let dur = self.profile.lan_transfer_ms(bytes);
        let (start, end) = self.lan.acquire(earliest, dur);
        let arrival = end + self.profile.lan_lat_ms;
        self.trace.push_lan(start, end, arrival, what);
        arrival
    }

    /// Book an expert load over `worker`'s PCIe link starting no earlier
    /// than `earliest`. Returns (start, done). Honors straggler injection.
    /// Panics on a dead worker: callers must route around failed nodes
    /// (see `coordinator::schedule::SlotMap`) before booking.
    ///
    /// This is the monolithic (single-chunk) special case of
    /// [`Cluster::expert_load_chunked`]; the two book identically at
    /// chunk count 1.
    pub fn expert_load(&mut self, worker: usize, earliest: Ms, bytes: f64) -> (Ms, Ms) {
        let t = self.expert_load_chunked(worker, earliest, bytes, 1, EventKind::ExpertLoad);
        (t.start, t.done())
    }

    /// Book an expert transfer as `chunks` dependent sub-transfers on
    /// `worker`'s PCIe link (DESIGN.md §9): the expert's `w1/w3/w2` tiles
    /// stream back to back, each chunk's completion visible to the
    /// scheduler so expert compute can begin once its first input tile is
    /// resident instead of waiting for the last byte. `kind` tags the
    /// trace events ([`EventKind::ExpertLoad`] for demand loads,
    /// [`EventKind::Prefetch`] for speculative streams). Chunk durations
    /// come from the *owning node's class profile*
    /// ([`HardwareProfile::chunk_durations`] of
    /// [`Cluster::worker_profile`]; identical to the base profile on a
    /// uniform cluster); at `chunks == 1` the booking is bit-identical to
    /// the monolithic [`Cluster::expert_load`].
    pub fn expert_load_chunked(
        &mut self,
        worker: usize,
        earliest: Ms,
        bytes: f64,
        chunks: usize,
        kind: EventKind,
    ) -> ChunkedTransfer {
        let durs = self.worker_profiles[worker].chunk_durations(bytes, chunks);
        self.expert_load_chunks(worker, earliest, &durs, kind)
    }

    /// Book a chunk train with explicit per-chunk durations — the resume
    /// path of a failover re-books only the chunks the dead worker hadn't
    /// delivered (DESIGN.md §9). Durations are pre-slowdown; this method
    /// applies the worker's straggler factor. Panics on a dead worker or
    /// an empty train.
    pub fn expert_load_chunks(
        &mut self,
        worker: usize,
        earliest: Ms,
        durations: &[Ms],
        kind: EventKind,
    ) -> ChunkedTransfer {
        assert!(
            self.workers[worker].is_alive(),
            "expert load booked on dead worker {worker}"
        );
        assert!(!durations.is_empty(), "a transfer needs at least one chunk");
        let slowdown = self.workers[worker].pcie_slowdown;
        let id = self.workers[worker].id;
        let free_before = self.workers[worker].pcie.free_at();
        let mut chunk_ends = Vec::with_capacity(durations.len());
        let mut first_start = Ms::INFINITY;
        let mut next = earliest;
        for &d in durations {
            let (s, e) = self.workers[worker].pcie.acquire(next, d * slowdown);
            self.trace.push(kind, id, s, e, "EL");
            first_start = first_start.min(s);
            chunk_ends.push(e);
            next = e;
        }
        ChunkedTransfer { worker, start: first_start, chunk_ends, free_before }
    }

    /// Stage `bytes` from `worker`'s local SSD into host DRAM (tiered
    /// cache cold-tier hit, DESIGN.md §12). Books on the worker's
    /// [`Node::ssd`] resource — storage reads queue like PCIe transfers
    /// do — using the owning node's class profile for bandwidth/latency.
    /// Returns (start, end); the PCIe chunk train may begin at `end`.
    /// Panics on a dead worker.
    pub fn ssd_stage(&mut self, worker: usize, earliest: Ms, bytes: f64) -> (Ms, Ms) {
        assert!(
            self.workers[worker].is_alive(),
            "SSD staging booked on dead worker {worker}"
        );
        let dur = self.worker_profiles[worker].ssd_stage_ms(bytes);
        let id = self.workers[worker].id;
        let (start, end) = self.workers[worker].ssd.acquire(earliest, dur);
        self.trace.push(EventKind::ExpertLoad, id, start, end, "SSD");
        (start, end)
    }

    /// Book an expert compute of base duration `base_ms` on `worker`'s
    /// GPU starting no earlier than `earliest`. Returns (start, end).
    /// Honors straggler injection; panics on a dead worker.
    pub fn expert_compute(&mut self, worker: usize, earliest: Ms, base_ms: Ms) -> (Ms, Ms) {
        assert!(
            self.workers[worker].is_alive(),
            "expert compute booked on dead worker {worker}"
        );
        let dur = base_ms * self.workers[worker].gpu_slowdown;
        let (start, end) = self.workers[worker].gpu.acquire(earliest, dur);
        self.trace
            .push(EventKind::ExpertCompute, self.workers[worker].id, start, end, "EC");
        (start, end)
    }

    /// Book an expert compute as one tile per input chunk (DESIGN.md §9):
    /// tile `i` (duration `base_ms / gates.len()`) starts no earlier than
    /// `earliest` *and* its chunk's arrival `gates[i]`, so the FFN
    /// pipelines behind the streaming transfer instead of waiting for the
    /// whole expert. With a single gate this is exactly
    /// [`Cluster::expert_compute`] at `max(earliest, gates[0])`, and the
    /// pipelined end never exceeds the monolithic
    /// `max(earliest, last gate) + base_ms` (chunking only ever pulls
    /// compute earlier). Returns (first tile start, last tile end).
    pub fn expert_compute_chunked(
        &mut self,
        worker: usize,
        earliest: Ms,
        base_ms: Ms,
        gates: &[Ms],
    ) -> (Ms, Ms) {
        assert!(
            self.workers[worker].is_alive(),
            "expert compute booked on dead worker {worker}"
        );
        assert!(!gates.is_empty(), "a compute needs at least one tile");
        let tile = base_ms / gates.len() as f64 * self.workers[worker].gpu_slowdown;
        let id = self.workers[worker].id;
        let mut first_start = Ms::INFINITY;
        let mut end = earliest;
        for &g in gates {
            let (s, e) = self.workers[worker].gpu.acquire(earliest.max(g), tile);
            self.trace.push(EventKind::ExpertCompute, id, s, e, "EC");
            first_start = first_start.min(s);
            end = e;
        }
        (first_start, end)
    }

    /// Inject a straggler: worker `w`'s PCIe and GPU run `factor`x slower.
    /// The factor must be finite — a dead node is [`Cluster::fail_worker`],
    /// not an infinite slowdown (which would corrupt virtual time).
    pub fn inject_straggler(&mut self, w: usize, factor: f64) {
        assert!(
            factor >= 1.0 && factor.is_finite(),
            "straggler factor must be finite and >= 1 (got {factor}); \
             use fail_worker for dead nodes"
        );
        self.workers[w].pcie_slowdown = factor;
        self.workers[w].gpu_slowdown = factor;
    }

    /// Fail-stop worker `w` at virtual time `at_ms`: its resources freeze
    /// at the failure instant, its GPU memory contents are lost, and every
    /// later booking attempt on it panics. Idempotent.
    pub fn fail_worker(&mut self, w: usize, at_ms: Ms) {
        if !self.workers[w].is_alive() {
            return;
        }
        self.workers[w].fail(at_ms);
        let id = self.workers[w].id;
        self.trace.push(EventKind::Failure, id, at_ms, at_ms, "fail");
    }

    /// Fail-stop the shadow node at `at_ms`. Engines consult this to fall
    /// back from SEP prediction to reactive (gate-result-driven) loads.
    pub fn fail_shadow(&mut self, at_ms: Ms) {
        if !self.shadow.is_alive() {
            return;
        }
        self.shadow.fail(at_ms);
        let id = self.shadow.id;
        self.trace.push(EventKind::Failure, id, at_ms, at_ms, "fail");
    }

    /// Number of workers still alive.
    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.is_alive()).count()
    }

    /// Peak paper-scale GPU bytes across all nodes (Table 2(ii)).
    pub fn total_gpu_peak_bytes(&self) -> u64 {
        self.main.gpu_bytes_peak
            + self.shadow.gpu_bytes_peak
            + self.workers.iter().map(|w| w.gpu_bytes_peak).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_serializes_bookings() {
        let mut r = Resource::new();
        let (s1, e1) = r.acquire(0.0, 10.0);
        assert_eq!((s1, e1), (0.0, 10.0));
        // Earliest 5 but resource busy until 10 -> starts at 10.
        let (s2, e2) = r.acquire(5.0, 2.0);
        assert_eq!((s2, e2), (10.0, 12.0));
        // Idle gap respected.
        let (s3, _) = r.acquire(20.0, 1.0);
        assert_eq!(s3, 20.0);
        assert_eq!(r.busy_total(), 13.0);
    }

    #[test]
    fn node_memory_ledger() {
        let mut n = Node::new(0);
        n.alloc(100);
        n.alloc(50);
        assert_eq!(n.dealloc(100), 100, "dealloc reports the bytes it freed");
        n.alloc(20);
        assert_eq!(n.gpu_bytes_used, 70);
        assert_eq!(n.gpu_bytes_peak, 150);
        assert_eq!(n.dealloc(70), 70);
        assert_eq!(n.gpu_bytes_used, 0);
    }

    #[test]
    fn lan_is_shared_and_serialized() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        let bytes = 1e6; // 1 MB over 1 Gbps = 8 ms
        let a1 = c.lan_send(0.0, bytes, "m1");
        let a2 = c.lan_send(0.0, bytes, "m2");
        assert!(a2 > a1, "second message must queue behind the first");
        let expected_first = c.profile.lan_transfer_ms(bytes) + c.profile.lan_lat_ms;
        assert!((a1 - expected_first).abs() < 1e-9);
    }

    #[test]
    fn expert_loads_on_different_workers_overlap() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 4);
        let bytes = c.profile.expert_bytes;
        let (_, d0) = c.expert_load(0, 0.0, bytes);
        let (_, d1) = c.expert_load(1, 0.0, bytes);
        // Independent PCIe links: same finish time.
        assert_eq!(d0, d1);
        // Same worker serializes.
        let (_, d2) = c.expert_load(0, 0.0, bytes);
        assert!(d2 > d0);
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        c.lan_send(0.0, 1e6, "x");
        c.workers[0].alloc(10);
        c.fail_worker(1, 5.0);
        c.reset();
        assert_eq!(c.lan.free_at(), 0.0);
        assert_eq!(c.workers[0].gpu_bytes_used, 0);
        assert!(c.workers[1].is_alive(), "reset resurrects failed nodes");
        assert_eq!(c.trace.len(), 0);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn infinite_booking_rejected() {
        let mut r = Resource::new();
        r.acquire(0.0, f64::INFINITY);
    }

    #[test]
    fn preempt_clamps_busy_total() {
        let mut r = Resource::new();
        r.acquire(10.0, 5.0); // busy 5, free_at 15
        // Rewind past the booking start AND the leading idle gap: the
        // reclaimed span clamps to the booked total instead of driving
        // busy_total to -5.
        r.preempt(0.0);
        assert_eq!(r.free_at(), 0.0);
        assert_eq!(r.busy_total(), 0.0);
        assert!(r.busy_total().is_finite());
    }

    #[test]
    fn preempt_mid_booking_reclaims_exact_span() {
        let mut r = Resource::new();
        r.acquire(0.0, 10.0);
        r.preempt(4.0);
        assert_eq!(r.free_at(), 4.0);
        assert_eq!(r.busy_total(), 4.0);
        // Preempting an idle resource is a no-op.
        r.preempt(9.0);
        assert_eq!(r.busy_total(), 4.0);
    }

    #[test]
    fn preempt_never_reclaims_completed_work_or_idle_gaps() {
        // 40 ms of completed work, idle until a residency-gated booking
        // at [100, 130); the node dies at t=60, before the booking even
        // started. Only the cancelled booking's 30 ms is reclaimed — the
        // completed 40 ms survives, and the idle gap is not "reclaimed".
        let mut r = Resource::new();
        r.acquire(0.0, 40.0);
        r.acquire(100.0, 30.0);
        assert_eq!(r.busy_total(), 70.0);
        r.preempt(60.0);
        assert_eq!(r.free_at(), 60.0);
        assert_eq!(r.busy_total(), 40.0, "completed work must survive the preempt");
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn infinite_straggler_rejected() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        c.inject_straggler(0, f64::INFINITY);
    }

    #[test]
    fn fail_worker_freezes_resources_and_drops_memory() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        let (_, done) = c.expert_load(0, 0.0, c.profile.expert_bytes);
        c.workers[0].alloc(100);
        let mid = done / 2.0;
        c.fail_worker(0, mid);
        assert!(!c.workers[0].is_alive());
        assert_eq!(c.workers[0].failed_at(), Some(mid));
        assert_eq!(c.workers[0].pcie.free_at(), mid, "in-flight transfer frozen");
        assert!(c.workers[0].pcie.busy_total() >= 0.0);
        assert!(c.workers[0].pcie.busy_total().is_finite());
        assert_eq!(c.workers[0].gpu_bytes_used, 0, "contents lost with the node");
        assert_eq!(c.workers[0].gpu_bytes_peak, 100, "peak survives for the audit");
        // Idempotent: a second failure does not move the freeze point.
        c.fail_worker(0, 0.0);
        assert_eq!(c.workers[0].failed_at(), Some(mid));
        assert_eq!(c.alive_workers(), 1);
    }

    #[test]
    #[should_panic(expected = "dead worker")]
    fn booking_on_dead_worker_panics() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        c.fail_worker(0, 0.0);
        c.expert_load(0, 1.0, c.profile.expert_bytes);
    }

    #[test]
    fn lan_trace_records_booked_interval_not_propagation() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 1);
        c.trace.enabled = true;
        let bytes = 1e6;
        let arrival = c.lan_send(0.0, bytes, "m");
        let ev = &c.trace.events()[0];
        assert_eq!(ev.end, c.lan.free_at(), "event spans the booked interval");
        assert_eq!(ev.arrival, Some(arrival), "arrival carried separately");
        assert!((arrival - (ev.end + c.profile.lan_lat_ms)).abs() < 1e-12);
        assert!((ev.end - ev.start - c.profile.lan_transfer_ms(bytes)).abs() < 1e-12);
    }

    #[test]
    fn chunked_load_of_one_chunk_is_the_monolithic_booking() {
        let mut a = Cluster::new(HardwareProfile::rtx3090(), 2);
        let mut b = Cluster::new(HardwareProfile::rtx3090(), 2);
        a.inject_straggler(0, 2.5);
        b.inject_straggler(0, 2.5);
        let bytes = a.profile.expert_bytes;
        let (s, e) = a.expert_load(0, 3.0, bytes);
        let t = b.expert_load_chunked(0, 3.0, bytes, 1, EventKind::ExpertLoad);
        assert_eq!((s, e), (t.start, t.done()));
        assert_eq!(t.first_ready(), t.done(), "one chunk: first == last");
        assert_eq!(
            a.workers[0].pcie.busy_total(),
            b.workers[0].pcie.busy_total(),
            "identical link accounting"
        );
    }

    #[test]
    fn chunk_train_is_contiguous_and_first_chunk_lands_early() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 1);
        let bytes = c.profile.expert_bytes;
        let mono = c.profile.pcie_transfer_ms(bytes);
        let t = c.expert_load_chunked(0, 0.0, bytes, 4, EventKind::ExpertLoad);
        assert_eq!(t.chunk_ends.len(), 4);
        assert!(t.first_ready() < mono / 3.0, "first tile resident ~4x earlier");
        let expected_done = mono + 3.0 * c.profile.chunk_overhead_ms;
        assert!((t.done() - expected_done).abs() < 1e-9, "{} vs {expected_done}", t.done());
        for w in t.chunk_ends.windows(2) {
            assert!(w[1] > w[0], "chunks complete in order");
        }
        assert_eq!(t.delivered_by(t.chunk_ends[1]), 2);
        assert_eq!(t.delivered_by(t.chunk_ends[1] - 1e-9), 1, "in-flight chunk not delivered");
    }

    #[test]
    fn abort_of_chunk_train_reclaims_only_undelivered_chunks() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 1);
        let bytes = c.profile.expert_bytes;
        let t = c.expert_load_chunked(0, 0.0, bytes, 4, EventKind::ExpertLoad);
        // Abort mid third chunk: two delivered chunks stay busy (wasted
        // but transferred), the in-flight tail and the fourth chunk are
        // reclaimed.
        let at = (t.chunk_ends[1] + t.chunk_ends[2]) / 2.0;
        c.workers[0].pcie.preempt(at.max(t.free_before));
        assert_eq!(c.workers[0].pcie.free_at(), at);
        assert!((c.workers[0].pcie.busy_total() - at).abs() < 1e-9);
    }

    #[test]
    fn chunked_compute_pipelines_behind_the_stream() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 1);
        let bytes = c.profile.expert_bytes;
        let base = c.profile.t_expert_gpu_ms;
        let t = c.expert_load_chunked(0, 0.0, bytes, 4, EventKind::ExpertLoad);
        let (start, end) = c.expert_compute_chunked(0, 0.0, base, &t.chunk_ends);
        assert_eq!(start, t.first_ready(), "first tile starts on the first chunk");
        // The transfer is the pipeline bottleneck: the last tile runs
        // right after the last chunk, so the end beats done + base.
        assert!(end < t.done() + base);
        assert!((end - (t.done() + base / 4.0)).abs() < 1e-9);
        // GPU busy time is exactly one FFN regardless of tiling.
        assert!((c.workers[0].gpu.busy_total() - base).abs() < 1e-9);
    }

    #[test]
    fn chunked_compute_with_one_gate_matches_monolithic() {
        let mut a = Cluster::new(HardwareProfile::rtx3090(), 1);
        let mut b = Cluster::new(HardwareProfile::rtx3090(), 1);
        let (s1, e1) = a.expert_compute(0, 5.0, 2.0);
        let (s2, e2) = b.expert_compute_chunked(0, 5.0, 2.0, &[4.0]);
        assert_eq!((s1, e1), (s2, e2));
    }

    #[test]
    fn uniform_cluster_worker_profiles_match_the_base() {
        let base = HardwareProfile::rtx3090();
        let c = Cluster::new(base.clone(), 3);
        for w in 0..3 {
            let wp = c.worker_profile(w);
            assert_eq!(wp.t_expert_gpu_ms, base.t_expert_gpu_ms);
            assert_eq!(wp.pcie_gbps, base.pcie_gbps);
            assert_eq!(
                wp.chunk_durations(base.expert_bytes, 4),
                base.chunk_durations(base.expert_bytes, 4),
                "single-class chunk trains are the shared-profile trains"
            );
            assert_eq!(c.lan_extra(w), 0.0);
            assert_eq!(c.expert_ffn_ms(w, 1), base.t_expert_gpu_ms);
        }
        assert!(c.trace.class_of(2).is_none(), "uniform clusters stay untagged");
    }

    #[test]
    fn heterogeneous_workers_book_their_class_durations() {
        let base = HardwareProfile::rtx3090();
        let mut c =
            Cluster::with_classes(base.clone(), vec![NodeClass::rtx3090(), NodeClass::jetson()]);
        let bytes = base.expert_bytes;
        let (_, d0) = c.expert_load(0, 0.0, bytes);
        let (_, d1) = c.expert_load(1, 0.0, bytes);
        assert!((d0 - base.pcie_transfer_ms(bytes)).abs() < 1e-9);
        assert!(d1 > 3.0 * d0, "jetson's thin link books honestly: {d1} vs {d0}");
        assert_eq!(c.worker_class(1).name, "jetson");
        assert!(c.lan_extra(1) > 0.0 && c.lan_extra(0) == 0.0);
        assert!(c.expert_ffn_ms(1, 1) > c.expert_ffn_ms(0, 1), "slower FFN class");
        // Mixed fleets tag trace nodes with their class.
        assert_eq!(c.trace.class_of(2), Some("rtx3090"));
        assert_eq!(c.trace.class_of(3), Some("jetson"));
    }

    #[test]
    fn expert_compute_honors_straggler_injection() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        c.inject_straggler(1, 3.0);
        let (_, e0) = c.expert_compute(0, 0.0, 2.0);
        let (_, e1) = c.expert_compute(1, 0.0, 2.0);
        assert_eq!(e0, 2.0);
        assert_eq!(e1, 6.0);
    }
}
