//! Virtual-time edge-cluster simulator.
//!
//! The paper's speed results are scheduling/queueing phenomena over four
//! hardware quantities — main-node compute `t_M`, worker expert compute
//! `t_W`, CPU→GPU expert-load time, and LAN message time. The simulator
//! models each node's GPU and PCIe link plus the shared LAN as *resources
//! with availability timestamps*; engines schedule tasks as
//! `start = max(dependencies, resource_free)`, `end = start + duration`,
//! exactly the dependency structure of the paper's Fig. 2/4/5 timing
//! diagrams. Numerics (which expert, which token) come from real PJRT
//! executions; only durations are simulated. See DESIGN.md §4.

pub mod profile;

pub use profile::HardwareProfile;

use crate::trace::{EventKind, Trace};

/// Milliseconds of virtual time.
pub type Ms = f64;

/// A serially-reusable resource (a GPU, a PCIe link, the LAN).
///
/// `acquire(earliest, duration)` books the resource for `duration` ms at
/// the first instant >= both `earliest` and the resource's availability,
/// returning the (start, end) of the booking.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    free_at: Ms,
    busy_total: Ms,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn acquire(&mut self, earliest: Ms, duration: Ms) -> (Ms, Ms) {
        debug_assert!(duration >= 0.0, "negative duration");
        let start = self.free_at.max(earliest);
        let end = start + duration;
        self.free_at = end;
        self.busy_total += duration;
        (start, end)
    }

    /// Next instant this resource is idle.
    pub fn free_at(&self) -> Ms {
        self.free_at
    }

    /// Abort the in-flight booking at time `at`: the resource becomes free
    /// at `at` if it was booked past it (mispredicted expert loads are
    /// cancelled the moment the gate result disagrees — paper §3.1).
    pub fn preempt(&mut self, at: Ms) {
        if self.free_at > at {
            self.busy_total -= self.free_at - at;
            self.free_at = at;
        }
    }

    /// Total booked time (utilization accounting).
    pub fn busy_total(&self) -> Ms {
        self.busy_total
    }

    pub fn reset(&mut self) {
        self.free_at = 0.0;
        self.busy_total = 0.0;
    }
}

/// One edge node: a GPU (compute) + its private CPU→GPU link + a GPU
/// memory ledger in *paper-scale* bytes (Table 2(ii) audit).
#[derive(Debug)]
pub struct Node {
    pub id: usize,
    pub gpu: Resource,
    pub pcie: Resource,
    /// Paper-scale bytes currently resident on the GPU.
    pub gpu_bytes_used: u64,
    /// High-water mark of `gpu_bytes_used`.
    pub gpu_bytes_peak: u64,
    /// Straggler injection: multiplies this node's PCIe transfer times
    /// (1.0 = healthy; 3.0 = a degraded link; f64::INFINITY ~ dead link).
    pub pcie_slowdown: f64,
    /// Straggler injection for GPU compute on this node.
    pub gpu_slowdown: f64,
}

impl Node {
    pub fn new(id: usize) -> Self {
        Self {
            id,
            gpu: Resource::new(),
            pcie: Resource::new(),
            gpu_bytes_used: 0,
            gpu_bytes_peak: 0,
            pcie_slowdown: 1.0,
            gpu_slowdown: 1.0,
        }
    }

    pub fn alloc(&mut self, bytes: u64) {
        self.gpu_bytes_used += bytes;
        self.gpu_bytes_peak = self.gpu_bytes_peak.max(self.gpu_bytes_used);
    }

    /// Release `bytes` from the ledger, returning the bytes actually
    /// freed. Debug builds assert on underflow; release builds clamp, and
    /// the shortfall is visible in the return value so callers can detect
    /// ledger drift instead of it silently accumulating.
    pub fn dealloc(&mut self, bytes: u64) -> u64 {
        debug_assert!(self.gpu_bytes_used >= bytes, "GPU memory underflow");
        let freed = bytes.min(self.gpu_bytes_used);
        self.gpu_bytes_used -= freed;
        freed
    }

    pub fn reset(&mut self) {
        self.gpu.reset();
        self.pcie.reset();
        self.gpu_bytes_used = 0;
        self.gpu_bytes_peak = 0;
    }
}

/// The simulated testbed: main node, shadow node, `n_workers` workers and
/// the shared LAN, with durations supplied by a [`HardwareProfile`].
#[derive(Debug)]
pub struct Cluster {
    pub profile: HardwareProfile,
    pub main: Node,
    pub shadow: Node,
    pub workers: Vec<Node>,
    /// Shared Ethernet segment (the paper's 1 Gbps LAN).
    pub lan: Resource,
    pub trace: Trace,
}

impl Cluster {
    pub fn new(profile: HardwareProfile, n_workers: usize) -> Self {
        Self {
            profile,
            main: Node::new(0),
            shadow: Node::new(1),
            workers: (0..n_workers).map(|i| Node::new(2 + i)).collect(),
            lan: Resource::new(),
            trace: Trace::new(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn reset(&mut self) {
        self.main.reset();
        self.shadow.reset();
        for w in &mut self.workers {
            w.reset();
        }
        self.lan.reset();
        self.trace.clear();
    }

    /// Book a LAN message of `bytes`, earliest at `earliest`. Returns the
    /// arrival time. Latency is paid per message; the shared segment is
    /// serialized at its bandwidth.
    pub fn lan_send(&mut self, earliest: Ms, bytes: f64, what: &'static str) -> Ms {
        let dur = self.profile.lan_transfer_ms(bytes);
        let (start, end) = self.lan.acquire(earliest, dur);
        let arrival = end + self.profile.lan_lat_ms;
        self.trace.push(EventKind::LanSend, usize::MAX, start, arrival, what);
        arrival
    }

    /// Book an expert load over `worker`'s PCIe link starting no earlier
    /// than `earliest`. Returns (start, done). Honors straggler injection.
    pub fn expert_load(&mut self, worker: usize, earliest: Ms, bytes: f64) -> (Ms, Ms) {
        let dur = self.profile.pcie_transfer_ms(bytes) * self.workers[worker].pcie_slowdown;
        let (start, end) = self.workers[worker].pcie.acquire(earliest, dur);
        self.trace
            .push(EventKind::ExpertLoad, self.workers[worker].id, start, end, "EL");
        (start, end)
    }

    /// Inject a straggler: worker `w`'s PCIe and GPU run `factor`x slower.
    pub fn inject_straggler(&mut self, w: usize, factor: f64) {
        assert!(factor >= 1.0, "straggler factor must be >= 1");
        self.workers[w].pcie_slowdown = factor;
        self.workers[w].gpu_slowdown = factor;
    }

    /// Peak paper-scale GPU bytes across all nodes (Table 2(ii)).
    pub fn total_gpu_peak_bytes(&self) -> u64 {
        self.main.gpu_bytes_peak
            + self.shadow.gpu_bytes_peak
            + self.workers.iter().map(|w| w.gpu_bytes_peak).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_serializes_bookings() {
        let mut r = Resource::new();
        let (s1, e1) = r.acquire(0.0, 10.0);
        assert_eq!((s1, e1), (0.0, 10.0));
        // Earliest 5 but resource busy until 10 -> starts at 10.
        let (s2, e2) = r.acquire(5.0, 2.0);
        assert_eq!((s2, e2), (10.0, 12.0));
        // Idle gap respected.
        let (s3, _) = r.acquire(20.0, 1.0);
        assert_eq!(s3, 20.0);
        assert_eq!(r.busy_total(), 13.0);
    }

    #[test]
    fn node_memory_ledger() {
        let mut n = Node::new(0);
        n.alloc(100);
        n.alloc(50);
        assert_eq!(n.dealloc(100), 100, "dealloc reports the bytes it freed");
        n.alloc(20);
        assert_eq!(n.gpu_bytes_used, 70);
        assert_eq!(n.gpu_bytes_peak, 150);
        assert_eq!(n.dealloc(70), 70);
        assert_eq!(n.gpu_bytes_used, 0);
    }

    #[test]
    fn lan_is_shared_and_serialized() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        let bytes = 1e6; // 1 MB over 1 Gbps = 8 ms
        let a1 = c.lan_send(0.0, bytes, "m1");
        let a2 = c.lan_send(0.0, bytes, "m2");
        assert!(a2 > a1, "second message must queue behind the first");
        let expected_first = c.profile.lan_transfer_ms(bytes) + c.profile.lan_lat_ms;
        assert!((a1 - expected_first).abs() < 1e-9);
    }

    #[test]
    fn expert_loads_on_different_workers_overlap() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 4);
        let bytes = c.profile.expert_bytes;
        let (_, d0) = c.expert_load(0, 0.0, bytes);
        let (_, d1) = c.expert_load(1, 0.0, bytes);
        // Independent PCIe links: same finish time.
        assert_eq!(d0, d1);
        // Same worker serializes.
        let (_, d2) = c.expert_load(0, 0.0, bytes);
        assert!(d2 > d0);
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 2);
        c.lan_send(0.0, 1e6, "x");
        c.workers[0].alloc(10);
        c.reset();
        assert_eq!(c.lan.free_at(), 0.0);
        assert_eq!(c.workers[0].gpu_bytes_used, 0);
        assert_eq!(c.trace.len(), 0);
    }
}
