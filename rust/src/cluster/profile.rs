//! Hardware profiles: the virtual-time duration model.
//!
//! All durations are *paper-scale*: they describe Mixtral-8x7B work units
//! on the paper's testbed (RTX 3090/3080 nodes, PCIe 4.0 x16, 1 Gbps LAN),
//! translated from the paper's own published figures:
//!
//! * fully-cached decode = 4.89 tok/s over 32 layers
//!   → `t_nonexpert + 2*t_expert ≈ 6.3 ms/layer` on a 3090;
//! * expert transfer ≈ 500 MB effective (FP16 weights + framing) over
//!   PCIe 4.0 x16 at ≈ 25 GB/s → load ≈ 20.2 ms, just inside the Eq. (1)
//!   no-stall window `4*t_M + 3*t_W ≈ 20.5 ms` — the knife's-edge the
//!   whole design balances on;
//! * llama.cpp CPU decode = 0.82 tok/s → ≈ 38 ms/layer on CPU;
//! * LAN embedding message = 16 KB/token/hop, KV alignment = 256 KB/token.
//!
//! The calibration is recorded in EXPERIMENTS.md §Calibration. Simulated
//! engines combine these quantities through the Fig. 2/4/5 dependency
//! graphs; nothing else about speed is assumed.

use anyhow::{ensure, Result};

use super::Ms;

/// Duration model for one testbed configuration.
#[derive(Debug, Clone)]
pub struct HardwareProfile {
    pub name: &'static str,
    /// Main-node non-expert compute per layer (attention, norms, gating).
    pub t_nonexpert_ms: Ms,
    /// One expert FFN (decode, 1 token) on a worker/main GPU.
    pub t_expert_gpu_ms: Ms,
    /// Final norm + LM head + sampling.
    pub t_lm_head_ms: Ms,
    /// One full shadow-model layer (quantized, incl. its experts) on the
    /// shadow node. Must be < t_M + t_W for SEP to run ahead (paper §3.1).
    pub t_shadow_layer_ms: Ms,
    /// Bytes of one expert as *transferred/served on workers* at paper
    /// scale: 500 MB effective (FP16 weights + transfer framing/buffer
    /// overhead). The paper's own worker budget (<1 GB incl. workspace)
    /// rules out raw FP32 (704 MB); 500 MB places the load time just
    /// inside the Eq. (1) window — the knife's-edge the paper's design
    /// balances on (numerics stay FP32 in this repo; in-flight precision
    /// is a bandwidth property, see EXPERIMENTS.md §Calibration).
    pub expert_bytes: f64,
    /// Bytes of one FP32 expert (704 MB) — memory-audit + baseline
    /// load-factor reference.
    pub expert_bytes_fp32: f64,
    /// Effective CPU→GPU bandwidth per node, GB/s.
    pub pcie_gbps: f64,
    /// Per-transfer PCIe latency.
    pub pcie_lat_ms: Ms,
    /// Re-issue overhead of each sub-expert chunk after the first when a
    /// transfer streams as K chunks (descriptor setup / ring doorbell —
    /// far below the full per-transfer latency). A K-chunk stream costs
    /// `pcie_transfer_ms(bytes) + (K-1) * chunk_overhead_ms` total, so
    /// chunk count 1 is exactly the monolithic transfer (DESIGN.md §9).
    pub chunk_overhead_ms: Ms,
    /// Shared LAN bandwidth, Gb/s.
    pub lan_gbps: f64,
    /// Per-message LAN latency.
    pub lan_lat_ms: Ms,
    /// Embedding message bytes per token per hop (paper §4.2: ~16 KB).
    pub embed_msg_bytes: f64,
    /// KV-cache alignment payload per token (paper §4.2: 256 KB).
    pub kv_align_bytes: f64,
    /// Token alignment payload (a few bytes).
    pub token_msg_bytes: f64,
    /// CPU-only per-layer times (llama.cpp reference).
    pub cpu_nonexpert_ms: Ms,
    pub cpu_expert_ms: Ms,
    /// Batched-expert efficiency: computing a T-token batch on one expert
    /// costs `t_expert * (1 + (T-1) * batch_marginal)` (GPU matmuls are
    /// weight-bound at these sizes — a 128-token batch costs ~2x one
    /// token, which is what makes the paper's Transformers TTFT(128) only
    /// 447 ms).
    pub batch_marginal: f64,
    /// Same efficiency factor for the main node's batched prefill
    /// attention.
    pub prefill_attn_marginal: f64,
    /// Paper-scale GPU-memory constants (Table 2(ii) audit).
    pub nonexpert_bytes: f64,
    pub shadow_model_bytes: f64,
    pub activation_bytes: f64,
    /// Local-SSD read bandwidth, GB/s (tiered cache's cold tier,
    /// DESIGN.md §12). Storage I/O books on its own per-worker
    /// `Resource`, making it a schedulable bottleneck like PCIe.
    pub ssd_gbps: f64,
    /// Per-read SSD access latency.
    pub ssd_lat_ms: Ms,
}

impl HardwareProfile {
    /// Enforce the §3.1 invariants that used to live only in doc
    /// comments: every duration/bandwidth is finite and positive where it
    /// must be, batching marginals stay in `[0, 1]`, and the shadow node
    /// runs ahead of the pipeline (`t_shadow_layer < t_M + t_W`, the
    /// precondition for SEP predictions to arrive before they are
    /// needed). Presets assert this at construction; `FleetSpec` parsing
    /// and the planner validate every materialized per-class profile.
    pub fn validate(&self) -> Result<()> {
        let pos = |v: f64, what: &str| -> Result<()> {
            ensure!(v.is_finite() && v > 0.0, "{what} must be finite and > 0, got {v}");
            Ok(())
        };
        let nonneg = |v: f64, what: &str| -> Result<()> {
            ensure!(v.is_finite() && v >= 0.0, "{what} must be finite and >= 0, got {v}");
            Ok(())
        };
        pos(self.t_nonexpert_ms, "t_nonexpert_ms")?;
        pos(self.t_expert_gpu_ms, "t_expert_gpu_ms")?;
        pos(self.t_lm_head_ms, "t_lm_head_ms")?;
        pos(self.t_shadow_layer_ms, "t_shadow_layer_ms")?;
        pos(self.cpu_nonexpert_ms, "cpu_nonexpert_ms")?;
        pos(self.cpu_expert_ms, "cpu_expert_ms")?;
        pos(self.pcie_gbps, "pcie_gbps")?;
        pos(self.lan_gbps, "lan_gbps")?;
        pos(self.expert_bytes, "expert_bytes")?;
        pos(self.expert_bytes_fp32, "expert_bytes_fp32")?;
        nonneg(self.pcie_lat_ms, "pcie_lat_ms")?;
        nonneg(self.chunk_overhead_ms, "chunk_overhead_ms")?;
        nonneg(self.lan_lat_ms, "lan_lat_ms")?;
        nonneg(self.embed_msg_bytes, "embed_msg_bytes")?;
        nonneg(self.kv_align_bytes, "kv_align_bytes")?;
        nonneg(self.token_msg_bytes, "token_msg_bytes")?;
        nonneg(self.nonexpert_bytes, "nonexpert_bytes")?;
        nonneg(self.shadow_model_bytes, "shadow_model_bytes")?;
        nonneg(self.activation_bytes, "activation_bytes")?;
        pos(self.ssd_gbps, "ssd_gbps")?;
        nonneg(self.ssd_lat_ms, "ssd_lat_ms")?;
        for (v, what) in [
            (self.batch_marginal, "batch_marginal"),
            (self.prefill_attn_marginal, "prefill_attn_marginal"),
        ] {
            ensure!(
                v.is_finite() && (0.0..=1.0).contains(&v),
                "{what} must lie in [0, 1], got {v}"
            );
        }
        ensure!(
            self.t_shadow_layer_ms < self.t_main_ms() + self.t_worker_ms(),
            "SEP cannot run ahead: t_shadow_layer_ms {} >= t_M + t_W {} (paper §3.1)",
            self.t_shadow_layer_ms,
            self.t_main_ms() + self.t_worker_ms()
        );
        Ok(())
    }

    /// The paper's main testbed: ten nodes with RTX 3090s.
    pub fn rtx3090() -> Self {
        let p = Self {
            name: "rtx3090",
            t_nonexpert_ms: 3.5,
            t_expert_gpu_ms: 1.4,
            t_lm_head_ms: 2.0,
            t_shadow_layer_ms: 2.8,
            expert_bytes: 500e6,
            expert_bytes_fp32: 704e6,
            pcie_gbps: 25.0,
            pcie_lat_ms: 0.2,
            chunk_overhead_ms: 0.01,
            lan_gbps: 1.0,
            lan_lat_ms: 0.15,
            embed_msg_bytes: 16_384.0,
            kv_align_bytes: 262_144.0,
            token_msg_bytes: 64.0,
            cpu_nonexpert_ms: 12.0,
            cpu_expert_ms: 13.0,
            batch_marginal: 0.02,
            prefill_attn_marginal: 0.02,
            nonexpert_bytes: 7e9,      // paper: 7 GB on the main node
            shadow_model_bytes: 45e9,  // paper: 45 GB INT8 shadow
            activation_bytes: 0.3e9,   // compute workspace per worker
            ssd_gbps: 3.5,             // NVMe-class local storage
            ssd_lat_ms: 0.1,
        };
        p.validate().expect("rtx3090 preset violates §3.1 invariants");
        p
    }

    /// Fig. 10 variant: worker GPUs replaced by RTX 3080s (slower expert
    /// compute, slightly slower PCIe effective bandwidth).
    pub fn rtx3080_workers() -> Self {
        let p = Self {
            name: "rtx3080-workers",
            t_expert_gpu_ms: 1.9,
            pcie_gbps: 22.0,
            ..Self::rtx3090()
        };
        p.validate().expect("rtx3080-workers preset violates §3.1 invariants");
        p
    }

    /// Single-server reference for the baselines (8x3090 box; same GPU
    /// speeds, one PCIe link for all offloading traffic).
    pub fn gpu_server() -> Self {
        Self { name: "gpu-server", ..Self::rtx3090() }
    }

    /// One expert-load over PCIe at `precision_factor` of FP32 bytes.
    pub fn expert_load_ms(&self, precision_factor: f64) -> Ms {
        self.pcie_lat_ms + self.pcie_transfer_ms(self.expert_bytes * precision_factor)
    }

    /// PCIe transfer time for `bytes`.
    pub fn pcie_transfer_ms(&self, bytes: f64) -> Ms {
        bytes / (self.pcie_gbps * 1e9) * 1e3
    }

    /// SSD→DRAM staging time for `bytes` (tiered cache's cold tier,
    /// DESIGN.md §12): access latency + read at `ssd_gbps`.
    pub fn ssd_stage_ms(&self, bytes: f64) -> Ms {
        self.ssd_lat_ms + bytes / (self.ssd_gbps * 1e9) * 1e3
    }

    /// Per-chunk durations of a `bytes` transfer streamed as `chunks`
    /// equal sub-transfers: every chunk moves `1/chunks` of the payload,
    /// and each chunk after the first pays [`chunk_overhead_ms`]
    /// (re-issue cost). At `chunks == 1` the single duration is exactly
    /// [`Self::pcie_transfer_ms`] — the monolithic booking.
    ///
    /// [`chunk_overhead_ms`]: HardwareProfile::chunk_overhead_ms
    pub fn chunk_durations(&self, bytes: f64, chunks: usize) -> Vec<Ms> {
        assert!(chunks >= 1, "a transfer needs at least one chunk");
        let per = self.pcie_transfer_ms(bytes) / chunks as f64;
        (0..chunks)
            .map(|i| if i == 0 { per } else { per + self.chunk_overhead_ms })
            .collect()
    }

    /// Expert-load latency as seen by the decode critical path when the
    /// transfer streams as `chunks` sub-transfers and the expert FFN
    /// pipelines behind it (DESIGN.md §9): all but the first chunk can
    /// hide behind compute, capped by the compute's own length, so the
    /// effective latency is the full stream minus
    /// `min(stream - first_chunk, t_expert)`. At `chunks == 1` this is
    /// exactly [`Self::expert_load_ms`] — nothing hides. The result is
    /// additionally capped at the monolithic latency: past the point
    /// where per-chunk overhead outweighs what the pipeline hides
    /// (absurd chunk counts), a coordinator would fall back to the
    /// monolithic transfer rather than stream at a loss, so chunking
    /// never *worsens* the deadline this models.
    pub fn effective_load_ms(&self, chunks: usize) -> Ms {
        assert!(chunks >= 1, "a transfer needs at least one chunk");
        let mono = self.expert_load_ms(1.0);
        let total = mono + (chunks as f64 - 1.0) * self.chunk_overhead_ms;
        let first = self.pcie_lat_ms + self.pcie_transfer_ms(self.expert_bytes) / chunks as f64;
        let hidden = (total - first).min(self.t_expert_gpu_ms).max(0.0);
        (total - hidden).min(mono)
    }

    /// LAN serialization time for `bytes` (latency added per message by
    /// the cluster).
    pub fn lan_transfer_ms(&self, bytes: f64) -> Ms {
        bytes * 8.0 / (self.lan_gbps * 1e9) * 1e3
    }

    /// Expert compute for a T-token batch (prefill mini-batches, §3.3).
    pub fn expert_batch_ms(&self, t: usize) -> Ms {
        self.batched_ms(self.t_expert_gpu_ms, t)
    }

    /// Any GPU task of single-item duration `base` over an `n`-item batch:
    /// `base * (1 + (n-1) * batch_marginal)` — the same weight-bound
    /// efficiency model as [`HardwareProfile::expert_batch_ms`], also used
    /// for batched-decode attention/LM-head/shadow time across concurrent
    /// sessions (one token per session behaves like one batch row).
    pub fn batched_ms(&self, base: Ms, n: usize) -> Ms {
        if n == 0 {
            return 0.0;
        }
        base * (1.0 + (n as f64 - 1.0) * self.batch_marginal)
    }

    /// Main-node task time `t_M` = non-expert compute + the two LAN hops
    /// of one embedding message (paper Eq. 1 folds comm into t_M).
    pub fn t_main_ms(&self) -> Ms {
        self.t_nonexpert_ms
            + 2.0 * (self.lan_lat_ms + self.lan_transfer_ms(self.embed_msg_bytes))
    }

    /// Worker task time `t_W` (experts in a group run in parallel).
    pub fn t_worker_ms(&self) -> Ms {
        self.t_expert_gpu_ms
    }

    /// Paper Eq. (1): max expert-load window without an I/O bottleneck for
    /// `n_groups` staggered worker groups.
    pub fn t_maxload_ms(&self, n_groups: usize) -> Ms {
        n_groups as f64 * self.t_main_ms() + (n_groups as f64 - 1.0) * self.t_worker_ms()
    }

    /// Failover feasibility (DESIGN.md §8/§9): can a worker serving
    /// `slots` expert slots fit all of its per-cycle loads inside the
    /// `n_groups`-stagger Eq. (1) window? A healthy worker serves one
    /// slot; rerouting a dead worker's slot onto it doubles its per-cycle
    /// load time, and `coordinator::schedule::SlotMap::fail` prefers
    /// targets for which this still holds. The deadline is
    /// *earliest-first-chunk* aware: with chunked streaming the compute
    /// pipeline hides all but the first chunk (up to the FFN length), so
    /// each slot charges [`Self::effective_load_ms`] rather than the
    /// whole-expert latency — at `chunks == 1` this is the original
    /// whole-expert-deadline predicate.
    pub fn reroute_feasible(&self, slots: usize, n_groups: usize, chunks: usize) -> bool {
        slots as f64 * self.effective_load_ms(chunks) <= self.t_maxload_ms(n_groups)
    }
}

/// One hardware class of fleet workers (DESIGN.md §10): the per-node
/// knobs that differ across a heterogeneous edge fleet — GPU speed, PCIe
/// bandwidth/latency, provisioned memory, and the LAN attach. Main-node,
/// shadow-node and shared-LAN constants stay on the cluster's *base*
/// [`HardwareProfile`]; [`NodeClass::worker_profile`] materializes the
/// full duration model for one node of this class. The uniform class
/// built by [`NodeClass::of_profile`] reproduces the base profile
/// bit-identically, which is how the single-class fleet stays pinned to
/// the shared-profile behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeClass {
    pub name: &'static str,
    /// One expert FFN (decode, 1 token) on this class's GPU.
    pub t_expert_gpu_ms: Ms,
    /// Effective CPU→GPU bandwidth of this class, GB/s.
    pub pcie_gbps: f64,
    /// Per-transfer PCIe latency.
    pub pcie_lat_ms: Ms,
    /// Per-chunk re-issue overhead when transfers stream (DESIGN.md §9).
    pub chunk_overhead_ms: Ms,
    /// Batched-FFN efficiency of this class's GPU.
    pub batch_marginal: f64,
    /// Provisioned GPU memory per node, bytes at paper scale — the
    /// planner's per-node budget. `f64::INFINITY` = unchecked (the
    /// uniform class, where the budget question does not arise).
    pub mem_bytes: f64,
    /// Extra LAN attach latency for messages to/from nodes of this class
    /// (e.g. a Wi-Fi hop instead of wired Ethernet).
    pub lan_extra_ms: Ms,
    /// Relative per-node cost, in deployment bill units (rtx3090 = 1.0).
    pub unit_cost: f64,
}

impl NodeClass {
    /// The uniform class of a base profile: every field copied verbatim,
    /// memory unchecked, wired LAN. `worker_profile(base)` of this class
    /// is field-for-field identical to `base`.
    pub fn of_profile(p: &HardwareProfile) -> Self {
        Self {
            name: p.name,
            t_expert_gpu_ms: p.t_expert_gpu_ms,
            pcie_gbps: p.pcie_gbps,
            pcie_lat_ms: p.pcie_lat_ms,
            chunk_overhead_ms: p.chunk_overhead_ms,
            batch_marginal: p.batch_marginal,
            mem_bytes: f64::INFINITY,
            lan_extra_ms: 0.0,
            unit_cost: 1.0,
        }
    }

    /// The paper's main worker class (24 GB card, PCIe 4.0 x16).
    pub fn rtx3090() -> Self {
        Self { mem_bytes: 24e9, ..Self::of_profile(&HardwareProfile::rtx3090()) }
    }

    /// Fig. 10's cheaper workers: slower FFN, slightly slower link, 10 GB.
    pub fn rtx3080() -> Self {
        Self {
            name: "rtx3080",
            t_expert_gpu_ms: 1.9,
            pcie_gbps: 22.0,
            mem_bytes: 10e9,
            unit_cost: 0.6,
            ..Self::rtx3090()
        }
    }

    /// Embedded-class edge node (Jetson-like): slow shared-memory
    /// "PCIe", slower FFN, Wi-Fi attach. Cannot hold the Eq. (1) window
    /// at full transfer precision — the planner's precision/chunking
    /// knobs are what make this class deployable.
    pub fn jetson() -> Self {
        Self {
            name: "jetson",
            t_expert_gpu_ms: 3.2,
            pcie_gbps: 8.0,
            pcie_lat_ms: 0.4,
            chunk_overhead_ms: 0.02,
            batch_marginal: 0.05,
            mem_bytes: 4e9,
            lan_extra_ms: 0.1,
            unit_cost: 0.35,
        }
    }

    /// Bottom-tier edge node (Nano-like): the paper's "less-than-1 GB"
    /// worker taken literally. Memory binds before bandwidth does.
    pub fn nano() -> Self {
        Self {
            name: "nano",
            t_expert_gpu_ms: 6.5,
            pcie_gbps: 4.0,
            pcie_lat_ms: 0.6,
            chunk_overhead_ms: 0.04,
            batch_marginal: 0.08,
            mem_bytes: 1e9,
            lan_extra_ms: 0.2,
            unit_cost: 0.15,
        }
    }

    /// Preset lookup for `FleetSpec` parsing.
    pub fn preset(name: &str) -> Option<Self> {
        Some(match name {
            "rtx3090" => Self::rtx3090(),
            "rtx3080" => Self::rtx3080(),
            "jetson" => Self::jetson(),
            "nano" => Self::nano(),
            _ => return None,
        })
    }

    /// Names `preset` accepts, for error messages.
    pub const PRESET_NAMES: &'static [&'static str] =
        &["rtx3090", "rtx3080", "jetson", "nano"];

    /// Class-level invariants (profile-level ones are enforced by
    /// [`HardwareProfile::validate`] on the materialized worker profile).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "node class needs a name");
        ensure!(
            self.mem_bytes > 0.0 && !self.mem_bytes.is_nan(),
            "{}: mem_bytes must be positive, got {}",
            self.name,
            self.mem_bytes
        );
        ensure!(
            self.lan_extra_ms.is_finite() && self.lan_extra_ms >= 0.0,
            "{}: lan_extra_ms must be finite and >= 0, got {}",
            self.name,
            self.lan_extra_ms
        );
        ensure!(
            self.unit_cost.is_finite() && self.unit_cost >= 0.0,
            "{}: unit_cost must be finite and >= 0, got {}",
            self.name,
            self.unit_cost
        );
        Ok(())
    }

    /// Materialize the full duration model for one node of this class:
    /// this class's worker-side knobs over `base`'s main/shadow/LAN/model
    /// constants. The result is what [`super::Cluster`] consults for
    /// every booking on a node of this class.
    pub fn worker_profile(&self, base: &HardwareProfile) -> HardwareProfile {
        HardwareProfile {
            name: self.name,
            t_expert_gpu_ms: self.t_expert_gpu_ms,
            pcie_gbps: self.pcie_gbps,
            pcie_lat_ms: self.pcie_lat_ms,
            chunk_overhead_ms: self.chunk_overhead_ms,
            batch_marginal: self.batch_marginal,
            ..base.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_cached_decode_matches_paper_calibration() {
        // 32 layers * (t_nonexpert + 2*t_expert) + lm_head ≈ 204 ms/token
        // → ~4.9 tok/s (paper Table 2: 4.89).
        let p = HardwareProfile::rtx3090();
        let ms = 32.0 * (p.t_nonexpert_ms + 2.0 * p.t_expert_gpu_ms) + p.t_lm_head_ms;
        let tps = 1000.0 / ms;
        assert!((tps - 4.89).abs() < 0.15, "calibration drifted: {tps}");
    }

    #[test]
    fn expert_load_fits_inside_eq1_window() {
        // The paper's design point: expert load fits the Eq. (1) window of
        // 4 staggered groups — no steady-state stall, but with only
        // moderate headroom (the stalls that remain come from alignment
        // late-departures and mispredictions, not steady-state loading).
        let p = HardwareProfile::rtx3090();
        let load = p.expert_load_ms(1.0);
        let window = p.t_maxload_ms(4);
        assert!(load < window, "load {load} must fit in window {window}");
        assert!(load > 0.5 * window, "design point should be tight-ish: {load} vs {window}");
    }

    #[test]
    fn cpu_profile_matches_llamacpp_rate() {
        let p = HardwareProfile::rtx3090();
        let ms = 32.0 * (p.cpu_nonexpert_ms + 2.0 * p.cpu_expert_ms) + p.t_lm_head_ms;
        let tps = 1000.0 / ms;
        assert!((tps - 0.82).abs() < 0.08, "cpu calibration drifted: {tps}");
    }

    #[test]
    fn shadow_runs_ahead_of_pipeline() {
        let p = HardwareProfile::rtx3090();
        assert!(p.t_shadow_layer_ms < p.t_main_ms() + p.t_worker_ms());
    }

    #[test]
    fn lan_numbers() {
        let p = HardwareProfile::rtx3090();
        // 256 KB KV alignment over 1 Gbps ≈ 2.1 ms (paper §4.2).
        let t = p.lan_transfer_ms(p.kv_align_bytes);
        assert!((t - 2.097).abs() < 0.01, "{t}");
        // 16 KB embedding ≈ 0.13 ms.
        assert!((p.lan_transfer_ms(p.embed_msg_bytes) - 0.131).abs() < 0.01);
    }

    #[test]
    fn batch_beats_sequential_but_not_free() {
        let p = HardwareProfile::rtx3090();
        let t8 = p.expert_batch_ms(8);
        assert!(t8 < 8.0 * p.t_expert_gpu_ms, "batching must amortize");
        assert!(t8 > p.t_expert_gpu_ms, "but not be free");
    }

    #[test]
    fn reroute_on_paper_testbed_must_fall_back_to_degraded_mode() {
        // The design point is knife's-edge: one slot per worker just fits
        // the 4-group window, so absorbing a dead neighbour's slot cannot
        // stay stall-free — failover is possible but degraded, which is
        // exactly what the SlotMap's least-loaded fallback models.
        let p = HardwareProfile::rtx3090();
        assert!(p.reroute_feasible(1, 4, 1), "healthy load fits Eq. (1)");
        assert!(!p.reroute_feasible(2, 4, 1), "a second slot breaks the window");
        // More stagger groups widen the window enough to absorb one.
        assert!(p.reroute_feasible(2, 8, 1));
    }

    #[test]
    fn chunk_durations_sum_to_transfer_plus_overheads() {
        let p = HardwareProfile::rtx3090();
        let total = p.pcie_transfer_ms(p.expert_bytes);
        assert_eq!(p.chunk_durations(p.expert_bytes, 1), vec![total]);
        for k in [2usize, 4, 8] {
            let durs = p.chunk_durations(p.expert_bytes, k);
            assert_eq!(durs.len(), k);
            let sum: f64 = durs.iter().sum();
            let expected = total + (k as f64 - 1.0) * p.chunk_overhead_ms;
            assert!((sum - expected).abs() < 1e-9, "k={k}: {sum} vs {expected}");
            // First chunk lands ~K times earlier than the whole expert.
            assert!(durs[0] < total / (k as f64 - 0.5));
        }
    }

    #[test]
    fn effective_load_shrinks_with_chunking_but_never_below_stream_minus_ffn() {
        // The pipeline hides at most one FFN worth of transfer, so the
        // effective latency drops by ~t_expert at K = 2 and then creeps
        // back up by the per-chunk overhead — always strictly below the
        // monolithic latency, but not monotone in K.
        let p = HardwareProfile::rtx3090();
        assert_eq!(p.effective_load_ms(1), p.expert_load_ms(1.0));
        for k in [2usize, 4, 8] {
            let eff = p.effective_load_ms(k);
            assert!(
                eff < p.expert_load_ms(1.0),
                "chunking must shrink the effective latency: {eff}"
            );
            let floor = p.expert_load_ms(1.0) + (k as f64 - 1.0) * p.chunk_overhead_ms
                - p.t_expert_gpu_ms;
            assert!((eff - floor).abs() < 1e-9, "hiding is FFN-capped on this profile");
        }
        // Absurd chunk counts (overhead outweighs the hideable FFN): the
        // model falls back to the monolithic transfer rather than
        // streaming at a loss — the deadline never exceeds monolithic.
        assert_eq!(p.effective_load_ms(1000), p.expert_load_ms(1.0));
    }

    #[test]
    fn chunked_streaming_widens_the_effective_eq1_window() {
        // A profile whose monolithic load *misses* the 4-group window but
        // whose chunked stream fits: the reroute predicate must notice.
        let p = HardwareProfile { pcie_gbps: 24.0, ..HardwareProfile::rtx3090() };
        assert!(p.expert_load_ms(1.0) > p.t_maxload_ms(4), "monolithic load misses");
        assert!(!p.reroute_feasible(1, 4, 1));
        assert!(p.reroute_feasible(1, 4, 8), "first-chunk deadline fits the window");
    }

    #[test]
    fn rtx3080_is_slower_where_it_matters() {
        let a = HardwareProfile::rtx3090();
        let b = HardwareProfile::rtx3080_workers();
        assert!(b.t_expert_gpu_ms > a.t_expert_gpu_ms);
        assert!(b.pcie_gbps < a.pcie_gbps);
        assert_eq!(a.t_nonexpert_ms, b.t_nonexpert_ms, "main node unchanged");
    }

    #[test]
    fn presets_validate() {
        for p in [
            HardwareProfile::rtx3090(),
            HardwareProfile::rtx3080_workers(),
            HardwareProfile::gpu_server(),
        ] {
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", p.name));
        }
    }

    #[test]
    fn validate_rejects_each_invariant_breach() {
        let base = HardwareProfile::rtx3090;
        // Non-positive / non-finite durations and bandwidths.
        assert!(HardwareProfile { t_expert_gpu_ms: 0.0, ..base() }.validate().is_err());
        assert!(HardwareProfile { t_nonexpert_ms: -1.0, ..base() }.validate().is_err());
        assert!(HardwareProfile { pcie_gbps: 0.0, ..base() }.validate().is_err());
        assert!(HardwareProfile { pcie_gbps: f64::INFINITY, ..base() }.validate().is_err());
        assert!(HardwareProfile { lan_gbps: f64::NAN, ..base() }.validate().is_err());
        assert!(HardwareProfile { expert_bytes: 0.0, ..base() }.validate().is_err());
        // Negative latencies / overheads.
        assert!(HardwareProfile { pcie_lat_ms: -0.1, ..base() }.validate().is_err());
        assert!(HardwareProfile { chunk_overhead_ms: -0.01, ..base() }.validate().is_err());
        // Marginals outside [0, 1].
        assert!(HardwareProfile { batch_marginal: 1.5, ..base() }.validate().is_err());
        assert!(HardwareProfile { prefill_attn_marginal: -0.1, ..base() }.validate().is_err());
        // The §3.1 shadow-lead invariant that was previously only a doc
        // comment: a shadow slower than t_M + t_W cannot run ahead.
        let p = base();
        let too_slow = p.t_main_ms() + p.t_worker_ms() + 0.1;
        let err = HardwareProfile { t_shadow_layer_ms: too_slow, ..base() }
            .validate()
            .unwrap_err();
        assert!(err.to_string().contains("SEP cannot run ahead"), "{err}");
    }

    #[test]
    fn uniform_node_class_reproduces_the_base_profile_exactly() {
        let base = HardwareProfile::rtx3090();
        let c = NodeClass::of_profile(&base);
        let wp = c.worker_profile(&base);
        // Field-for-field identity on everything the cluster consults —
        // the bit-identical single-class pin rests on this.
        assert_eq!(wp.name, base.name);
        assert_eq!(wp.t_expert_gpu_ms, base.t_expert_gpu_ms);
        assert_eq!(wp.pcie_gbps, base.pcie_gbps);
        assert_eq!(wp.pcie_lat_ms, base.pcie_lat_ms);
        assert_eq!(wp.chunk_overhead_ms, base.chunk_overhead_ms);
        assert_eq!(wp.batch_marginal, base.batch_marginal);
        assert_eq!(wp.expert_bytes, base.expert_bytes);
        assert_eq!(
            wp.chunk_durations(base.expert_bytes, 4),
            base.chunk_durations(base.expert_bytes, 4)
        );
        assert_eq!(c.lan_extra_ms, 0.0);
    }

    #[test]
    fn class_presets_validate_and_are_ordered_by_capability() {
        let base = HardwareProfile::rtx3090();
        let classes = [
            NodeClass::rtx3090(),
            NodeClass::rtx3080(),
            NodeClass::jetson(),
            NodeClass::nano(),
        ];
        for c in &classes {
            c.validate().unwrap_or_else(|e| panic!("{}: {e}", c.name));
            c.worker_profile(&base)
                .validate()
                .unwrap_or_else(|e| panic!("{} profile: {e}", c.name));
            assert_eq!(NodeClass::preset(c.name).as_ref(), Some(c), "{} round-trips", c.name);
        }
        assert!(NodeClass::preset("gtx1080").is_none());
        // Monotone down the tier list: slower FFN, thinner link, less
        // memory, cheaper.
        for w in classes.windows(2) {
            assert!(w[1].t_expert_gpu_ms >= w[0].t_expert_gpu_ms);
            assert!(w[1].pcie_gbps <= w[0].pcie_gbps);
            assert!(w[1].mem_bytes <= w[0].mem_bytes);
            assert!(w[1].unit_cost <= w[0].unit_cost);
        }
    }

    #[test]
    fn jetson_needs_precision_or_chunking_to_hold_the_window() {
        // The planner's whole reason to exist: the embedded class misses
        // the Eq. (1) window at full transfer precision but fits once the
        // transfer shrinks (HOBBIT's precision knob) — so deployability
        // is a *configuration* question, not a hardware constant.
        let base = HardwareProfile::rtx3090();
        let jetson = NodeClass::jetson().worker_profile(&base);
        assert!(!jetson.reroute_feasible(1, 5, 1), "full-precision jetson misses");
        assert!(!jetson.reroute_feasible(1, 5, 8), "chunking alone is not enough");
        let nf4 = HardwareProfile { expert_bytes: base.expert_bytes * 0.28, ..jetson };
        assert!(nf4.reroute_feasible(1, 5, 1), "nf4-sized transfers fit the window");
    }
}
