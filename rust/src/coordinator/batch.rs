//! Multi-session batched decode: the engine-side contract and the
//! route-merge / load-dedup helpers.
//!
//! The paper decodes one sequence at a time, but its cacheless design
//! amortizes naturally: when several concurrent sessions route to the
//! same expert in the same layer, one on-demand load serves all of them.
//! A [`BatchEngine`] steps N sessions through each decode iteration
//! together — numerics stay per-session exact (see
//! [`crate::engine::batch::BatchState`]) while virtual time books a
//! single expert load per **distinct** expert per layer per iteration,
//! split across the layer's group workers as in sequential decode.
//!
//! The core invariant (asserted by [`merge_distinct`]'s unit tests and
//! the `batch_props` integration tests): per layer per iteration,
//!
//! ```text
//! distinct-expert loads  <=  sum over sessions of top_k loads
//! ```
//!
//! with equality exactly when no two sessions share an expert. A batch of
//! one merges to the session's own route, so `run_batch` over a single
//! session reproduces sequential `run_prompt` token streams *and*
//! timings exactly — the property the serving layer's `--max-batch 1`
//! baseline rests on (see DESIGN.md §7).

use anyhow::Result;

use super::{Engine, PromptResult};
use crate::cluster::Ms;

/// Everything one co-scheduled batch run produced.
#[derive(Debug, Clone, Default)]
pub struct BatchRunResult {
    /// Per-session results, in input order. `ttft_ms`/`decode_ms` are
    /// measured from the batch's start on the engine's virtual clock
    /// (prefills serialize on the main node, so later sessions' TTFTs
    /// include their wait; a session's `decode_ms` spans from its first
    /// token to its last).
    pub sessions: Vec<PromptResult>,
    /// Expert loads that completed and fed an expert compute (one per
    /// distinct expert per layer per iteration, plus mispredict reloads).
    pub expert_loads: u64,
    /// Prediction-driven loads aborted at the gate result (mispredicts).
    pub aborted_loads: u64,
    /// Loads/computes re-booked on a replacement worker after a node
    /// died mid-flight (fault injection; see DESIGN.md §8).
    pub failovers: u64,
    /// Decode tokens produced across all sessions (prefill excluded).
    pub decode_tokens: u64,
    /// Decode iterations executed (the batch shrinks at token boundaries
    /// as sessions complete, so this is less than `decode_tokens` whenever
    /// any iteration ran more than one session).
    pub decode_iterations: u64,
    /// Virtual span of the decode phase (last token time minus the batch
    /// decode start).
    pub decode_span_ms: Ms,
    /// Per-expert demand over the run: how many session-route hits each
    /// expert took across layers and iterations — the sum of
    /// [`merge_distinct`]'s per-expert counts. Indexed by expert id;
    /// empty for engines that do not track it (baselines). This is the
    /// popularity signal the SLO control loop's expert replication
    /// consumes (DESIGN.md §15).
    pub expert_demand: Vec<u64>,
}

impl BatchRunResult {
    /// Mean completed expert loads per decode token — the quantity
    /// batching amortizes (equals `top_k * n_layers` at batch 1 with
    /// perfect prediction and no reloads).
    pub fn loads_per_token(&self) -> f64 {
        if self.decode_tokens == 0 {
            0.0
        } else {
            self.expert_loads as f64 / self.decode_tokens as f64
        }
    }
}

/// An engine that can co-schedule several sessions through one decode
/// loop, amortizing per-expert I/O across the batch.
///
/// Contract mirroring [`Engine::run_prompt`]: the caller `reset`s the
/// engine first; `run_batch` prefills every session, then decodes all of
/// them together, dropping each session from the batch at the token
/// boundary where it reaches its target (the batch *shrinks*; it never
/// admits new members mid-run — re-forming across dispatches is the
/// scheduler's job, see [`crate::serve::scheduler`]).
pub trait BatchEngine: Engine {
    /// Serve `sessions` (prompt, total output tokens) as one batch.
    fn run_batch(&mut self, sessions: &[(&[u32], usize)]) -> Result<BatchRunResult>;
}

/// Merge per-session expert selections for one layer into the distinct
/// expert list, first-appearance order, with per-expert token counts
/// (how many sessions routed to it — each session selects an expert at
/// most once, so the count is also the expert's batch-FFN row count).
///
/// This is the load-dedup kernel: `result.len()` loads replace
/// `sets.map(len).sum()` loads.
pub fn merge_distinct<'a, I>(sets: I) -> Vec<(usize, usize)>
where
    I: IntoIterator<Item = &'a [usize]>,
{
    let mut out: Vec<(usize, usize)> = Vec::new();
    for set in sets {
        for &e in set {
            match out.iter_mut().find(|(x, _)| *x == e) {
                Some((_, n)) => *n += 1,
                None => out.push((e, 1)),
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_of_one_session_is_identity() {
        let a = [3usize, 5];
        let m = merge_distinct([a.as_slice()]);
        assert_eq!(m, vec![(3, 1), (5, 1)]);
    }

    #[test]
    fn merge_dedups_shared_experts() {
        let a = [3usize, 5];
        let b = [5usize, 1];
        let c = [3usize, 5];
        let m = merge_distinct([a.as_slice(), b.as_slice(), c.as_slice()]);
        // First-appearance order, counts = sessions per expert.
        assert_eq!(m, vec![(3, 2), (5, 3), (1, 1)]);
    }

    #[test]
    fn distinct_loads_never_exceed_per_session_sum() {
        // The §7 invariant over a few synthetic batches.
        let batches: Vec<Vec<Vec<usize>>> = vec![
            vec![vec![0, 1], vec![0, 1], vec![0, 1]],
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            vec![vec![1, 2], vec![2, 3], vec![3, 1]],
            vec![vec![7, 0]],
        ];
        for sessions in &batches {
            let total: usize = sessions.iter().map(|s| s.len()).sum();
            let merged = merge_distinct(sessions.iter().map(|s| s.as_slice()));
            assert!(merged.len() <= total, "{merged:?} vs {total}");
            let count_sum: usize = merged.iter().map(|&(_, n)| n).sum();
            assert_eq!(count_sum, total, "counts must conserve selections");
        }
    }

    #[test]
    fn shared_routing_amortizes_perfectly() {
        // All sessions on the same route: distinct count stays top_k, so
        // loads per token = top_k / b strictly decreases with batch size.
        let route = [2usize, 6];
        let mut prev = f64::INFINITY;
        for b in 1..=8 {
            let sessions: Vec<&[usize]> = (0..b).map(|_| route.as_slice()).collect();
            let merged = merge_distinct(sessions);
            assert_eq!(merged.len(), 2);
            let loads_per_token = merged.len() as f64 / b as f64;
            assert!(loads_per_token < prev, "batch {b}: {loads_per_token} !< {prev}");
            prev = loads_per_token;
        }
    }
}
