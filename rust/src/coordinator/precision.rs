//! Runtime mixed-precision expert loading (DESIGN.md §14).
//!
//! HOBBIT (arXiv 2411.01433) observes that the expert-transfer precision
//! does not have to be a deployment constant: at the moment a load is
//! issued the coordinator knows how much of the Eq. (1) no-stall window
//! is left (slack) and how much the expert matters to the token
//! (importance — its router gate weight, or its SEP rank for a
//! prefetch), so it can stream each expert at the cheapest precision
//! that still lands in time. [`PrecisionController`] is that decision,
//! precomputed per worker class; [`PrecisionPolicy`] is the engine knob
//! that enables it.
//!
//! Numerics in this repo stay FP32 and in-flight precision is a
//! bandwidth property ([`Precision::transfer_factor`]): a transfer
//! downgrade changes ONLY virtual-time bookings, never tokens. The two
//! honest quality costs are tracked separately — every downgraded load
//! accrues `gate_weight × rel_error(tier)` of quality debt
//! ([`Precision::rel_error`]), and the optional *skip* of the weakest
//! routed expert under a hard deadline (SlimCaching's importance
//! argument, arXiv 2507.06567) really drops the expert's contribution
//! from the residual stream, which `workload::fidelity` then measures
//! as token drift.

use anyhow::{bail, Result};

use crate::cluster::{Cluster, HardwareProfile, Ms};
use crate::engine::Route;
use crate::quant::Precision;

/// How the engine picks each expert load's transfer precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrecisionPolicy {
    /// The deployed profile's precision for every load — the seed
    /// behavior, bit-identical in tokens AND timings (the engine builds
    /// no controller at all under this policy).
    Static,
    /// Cheapest tier of [`TRANSFER_TIERS`] whose remaining chunk train
    /// still lands inside the worker's Eq. (1) window.
    Slack,
    /// [`PrecisionPolicy::Slack`], plus the importance signal: experts
    /// with gate weight ≥ [`IMPORTANCE_FLOOR`] refuse the NF4 tier, and
    /// (only with the explicit skip knob) the weakest routed expert may
    /// be dropped outright on a worker whose window is hopeless.
    SlackImportance,
}

impl PrecisionPolicy {
    pub const ALL: [PrecisionPolicy; 3] =
        [PrecisionPolicy::Static, PrecisionPolicy::Slack, PrecisionPolicy::SlackImportance];

    pub fn label(self) -> &'static str {
        match self {
            PrecisionPolicy::Static => "static",
            PrecisionPolicy::Slack => "slack",
            PrecisionPolicy::SlackImportance => "slack-importance",
        }
    }

    /// Parse a `static|slack|slack-importance` CLI token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "static" => PrecisionPolicy::Static,
            "slack" => PrecisionPolicy::Slack,
            "slack-importance" => PrecisionPolicy::SlackImportance,
            other => bail!("unknown precision policy {other:?} (static|slack|slack-importance)"),
        })
    }
}

/// Transfer tiers the runtime controller may choose from, fastest wire
/// first in *precision* order: index 0 is the deployed full-fidelity
/// stream (fp16's transfer factor is exactly 1.0, so tier 0's chunk
/// train is bit-identical to the engine's static train), higher indices
/// shrink the stream at growing [`Precision::rel_error`].
pub const TRANSFER_TIERS: [Precision; 3] = [Precision::Fp16, Precision::Int8, Precision::Nf4];

/// Gate weight at or above which `SlackImportance` refuses the NF4
/// tier: the top expert of a top-2 softmax always clears this, so the
/// dominant contribution never takes the worst quantization.
pub const IMPORTANCE_FLOOR: f64 = 0.5;

/// Gate weight at or below which the skip rule may drop an expert (only
/// on hopeless workers, only with the skip knob on). Softmax weights
/// over a top-k ≥ 2 selection give the weakest expert ≤ 0.5, so this
/// bounds skipping to "never the dominant expert".
pub const SKIP_MAX_WEIGHT: f64 = 0.5;

/// Per-worker precomputed state behind runtime precision selection.
///
/// Built once per engine from each worker's *class* profile: the chunk
/// train of one expert at every tier, the worker's Eq. (1) window, and
/// two static verdicts — whether the full fp16 train fits the window
/// from a standing start (`fp16_fits`, the upgrade-reload condition)
/// and whether even the NF4 train cannot (`hopeless`, the skip
/// condition). Selection itself is pure arithmetic over these tables,
/// so it is deterministic and costs no allocation on the load path.
#[derive(Debug, Clone)]
pub struct PrecisionController {
    policy: PrecisionPolicy,
    skip: bool,
    /// `durs[w][tier]` = per-chunk durations of one expert transfer on
    /// worker `w` at [`TRANSFER_TIERS`]`[tier]`.
    durs: Vec<[Vec<Ms>; 3]>,
    /// Eq. (1) no-stall window of worker `w`'s class.
    window: Vec<Ms>,
    hopeless: Vec<bool>,
    fp16_fits: Vec<bool>,
}

impl PrecisionController {
    pub fn new(
        cluster: &Cluster,
        n_workers: usize,
        expert_bytes: f64,
        chunks: usize,
        n_groups: usize,
        policy: PrecisionPolicy,
        skip: bool,
    ) -> Self {
        let profiles: Vec<&HardwareProfile> =
            (0..n_workers).map(|w| cluster.worker_profile(w)).collect();
        Self::from_profiles(&profiles, expert_bytes, chunks, n_groups, policy, skip)
    }

    /// Profile-level constructor (what the runtime-free `bench` section
    /// and the unit tests drive directly).
    pub fn from_profiles(
        profiles: &[&HardwareProfile],
        expert_bytes: f64,
        chunks: usize,
        n_groups: usize,
        policy: PrecisionPolicy,
        skip: bool,
    ) -> Self {
        let mut durs = Vec::with_capacity(profiles.len());
        let mut window = Vec::with_capacity(profiles.len());
        let mut hopeless = Vec::with_capacity(profiles.len());
        let mut fp16_fits = Vec::with_capacity(profiles.len());
        for p in profiles {
            let tiers: [Vec<Ms>; 3] = TRANSFER_TIERS
                .map(|t| p.chunk_durations(expert_bytes * t.transfer_factor(), chunks));
            let win = p.t_maxload_ms(n_groups);
            let full = |ds: &[Ms]| p.pcie_lat_ms + ds.iter().sum::<f64>();
            hopeless.push(full(&tiers[2]) > win);
            fp16_fits.push(full(&tiers[0]) <= win);
            window.push(win);
            durs.push(tiers);
        }
        Self { policy, skip, durs, window, hopeless, fp16_fits }
    }

    /// Pick the transfer tier (index into [`TRANSFER_TIERS`]) for a load
    /// on worker `w` that would start streaming at `start` and must land
    /// by `deadline`, with `done_chunks` chunks already delivered (a
    /// failover re-books only the suffix). The estimate charges the
    /// remaining train back to back from `start` — link queueing is
    /// ignored, keeping selection a pure function of the schedule.
    /// `min_tier` forces at least that much downgrade (a mid-stream
    /// failover re-books the undelivered suffix one tier lower); it
    /// overrides the importance floor — a forced downgrade is a deadline
    /// recovery, not a fidelity preference.
    pub fn select(
        &self,
        w: usize,
        start: Ms,
        deadline: Ms,
        importance: f64,
        done_chunks: usize,
        min_tier: usize,
    ) -> usize {
        let mut idx = TRANSFER_TIERS.len() - 1; // nothing fits: cheapest wire
        for i in 0..TRANSFER_TIERS.len() {
            if start + self.remaining_ms(w, i, done_chunks) <= deadline {
                idx = i;
                break;
            }
        }
        if self.policy == PrecisionPolicy::SlackImportance && importance >= IMPORTANCE_FLOOR {
            idx = idx.min(1); // important experts refuse the NF4 tier
        }
        idx.max(min_tier).min(TRANSFER_TIERS.len() - 1)
    }

    /// Remaining stream time of the undelivered suffix at a tier.
    pub fn remaining_ms(&self, w: usize, tier: usize, done_chunks: usize) -> Ms {
        let ds = &self.durs[w][tier];
        ds[done_chunks.min(ds.len())..].iter().sum()
    }

    /// The per-chunk train of worker `w` at a tier (same length as the
    /// engine's static train; tier 0 is bit-identical to it).
    pub fn durs(&self, w: usize, tier: usize) -> &[Ms] {
        &self.durs[w][tier]
    }

    /// Worker `w`'s Eq. (1) deadline window (its class's `t_maxload`).
    pub fn window_ms(&self, w: usize) -> Ms {
        self.window[w]
    }

    /// Can worker `w` land a full fp16 train inside its window from a
    /// standing start? The upgrade-reload condition: a hot-tier resident
    /// installed from a downgraded stream is only worth re-streaming at
    /// full precision where this holds.
    pub fn fp16_fits(&self, w: usize) -> bool {
        self.fp16_fits[w]
    }

    /// Worker `w` cannot land even the NF4 train in-window: the hard
    /// deadline under which the skip rule is allowed to act.
    pub fn hopeless(&self, w: usize) -> bool {
        self.hopeless[w]
    }

    /// Is expert skipping in effect? Requires both the explicit knob and
    /// the `SlackImportance` policy — under `Slack` the importance
    /// signal (and with it the skip rule) does not exist.
    pub fn skip_active(&self) -> bool {
        self.skip && self.policy == PrecisionPolicy::SlackImportance
    }

    /// Skip rule: drop an expert of gate weight `weight` routed to
    /// worker `w`? Only under an active skip knob, only on a hopeless
    /// worker, and never the dominant expert (see [`SKIP_MAX_WEIGHT`]).
    pub fn should_skip(&self, w: usize, weight: f64) -> bool {
        self.skip_active() && self.hopeless[w] && weight <= SKIP_MAX_WEIGHT
    }

    /// Registry counter name for loads issued at a tier.
    pub fn tier_counter(tier: usize) -> &'static str {
        match TRANSFER_TIERS[tier] {
            Precision::Fp16 => "engine.loads_fp16",
            Precision::Int8 => "engine.loads_int8",
            _ => "engine.loads_nf4",
        }
    }
}

/// Routing importance of `expert` within `route`: its softmax gate
/// weight, 0.0 when not routed — the reactive-load importance signal.
pub fn gate_weight(route: &Route, expert: usize) -> f64 {
    route
        .experts
        .iter()
        .position(|&e| e == expert)
        .map_or(0.0, |i| route.weights[i] as f64)
}

/// Importance of a SEP prefetch candidate by shadow-route rank:
/// `1/(1+rank)` — the shadow's top pick counts like a certain route,
/// deeper speculative candidates matter geometrically less.
pub fn prefetch_importance(rank: usize) -> f64 {
    1.0 / (1.0 + rank as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeClass;

    fn ctl_for(
        class: NodeClass,
        chunks: usize,
        n_groups: usize,
        policy: PrecisionPolicy,
        skip: bool,
    ) -> (PrecisionController, HardwareProfile) {
        let base = HardwareProfile::rtx3090();
        let p = class.worker_profile(&base);
        let bytes = base.expert_bytes;
        let ctl = PrecisionController::from_profiles(&[&p], bytes, chunks, n_groups, policy, skip);
        (ctl, p)
    }

    #[test]
    fn policy_parse_round_trips_and_lists_names_on_error() {
        for p in PrecisionPolicy::ALL {
            assert_eq!(PrecisionPolicy::parse(p.label()).unwrap(), p);
        }
        let err = PrecisionPolicy::parse("adaptive").unwrap_err().to_string();
        for name in ["static", "slack", "slack-importance"] {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
    }

    #[test]
    fn tier_zero_train_is_bitwise_the_static_train() {
        // fp16's transfer factor is exactly 1.0, so tier 0 reproduces
        // the engine's precomputed chunk durations bit for bit — the
        // structural half of the Static-pinning argument.
        for chunks in [1usize, 4, 8] {
            let (ctl, p) = ctl_for(NodeClass::jetson(), chunks, 3, PrecisionPolicy::Slack, false);
            let seed = p.chunk_durations(HardwareProfile::rtx3090().expert_bytes, chunks);
            assert_eq!(ctl.durs(0, 0), seed.as_slice());
        }
    }

    #[test]
    fn ample_slack_selects_fp16_and_pressure_downgrades() {
        let (ctl, _) = ctl_for(NodeClass::jetson(), 4, 3, PrecisionPolicy::Slack, false);
        let win = ctl.window_ms(0);
        // Jetson misses its window at fp16 but holds it at nf4 (the
        // pinned `jetson_needs_precision_or_chunking_to_hold_the_window`
        // fact), so a standing start picks a downgraded tier...
        let tight = ctl.select(0, 0.0, win, 0.1, 0, 0);
        assert!(tight > 0, "jetson under pressure must downgrade, got tier {tight}");
        assert!(0.0 + ctl.remaining_ms(0, tight, 0) <= win, "the chosen tier lands in time");
        // ...while a huge deadline always affords fp16.
        assert_eq!(ctl.select(0, 0.0, 1e9, 0.1, 0, 0), 0);
        // More slack never lowers precision (tier index monotone).
        let mut last = usize::MAX;
        for deadline in [5.0, 10.0, 20.0, 40.0, 80.0, 1e9] {
            let t = ctl.select(0, 0.0, deadline, 0.1, 0, 0);
            assert!(t <= last, "slack {deadline}: tier went {last} -> {t}");
            last = t;
        }
    }

    #[test]
    fn importance_floor_refuses_nf4_under_slack_importance_only() {
        let (slack, _) = ctl_for(NodeClass::nano(), 1, 3, PrecisionPolicy::Slack, false);
        let (imp, _) = ctl_for(NodeClass::nano(), 1, 3, PrecisionPolicy::SlackImportance, false);
        // Impossible deadline: pure slack falls to nf4; an important
        // expert under SlackImportance stops at int8.
        assert_eq!(slack.select(0, 0.0, 1.0, 0.9, 0, 0), 2);
        assert_eq!(imp.select(0, 0.0, 1.0, 0.9, 0, 0), 1);
        // Unimportant experts take the full downgrade either way.
        assert_eq!(imp.select(0, 0.0, 1.0, 0.2, 0, 0), 2);
    }

    #[test]
    fn forced_min_tier_overrides_both_slack_and_importance() {
        let (ctl, _) = ctl_for(NodeClass::rtx3080(), 4, 3, PrecisionPolicy::SlackImportance, false);
        // Ample slack would pick fp16; a failover-forced floor wins.
        assert_eq!(ctl.select(0, 0.0, 1e9, 0.9, 0, 1), 1);
        // And the floor clamps to the last tier even past it.
        assert_eq!(ctl.select(0, 0.0, 1e9, 0.9, 0, 7), 2);
    }

    #[test]
    fn suffix_rebooking_at_lower_tiers_never_exceeds_monolithic_fp16() {
        // Satellite invariant for mid-stream failover downgrades: for
        // every class, chunk count, downgraded tier and progress point,
        // the undelivered suffix at the lower precision re-streams in no
        // more than one whole monolithic fp16 load — the recovery can
        // only be cheaper than starting the original transfer over.
        let base = HardwareProfile::rtx3090();
        for class in [NodeClass::rtx3090(), NodeClass::rtx3080(), NodeClass::jetson(), NodeClass::nano()]
        {
            let p = class.worker_profile(&base);
            let mono_fp16 = p.expert_load_ms(Precision::Fp16.transfer_factor());
            for chunks in [1usize, 2, 4, 8] {
                let ctl = PrecisionController::from_profiles(
                    &[&p],
                    base.expert_bytes,
                    chunks,
                    3,
                    PrecisionPolicy::Slack,
                    false,
                );
                for tier in 1..TRANSFER_TIERS.len() {
                    for done in 0..chunks {
                        let suffix = p.pcie_lat_ms + ctl.remaining_ms(0, tier, done);
                        assert!(
                            suffix <= mono_fp16,
                            "{} c{chunks} tier{tier} done{done}: {suffix} > {mono_fp16}",
                            class.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn skip_rule_needs_knob_policy_hopeless_worker_and_a_weak_expert() {
        // nano at one chunk cannot land even nf4 inside a 3-group window.
        let (ctl, _) = ctl_for(NodeClass::nano(), 1, 3, PrecisionPolicy::SlackImportance, true);
        assert!(ctl.hopeless(0));
        assert!(ctl.should_skip(0, 0.3));
        assert!(!ctl.should_skip(0, 0.7), "dominant experts are never skipped");
        // Same class, skip knob off.
        let (off, _) = ctl_for(NodeClass::nano(), 1, 3, PrecisionPolicy::SlackImportance, false);
        assert!(!off.should_skip(0, 0.3));
        // Slack policy has no importance signal, so no skip either.
        let (slack, _) = ctl_for(NodeClass::nano(), 1, 3, PrecisionPolicy::Slack, true);
        assert!(!slack.skip_active());
        // A class that holds its window is never hopeless.
        let (fast, _) = ctl_for(NodeClass::rtx3090(), 1, 3, PrecisionPolicy::SlackImportance, true);
        assert!(!fast.hopeless(0) && fast.fp16_fits(0));
        assert!(!fast.should_skip(0, 0.3));
    }

    #[test]
    fn importance_signals_are_ordered_and_bounded() {
        let route = Route { experts: vec![5, 2], weights: vec![0.7, 0.3] };
        assert_eq!(gate_weight(&route, 5), 0.7f32 as f64);
        assert_eq!(gate_weight(&route, 2), 0.3f32 as f64);
        assert_eq!(gate_weight(&route, 9), 0.0);
        assert_eq!(prefetch_importance(0), 1.0);
        assert!(prefetch_importance(1) < prefetch_importance(0));
        assert!(prefetch_importance(1) >= IMPORTANCE_FLOOR);
    }
}
