//! Serving front-end: a request queue + FCFS scheduler over any
//! [`Engine`] (the piece a deployment actually talks to; cf. the vLLM
//! router split of API front-end vs model engine).
//!
//! Requests carry a prompt, a token budget and an arrival time (virtual
//! ms). The server admits them FCFS — the paper's engines decode one
//! sequence at a time (no batched decoding, matching §4.4's comparison
//! setup) — and reports per-request queueing/service latency plus
//! aggregate throughput. Time composes with the engines' virtual clocks:
//! a request's service occupies the engine for its measured virtual
//! duration.

use anyhow::Result;

use super::{Engine, PromptResult};
use crate::cluster::Ms;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub out_tokens: usize,
    /// Arrival time in virtual ms (relative to server start).
    pub arrival_ms: Ms,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub queued_ms: Ms,
    pub ttft_ms: Ms,
    pub total_ms: Ms,
    pub tokens: Vec<u32>,
    pub stall_ms: Ms,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: usize,
    pub total_tokens: usize,
    pub makespan_ms: Ms,
    pub mean_queue_ms: Ms,
    pub mean_ttft_ms: Ms,
    pub p95_total_ms: Ms,
}

impl ServerStats {
    /// End-to-end serving throughput (tokens per virtual second).
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / (self.makespan_ms / 1000.0)
    }
}

/// FCFS server over one engine.
pub struct Server<'e> {
    engine: &'e mut dyn Engine,
    queue: Vec<Request>,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e mut dyn Engine) -> Self {
        Self { engine, queue: Vec::new() }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue FCFS (by arrival time, ties by id). Returns the
    /// per-request completions and aggregate stats.
    pub fn run(&mut self) -> Result<(Vec<Completion>, ServerStats)> {
        self.queue.sort_by(|a, b| {
            a.arrival_ms
                .partial_cmp(&b.arrival_ms)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        let mut completions = Vec::with_capacity(self.queue.len());
        let mut clock: Ms = 0.0;
        let mut total_tokens = 0usize;
        for req in self.queue.drain(..) {
            let start = clock.max(req.arrival_ms);
            self.engine.reset()?;
            let res: PromptResult = self.engine.run_prompt(&req.prompt, req.out_tokens, false)?;
            let service = res.ttft_ms + res.decode_ms;
            total_tokens += res.tokens.len();
            completions.push(Completion {
                id: req.id,
                queued_ms: start - req.arrival_ms,
                ttft_ms: start - req.arrival_ms + res.ttft_ms,
                total_ms: start - req.arrival_ms + service,
                tokens: res.tokens,
                stall_ms: res.stall_ms,
            });
            clock = start + service;
        }
        let stats = summarize(&completions, clock, total_tokens);
        Ok((completions, stats))
    }
}

fn summarize(completions: &[Completion], makespan: Ms, total_tokens: usize) -> ServerStats {
    if completions.is_empty() {
        return ServerStats::default();
    }
    let n = completions.len() as f64;
    let mut totals: Vec<Ms> = completions.iter().map(|c| c.total_ms).collect();
    totals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ServerStats {
        served: completions.len(),
        total_tokens,
        makespan_ms: makespan,
        mean_queue_ms: completions.iter().map(|c| c.queued_ms).sum::<Ms>() / n,
        mean_ttft_ms: completions.iter().map(|c| c.ttft_ms).sum::<Ms>() / n,
        p95_total_ms: totals[((totals.len() - 1) as f64 * 0.95) as usize],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine stub with fixed service times (server logic is engine-agnostic).
    struct StubEngine {
        ttft: Ms,
        decode: Ms,
    }

    impl Engine for StubEngine {
        fn name(&self) -> String {
            "stub".into()
        }
        fn reset(&mut self) -> Result<()> {
            Ok(())
        }
        fn run_prompt(&mut self, prompt: &[u32], out: usize, _: bool) -> Result<PromptResult> {
            Ok(PromptResult {
                ttft_ms: self.ttft,
                decode_ms: self.decode,
                tokens: vec![prompt[0]; out],
                ..Default::default()
            })
        }
    }

    fn req(id: u64, arrival: Ms) -> Request {
        Request { id, prompt: vec![1, 2, 3], out_tokens: 4, arrival_ms: arrival }
    }

    #[test]
    fn fcfs_order_and_queueing() {
        let mut e = StubEngine { ttft: 10.0, decode: 90.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 0.0));
        s.submit(req(2, 0.0));
        s.submit(req(3, 500.0)); // arrives after the first two finish
        let (done, stats) = s.run().unwrap();
        assert_eq!(done[0].queued_ms, 0.0);
        assert_eq!(done[1].queued_ms, 100.0, "second waits for the first");
        assert_eq!(done[2].queued_ms, 0.0, "late arrival finds an idle engine");
        assert_eq!(stats.served, 3);
        assert_eq!(stats.total_tokens, 12);
        assert_eq!(stats.makespan_ms, 600.0);
    }

    #[test]
    fn sorts_by_arrival_not_submission() {
        let mut e = StubEngine { ttft: 1.0, decode: 1.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 100.0));
        s.submit(req(2, 0.0));
        let (done, _) = s.run().unwrap();
        assert_eq!(done[0].id, 2);
        assert_eq!(done[1].id, 1);
    }

    #[test]
    fn throughput_accounts_makespan() {
        let mut e = StubEngine { ttft: 0.0, decode: 1000.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 0.0));
        s.submit(req(2, 0.0));
        let (_, stats) = s.run().unwrap();
        // 8 tokens over 2 virtual seconds.
        assert!((stats.tokens_per_s() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_queue_is_fine() {
        let mut e = StubEngine { ttft: 1.0, decode: 1.0 };
        let mut s = Server::new(&mut e);
        let (done, stats) = s.run().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.served, 0);
        assert_eq!(stats.tokens_per_s(), 0.0);
    }
}
