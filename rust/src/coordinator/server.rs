//! Serving front-end compatibility shim.
//!
//! The original single-engine FCFS drain now lives in [`crate::serve`] as
//! a special case of the continuous scheduler (FCFS policy, one replica,
//! no admission limits). This module keeps the seed API — [`Request`],
//! [`Server`], [`ServerStats`] — for existing callers and benches; new
//! code should use [`crate::serve`] directly for multi-replica pools,
//! SJF/EDF policies, admission control, SLOs and rate sweeps.

use anyhow::Result;

use super::Engine;
use crate::cluster::Ms;
use crate::serve::{self, EngineService, Scheduler, SchedulerConfig};

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub out_tokens: usize,
    /// Arrival time in virtual ms (relative to server start).
    pub arrival_ms: Ms,
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub queued_ms: Ms,
    pub ttft_ms: Ms,
    pub total_ms: Ms,
    pub tokens: Vec<u32>,
    pub stall_ms: Ms,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: usize,
    pub total_tokens: usize,
    pub makespan_ms: Ms,
    pub mean_queue_ms: Ms,
    pub mean_ttft_ms: Ms,
    pub p95_total_ms: Ms,
}

impl ServerStats {
    /// End-to-end serving throughput (tokens per virtual second).
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.total_tokens as f64 / (self.makespan_ms / 1000.0)
    }
}

/// FCFS server over one engine (shim over [`crate::serve::Scheduler`]).
pub struct Server<'e> {
    engine: &'e mut dyn Engine,
    queue: Vec<Request>,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e mut dyn Engine) -> Self {
        Self { engine, queue: Vec::new() }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue FCFS (by arrival time, ties by id). Returns the
    /// per-request completions (in completion order) and aggregate stats.
    pub fn run(&mut self) -> Result<(Vec<Completion>, ServerStats)> {
        let reqs: Vec<serve::Request> = self
            .queue
            .drain(..)
            .map(|r| serve::Request::open_loop(r.id, r.prompt, r.out_tokens, r.arrival_ms))
            .collect();
        // FCFS, one replica, no limits; the default core is the event
        // executor (DESIGN.md §13), pinned bit-identical to the round
        // loop by the equivalence properties, so nothing here changes.
        let cfg = SchedulerConfig::default();
        let mut service = EngineService::new(&mut *self.engine);
        let outcome = Scheduler::run(&cfg, &mut service, &reqs)?;

        let mut total_tokens = 0usize;
        let completions: Vec<Completion> = outcome
            .records
            .iter()
            .map(|rec| {
                total_tokens += rec.tokens.len();
                Completion {
                    id: rec.id,
                    queued_ms: rec.queued_ms(),
                    ttft_ms: rec.ttft_ms().unwrap_or_else(|| rec.e2e_ms()),
                    total_ms: rec.e2e_ms(),
                    tokens: rec.tokens.clone(),
                    stall_ms: rec.stall_ms,
                }
            })
            .collect();
        let stats = summarize(&completions, outcome.makespan_ms, total_tokens);
        Ok((completions, stats))
    }
}

fn summarize(completions: &[Completion], makespan: Ms, total_tokens: usize) -> ServerStats {
    if completions.is_empty() {
        return ServerStats::default();
    }
    let n = completions.len() as f64;
    let totals: Vec<Ms> = completions.iter().map(|c| c.total_ms).collect();
    ServerStats {
        served: completions.len(),
        total_tokens,
        makespan_ms: makespan,
        mean_queue_ms: completions.iter().map(|c| c.queued_ms).sum::<Ms>() / n,
        mean_ttft_ms: completions.iter().map(|c| c.ttft_ms).sum::<Ms>() / n,
        p95_total_ms: crate::metrics::percentile(&totals, 0.95),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PromptResult;

    /// Engine stub with fixed service times (server logic is engine-agnostic).
    struct StubEngine {
        ttft: Ms,
        decode: Ms,
    }

    impl Engine for StubEngine {
        fn name(&self) -> String {
            "stub".into()
        }
        fn reset(&mut self) -> Result<()> {
            Ok(())
        }
        fn run_prompt(&mut self, prompt: &[u32], out: usize, _: bool) -> Result<PromptResult> {
            Ok(PromptResult {
                ttft_ms: self.ttft,
                decode_ms: self.decode,
                tokens: vec![prompt[0]; out],
                ..Default::default()
            })
        }
    }

    fn req(id: u64, arrival: Ms) -> Request {
        Request { id, prompt: vec![1, 2, 3], out_tokens: 4, arrival_ms: arrival }
    }

    #[test]
    fn fcfs_order_and_queueing() {
        let mut e = StubEngine { ttft: 10.0, decode: 90.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 0.0));
        s.submit(req(2, 0.0));
        s.submit(req(3, 500.0)); // arrives after the first two finish
        let (done, stats) = s.run().unwrap();
        assert_eq!(done[0].queued_ms, 0.0);
        assert_eq!(done[1].queued_ms, 100.0, "second waits for the first");
        assert_eq!(done[2].queued_ms, 0.0, "late arrival finds an idle engine");
        assert_eq!(stats.served, 3);
        assert_eq!(stats.total_tokens, 12);
        assert_eq!(stats.makespan_ms, 600.0);
    }

    #[test]
    fn sorts_by_arrival_not_submission() {
        let mut e = StubEngine { ttft: 1.0, decode: 1.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 100.0));
        s.submit(req(2, 0.0));
        let (done, _) = s.run().unwrap();
        assert_eq!(done[0].id, 2);
        assert_eq!(done[1].id, 1);
    }

    #[test]
    fn throughput_accounts_makespan() {
        let mut e = StubEngine { ttft: 0.0, decode: 1000.0 };
        let mut s = Server::new(&mut e);
        s.submit(req(1, 0.0));
        s.submit(req(2, 0.0));
        let (_, stats) = s.run().unwrap();
        // 8 tokens over 2 virtual seconds.
        assert!((stats.tokens_per_s() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_queue_is_fine() {
        let mut e = StubEngine { ttft: 1.0, decode: 1.0 };
        let mut s = Server::new(&mut e);
        let (done, stats) = s.run().unwrap();
        assert!(done.is_empty());
        assert_eq!(stats.served, 0);
        assert_eq!(stats.tokens_per_s(), 0.0);
    }

    #[test]
    fn p95_uses_nearest_rank() {
        // 10 identical-service requests arriving back to back: totals are
        // 100, 200, ..., 1000; nearest-rank p95 is the 10th (1000), not
        // the truncated 9th.
        let mut e = StubEngine { ttft: 10.0, decode: 90.0 };
        let mut s = Server::new(&mut e);
        for i in 0..10 {
            s.submit(req(i, 0.0));
        }
        let (_, stats) = s.run().unwrap();
        assert_eq!(stats.p95_total_ms, 1000.0);
    }
}
