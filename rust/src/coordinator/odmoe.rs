//! The OD-MoE engine: cacheless on-demand expert loading over distributed
//! edge nodes (paper §3.1–§3.2).
//!
//! Per decode iteration the engine interleaves three concerns exactly as
//! the paper's Fig. 2/4/5 timing diagrams do:
//!
//! 1. **Numerics** — the full-precision main model executes the real AOT
//!    artifacts; the SEP shadow model runs its quantized replica.
//! 2. **Prediction** — the shadow's routes become expert predictions with
//!    availability times `shadow_start + (l+1) * t_shadow_layer`.
//! 3. **Virtual time** — main-node blocks, LAN hops, per-worker expert
//!    streams (PCIe chunk trains — [`OdMoeConfig::chunks`]; one chunk =
//!    the monolithic seed booking), tile-pipelined expert computes and
//!    mispredict aborts/reloads are booked on the cluster's resources;
//!    at the default depth 0 each worker holds at most ONE expert at a
//!    time (loaded just-in-time, evicted right after use — the cacheless
//!    property), while [`OdMoeConfig::prefetch_depth`] `>= 1` lets SEP's
//!    predicted next experts stream into residual link slack ahead of
//!    eviction (DESIGN.md §9).
//!
//! The engine also implements [`BatchEngine`]: `run_batch` steps several
//! concurrent sessions through each decode iteration together, merging
//! their per-layer routes so each *distinct* expert is loaded once per
//! layer per iteration (DESIGN.md §7). When a layer's distinct experts
//! exceed its group size, a worker runs several experts back to back, so
//! its transient residency reaches the number of loads it received that
//! layer (up to `ceil(distinct / group_size)` experts — see
//! `metrics::memory::odmoe_batched` for the honest audit); a batch of
//! one preserves strict single-expert residency and reproduces
//! sequential decode bookings exactly.
//!
//! **Failure model (DESIGN.md §8).** Fail-stop faults are injected with
//! [`OdMoeEngine::inject_failure`] and act during decode: the coordinator
//! heartbeats nodes at token boundaries and additionally notices a death
//! the moment a transfer or compute on the dead node would have
//! completed. A dead worker's slots reassign across survivors through
//! [`SlotMap::fail`] (preferring targets whose projected load still fits
//! the Eq. (1) no-stall window), in-flight work re-books on the
//! replacement one LAN notification later, and a dead shadow node
//! degrades prediction to the reactive no-prefetch path. Numerics never
//! touch virtual time, so the served token stream is bit-identical to the
//! healthy run. Both decode paths share the same failover helpers, which
//! keeps the batch-of-one equivalence intact under failures.

use anyhow::{anyhow, bail, ensure, Result};

use std::collections::BTreeMap;

use super::batch::{merge_distinct, BatchEngine, BatchRunResult};
use super::precision::{gate_weight, prefetch_importance, PrecisionController, PrecisionPolicy, TRANSFER_TIERS};
use super::prefill::{simulate_odmoe_prefill, PrefillTiming};
use super::schedule::{GroupSchedule, SlotMap};
use super::{Engine, PromptResult};
use crate::cache::{CacheConfig, ExpertKey, TierLevel, TieredCache};
use crate::cluster::{ChunkedTransfer, Cluster, HardwareProfile, Ms};
use crate::engine::{BatchState, ModelState, Route, StepRecord};
use crate::fleet::{capability_slots, FleetSpec};
use crate::metrics::correct_count;
use crate::model::{Precision, WeightStore};
use crate::predictor::baseline::RandomPredictor;
use crate::predictor::{AlignmentConfig, Predictor, SepPredictor};
use crate::runtime::Runtime;
use crate::telemetry::Registry;
use crate::trace::EventKind;

/// What drives expert prefetching (ablation cases of Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorMode {
    /// SEP shadow model (cases 1–4 depending on alignment config).
    Sep,
    /// Random prefetch at token start, no shadow node (case 5).
    Random,
    /// No prefetch: load after the gate result only (case 6).
    None,
}

/// A scheduled fail-stop fault on the engine's virtual clock.
///
/// Failures act during decode (prefill models a broadcast that completed
/// before the fault window); a time earlier than the decode start simply
/// means "dead from the first decode iteration". The plan is re-armed by
/// `reset`, so every serving run replays the same scenario on its own
/// clock — which keeps the serve layer's per-request memoization sound.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureSpec {
    /// Worker `worker` fail-stops at `at_ms`.
    Worker { worker: usize, at_ms: Ms },
    /// The shadow (SEP) node fail-stops at `at_ms`: prediction degrades
    /// to reactive gate-result-driven loads, tokens unchanged.
    Shadow { at_ms: Ms },
}

/// Split a `<target>@<ms>[ms]` failure spec into (target, ms) — the one
/// grammar shared by engine failure specs (`worker3@500ms`) and the
/// scheduler's replica failures (`0@500`), so the two CLI surfaces can
/// never drift apart.
pub(crate) fn parse_at_ms(s: &str) -> Result<(&str, f64)> {
    let (who, at) = s
        .split_once('@')
        .ok_or_else(|| anyhow!("failure spec {s:?} needs <target>@<ms>"))?;
    let at = at.trim().trim_end_matches("ms").trim();
    let at_ms: f64 = at.parse().map_err(|_| anyhow!("bad failure time in {s:?}"))?;
    ensure!(
        at_ms.is_finite() && at_ms >= 0.0,
        "failure time must be finite and >= 0 in {s:?}"
    );
    Ok((who.trim(), at_ms))
}

impl FailureSpec {
    /// Parse `worker3@500`, `worker3@500ms`, or `shadow@800`.
    pub fn parse(s: &str) -> Result<Self> {
        let (who, at_ms) = parse_at_ms(s)?;
        if who == "shadow" {
            return Ok(FailureSpec::Shadow { at_ms });
        }
        if let Some(idx) = who.strip_prefix("worker") {
            let worker: usize =
                idx.parse().map_err(|_| anyhow!("bad worker index in {s:?}"))?;
            return Ok(FailureSpec::Worker { worker, at_ms });
        }
        bail!("unknown failure target {who:?} (worker<N> | shadow)")
    }

    /// Parse a comma-separated list, e.g. `worker3@500,shadow@800ms`.
    pub fn parse_list(s: &str) -> Result<Vec<Self>> {
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|p| Self::parse(p.trim()))
            .collect()
    }
}

/// Engine configuration (defaults = the paper's ten-node testbed).
#[derive(Debug, Clone)]
pub struct OdMoeConfig {
    pub n_workers: usize,
    pub shadow_precision: Precision,
    pub align: AlignmentConfig,
    pub predictor: PredictorMode,
    /// Mini-batches per worker transfer during prefill (Fig. 7; 1 = one
    /// large batch, 0 = adaptive per prompt length).
    pub prefill_minibatches: usize,
    /// Sub-expert transfer chunks per expert load (DESIGN.md §9): 1 =
    /// one monolithic PCIe booking (the original behavior, bit-identical
    /// in tokens AND timings); K > 1 streams the expert's `w1/w3/w2`
    /// tiles as K dependent chunks and pipelines the expert FFN behind
    /// them, so compute begins once the first tile is resident.
    pub chunks: usize,
    /// Speculative staging depth (DESIGN.md §9): how many predicted
    /// future experts a worker may stream beyond the one it is still
    /// computing. 0 = strict single-expert residency (the cacheless
    /// seed behavior); D >= 1 lets SEP's top-ranked next candidates fill
    /// residual PCIe slack ahead of eviction — cheap to abort mid-stream
    /// on mispredict, at the cost of up to D+1 transient experts per
    /// worker.
    pub prefetch_depth: usize,
    pub profile: HardwareProfile,
    /// Heterogeneous fleet composition (DESIGN.md §10). `None` — the
    /// default — is the uniform cluster built from `profile`, the
    /// original shared-profile path. `Some(fleet)` gives each worker its
    /// own [`crate::cluster::NodeClass`] duration model and builds the
    /// slot map capability-aware (slots prefer nodes whose class holds
    /// the Eq. (1) window; see [`capability_slots`]); `n_workers` must
    /// equal the fleet's node count. A single-class fleet of the base
    /// profile's class reproduces `None` bit-identically — tokens AND
    /// timings — which `rust/tests/fleet_props.rs` pins.
    pub fleet: Option<FleetSpec>,
    /// Optional tiered expert cache (DESIGN.md §12): per-worker GPU-hot /
    /// CPU-warm / SSD-cold residency budgets layered on top of on-demand
    /// streaming. The default — [`CacheConfig::disabled`], every budget
    /// 0 — constructs no tier state at all, so the cacheless paths run
    /// byte-for-byte the seed code: budget 0 is bit-identical (tokens
    /// AND timings) on sequential, batched, chunked, failure-injection
    /// and mixed-fleet paths, which `rust/tests/cache_props.rs` and the
    /// existing prop suites pin.
    pub cache: CacheConfig,
    /// Runtime mixed-precision expert loading (DESIGN.md §14). The
    /// default — [`PrecisionPolicy::Static`] — builds no controller at
    /// all, so every load streams the deployed profile's fp16 train
    /// byte-for-byte the seed way (bit-identical in tokens AND timings,
    /// pinned by `rust/tests/precision_props.rs`). `Slack` picks the
    /// cheapest of fp16/int8/nf4 whose remaining chunk train still lands
    /// inside the worker's Eq. (1) window; `SlackImportance` adds the
    /// routing-importance signal (gate weight for reactive loads, SEP
    /// rank for prefetches): important experts refuse the NF4 tier.
    pub precision_policy: PrecisionPolicy,
    /// With [`PrecisionPolicy::SlackImportance`] only: allow honestly
    /// *skipping* the weakest routed expert on a worker that provably
    /// cannot land even the NF4 train in-window. Skips drop the expert's
    /// contribution from the residual stream — a real token-level
    /// fidelity cost measured by `workload::fidelity` — and are counted
    /// in `engine.skipped_experts` plus the quality-debt gauge.
    pub precision_skip: bool,
}

impl Default for OdMoeConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            shadow_precision: Precision::Int8,
            align: AlignmentConfig::every_iteration(),
            predictor: PredictorMode::Sep,
            prefill_minibatches: 0, // adaptive
            chunks: 1,
            prefetch_depth: 0,
            profile: HardwareProfile::rtx3090(),
            fleet: None,
            cache: CacheConfig::disabled(),
            precision_policy: PrecisionPolicy::Static,
            precision_skip: false,
        }
    }
}

/// Per-worker pipeline state carried across layers/tokens.
#[derive(Debug, Clone, Default)]
struct WorkerState {
    /// Completion times of this worker's expert computes, in booking
    /// order (non-decreasing — the GPU serializes). Prediction-driven
    /// loads gate on the entry `prefetch_depth` from the end: at depth 0
    /// the next load waits for the previous expert's eviction (strict
    /// single-expert residency, the seed behavior); at depth D the link
    /// may stream up to D future experts while older ones still compute.
    ec_ends: Vec<Ms>,
}

/// The OD-MoE serving engine.
pub struct OdMoeEngine<'rt> {
    pub cfg: OdMoeConfig,
    pub cluster: Cluster,
    /// Healthy-cluster blueprint (Eq. (1) windows, group arithmetic).
    pub schedule: GroupSchedule,
    /// Live slot→worker routing; diverges from `schedule` after failures.
    pub slots: SlotMap,
    /// Healthy slot map `reset` restores (identity on a uniform cluster;
    /// capability-aware first-fit on a fleet).
    slots_blueprint: SlotMap,
    main: ModelState<'rt>,
    sep: Option<SepPredictor<'rt>>,
    /// Per-session shadow predictors for batched decode, lazily built on
    /// the first `run_batch` that needs them (same weights/quantization
    /// as `sep`, so a batch of one is numerically identical to
    /// sequential decode). Unused in sequential mode.
    sep_slots: Vec<SepPredictor<'rt>>,
    random: Option<RandomPredictor>,
    workers: Vec<WorkerState>,
    /// Precomputed per-chunk durations of one expert transfer, per
    /// worker (each worker's *class* profile and `cfg.chunks` are fixed
    /// for the engine's lifetime; uniform clusters hold identical
    /// trains): the hot load path streams straight off this without
    /// allocating; the failover branch indexes the undelivered suffix of
    /// the *replacement's* train, so a resumed stream pays the new
    /// class's honest per-chunk times.
    chunk_durs: Vec<Vec<Ms>>,
    /// Virtual time at which the main node is ready for the next token.
    now: Ms,
    /// When the shadow node finished its previous iteration.
    shadow_free: Ms,
    /// The injected failure plan (survives `reset`, which re-arms it).
    plan: Vec<FailureSpec>,
    /// Worker failures not yet applied this run.
    pending_fail: Vec<(usize, Ms)>,
    /// Shadow failure not yet applied this run.
    pending_shadow: Option<Ms>,
    /// Named engine counters (`engine.expert_loads`,
    /// `engine.aborted_loads`, `engine.failovers`), incremented at the
    /// event sites and cleared by `reset` — the telemetry registry that
    /// replaced the old ad-hoc per-field plumbing (DESIGN.md §11).
    registry: Registry,
    /// Decode iteration windows `(start, end)` on the virtual clock,
    /// in order, since the last reset — the per-token windows
    /// [`crate::telemetry::attribute`] decomposes.
    token_spans: Vec<(Ms, Ms)>,
    /// Per-worker tiered caches (DESIGN.md §12); `None` when
    /// `cfg.cache` is disabled so the cacheless code paths stay
    /// byte-for-byte the seed paths.
    tiers: Option<Vec<TieredCache>>,
    /// Keys SEP predicts within the prefetch window of the layer being
    /// decoded — the reuse-distance policy's protection set. Rebuilt per
    /// layer; always empty while the cache is disabled.
    protected: Vec<ExpertKey>,
    /// Runtime precision controller (DESIGN.md §14); `None` under
    /// [`PrecisionPolicy::Static`] so the load path streams straight off
    /// `chunk_durs` byte-for-byte the seed way.
    precision: Option<PrecisionController>,
    /// Transfer precision of the most recent stream per `(worker, layer,
    /// expert)` — what a hot-tier install "remembers" (upgrade-reload
    /// checks it) and what the EC sites charge quality debt against.
    /// Only populated while a controller is active.
    stream_prec: BTreeMap<(usize, usize, usize), Precision>,
    /// Accumulated honest quality cost this run: Σ gate_weight ×
    /// rel_error over computed downgraded experts, plus the full gate
    /// weight of every skipped expert.
    quality_debt: f64,
    /// Σ of all routed gate weights this run — the quality-debt
    /// normalizer behind the `engine.quality_debt_frac` gauge.
    route_weight: f64,
    /// Per-expert session-route hits accumulated by the batched path's
    /// load-dedup merge (`merge_distinct` counts, summed over layers and
    /// iterations) — drained into [`BatchRunResult::expert_demand`], the
    /// popularity signal the SLO control loop's replication consumes
    /// (DESIGN.md §15). Grown on demand; empty in sequential decode.
    expert_demand: Vec<u64>,
}

impl<'rt> OdMoeEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore, cfg: OdMoeConfig) -> Result<Self> {
        ensure!(cfg.chunks >= 1, "expert transfers need at least one chunk");
        let group_size = ws.cfg.top_k;
        let (schedule, slots, cluster) = match &cfg.fleet {
            // Uniform cluster: the original shared-profile path, asserts
            // and all (equal split, identity slot map) — bit-identical.
            None => {
                let schedule = GroupSchedule::new(cfg.n_workers, group_size);
                let slots = SlotMap::from_schedule(&schedule);
                let cluster = Cluster::new(cfg.profile.clone(), cfg.n_workers);
                (schedule, slots, cluster)
            }
            // Heterogeneous fleet: per-worker class profiles, groups
            // rounded down over however many nodes the fleet brings
            // (leftovers are spares), slots capability-aware.
            Some(fleet) => {
                fleet.validate(&cfg.profile)?;
                ensure!(
                    cfg.n_workers == fleet.n_nodes(),
                    "n_workers {} must match the fleet's {} nodes ({})",
                    cfg.n_workers,
                    fleet.n_nodes(),
                    fleet.label()
                );
                let cluster = Cluster::with_classes(cfg.profile.clone(), fleet.node_classes());
                let n_groups = cfg.n_workers / group_size;
                ensure!(
                    n_groups >= 1,
                    "fleet {} has fewer nodes than one group of {group_size}",
                    fleet.label()
                );
                let slots = capability_slots(&cluster, group_size, cfg.chunks);
                let schedule = GroupSchedule::new(n_groups * group_size, group_size);
                (schedule, slots, cluster)
            }
        };
        let sep = match cfg.predictor {
            PredictorMode::Sep => Some(SepPredictor::new(
                rt,
                &ws,
                cfg.shadow_precision,
                cfg.align,
            )?),
            _ => None,
        };
        let random = match cfg.predictor {
            PredictorMode::Random => {
                Some(RandomPredictor::new(0xACE, ws.cfg.n_experts, ws.cfg.top_k))
            }
            _ => None,
        };
        let main = ModelState::new(rt, ws)?;
        let workers = vec![WorkerState::default(); cfg.n_workers];
        let chunk_durs = (0..cfg.n_workers)
            .map(|w| {
                cluster.worker_profile(w).chunk_durations(cfg.profile.expert_bytes, cfg.chunks)
            })
            .collect();
        let slots_blueprint = slots.clone();
        let tiers = cfg
            .cache
            .enabled()
            .then(|| (0..cfg.n_workers).map(|_| TieredCache::new(&cfg.cache)).collect());
        let precision = (cfg.precision_policy != PrecisionPolicy::Static).then(|| {
            PrecisionController::new(
                &cluster,
                cfg.n_workers,
                cfg.profile.expert_bytes,
                cfg.chunks,
                schedule.n_groups(),
                cfg.precision_policy,
                cfg.precision_skip,
            )
        });
        let mut engine = Self {
            cfg,
            cluster,
            schedule,
            slots,
            slots_blueprint,
            main,
            sep,
            sep_slots: Vec::new(),
            random,
            workers,
            chunk_durs,
            now: 0.0,
            shadow_free: 0.0,
            plan: Vec::new(),
            pending_fail: Vec::new(),
            pending_shadow: None,
            registry: Registry::new(),
            token_spans: Vec::new(),
            tiers,
            protected: Vec::new(),
            precision,
            stream_prec: BTreeMap::new(),
            quality_debt: 0.0,
            route_weight: 0.0,
            expert_demand: Vec::new(),
        };
        engine.charge_static_memory();
        Ok(engine)
    }

    fn charge_static_memory(&mut self) {
        let p = &self.cluster.profile;
        self.cluster.main.alloc(p.nonexpert_bytes as u64);
        if self.sep.is_some() {
            self.cluster.shadow.alloc(p.shadow_model_bytes as u64);
        }
        let act = p.activation_bytes as u64;
        for w in &mut self.cluster.workers {
            w.alloc(act);
        }
    }

    /// Enable Fig. 2-style trace recording.
    pub fn enable_trace(&mut self) {
        self.cluster.trace.enabled = true;
    }

    pub fn recall_correct(&self) -> &ModelState<'rt> {
        &self.main
    }

    /// Schedule a fail-stop fault (see [`FailureSpec`]). May be called
    /// multiple times; `reset` re-arms the whole plan.
    pub fn inject_failure(&mut self, f: FailureSpec) {
        match f {
            FailureSpec::Worker { worker, at_ms } => {
                assert!(
                    worker < self.cfg.n_workers,
                    "worker {worker} out of range ({} workers)",
                    self.cfg.n_workers
                );
                assert!(at_ms.is_finite() && at_ms >= 0.0, "bad failure time {at_ms}");
            }
            FailureSpec::Shadow { at_ms } => {
                assert!(at_ms.is_finite() && at_ms >= 0.0, "bad failure time {at_ms}");
            }
        }
        self.plan.push(f);
        self.arm(f);
    }

    fn arm(&mut self, f: FailureSpec) {
        match f {
            FailureSpec::Worker { worker, at_ms } => self.pending_fail.push((worker, at_ms)),
            FailureSpec::Shadow { at_ms } => {
                self.pending_shadow = Some(self.pending_shadow.map_or(at_ms, |x| x.min(at_ms)));
            }
        }
    }

    /// Loads/computes re-booked on a replacement worker after a
    /// mid-flight node death, cumulative since the last reset.
    pub fn failovers(&self) -> u64 {
        self.registry.counter("engine.failovers")
    }

    /// The engine's metrics registry (counters since the last reset).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Decode iteration windows on the virtual clock since the last
    /// reset, in decode order — feed these (with the trace from
    /// [`Self::enable_trace`]) to [`crate::telemetry::attribute`].
    pub fn token_spans(&self) -> &[(Ms, Ms)] {
        &self.token_spans
    }

    /// Experts currently GPU-hot on worker `w` (0 when the cache is
    /// disabled) — their bytes are held on the worker's memory ledger.
    pub fn cache_hot_resident(&self, w: usize) -> usize {
        self.tiers.as_ref().map_or(0, |t| t[w].hot_len())
    }

    /// Cumulative cache accesses since reset as (hot, warm, cold,
    /// misses), summed over workers. All zero while the cache is
    /// disabled.
    pub fn cache_stats(&self) -> (u64, u64, u64, u64) {
        self.tiers.as_ref().map_or((0, 0, 0, 0), |tiers| {
            tiers.iter().fold((0, 0, 0, 0), |acc, t| {
                (
                    acc.0 + t.hot_hits,
                    acc.1 + t.warm_hits,
                    acc.2 + t.cold_hits,
                    acc.3 + t.misses,
                )
            })
        })
    }

    /// Is `(layer, expert)` GPU-hot on `w`? Right after a load call this
    /// is equivalent to "that load was a hot hit that streamed nothing":
    /// installs only happen at compute time, later in the layer, so the
    /// state cannot have changed in between. Always false when the cache
    /// is disabled — the budget-0 counting paths are untouched.
    fn hot_resident(&self, w: usize, layer: usize, expert: usize) -> bool {
        self.tiers.as_ref().is_some_and(|t| t[w].contains_hot((layer, expert)))
    }

    /// Rebuild the reuse-distance protection set for layer `l`: every
    /// expert SEP predicts within the next `prefetch_depth + 1` layers
    /// (the lookahead window; >= 1 so the policy is meaningful at depth
    /// 0). `route_for(lf)` yields each session's predicted route for a
    /// future layer. No-op while the cache is disabled.
    fn rebuild_protected<'a>(
        &mut self,
        l: usize,
        n_layers: usize,
        mut routes_for: impl FnMut(usize) -> Vec<&'a [usize]>,
    ) {
        if self.tiers.is_none() {
            return;
        }
        self.protected.clear();
        let horizon = n_layers.min(l + 1 + self.cfg.prefetch_depth + 1);
        for lf in (l + 1)..horizon {
            for route in routes_for(lf) {
                for &e in route {
                    if !self.protected.contains(&(lf, e)) {
                        self.protected.push((lf, e));
                    }
                }
            }
        }
    }

    // ---- Failure machinery (shared by both decode paths). ---------------

    fn pending_worker_fail(&self, w: usize) -> Option<Ms> {
        self.pending_fail
            .iter()
            .filter(|&&(pw, _)| pw == w)
            .map(|&(_, at)| at)
            .fold(None, |m: Option<Ms>, at| Some(m.map_or(at, |x| x.min(at))))
    }

    /// Fail-stop worker `w` at `at`: freeze its resources, drop its
    /// memory contents, and reassign its slots across survivors,
    /// preferring targets whose *own class* keeps the projected load
    /// inside the Eq. (1) no-stall window (earliest-first-chunk aware
    /// when transfers are chunked — see
    /// [`HardwareProfile::reroute_feasible`]), least projected load
    /// *time* first — on a mixed fleet a fast survivor already carrying
    /// a slot can beat an empty slow one. Uniform clusters order exactly
    /// as the old shared-profile reroute did.
    fn apply_worker_failure(&mut self, w: usize, at: Ms) {
        self.pending_fail.retain(|&(pw, _)| pw != w);
        // The node's tier contents die with it (no dealloc here:
        // `Node::fail` zeroes the whole GPU ledger). Survivors rebuild
        // hot state from scratch — the cold-start reroute the failure
        // tests pin.
        if let Some(tiers) = self.tiers.as_mut() {
            tiers[w].drop_all();
        }
        self.cluster.fail_worker(w, at);
        let n_groups = self.schedule.n_groups();
        let chunks = self.cfg.chunks;
        let cluster = &self.cluster;
        self.slots.fail_with(
            w,
            |c, slots| cluster.worker_profile(c).reroute_feasible(slots, n_groups, chunks),
            |c| cluster.worker_profile(c).effective_load_ms(chunks),
        );
    }

    /// Apply every worker failure due by `t` — the coordinator's
    /// token-boundary heartbeat — in chronological order (ties break on
    /// the worker id), NOT injection order: an earlier death must be
    /// applied first so a later reroute never targets a node that was
    /// already physically dead, and identical plans written in different
    /// `--fail` flag orders replay identically. Mid-iteration deaths are
    /// caught lazily by the failover helpers below.
    fn apply_due_failures(&mut self, t: Ms) {
        loop {
            let due = self
                .pending_fail
                .iter()
                .filter(|&&(_, at)| at <= t)
                .copied()
                .min_by(|a, b| {
                    a.1.partial_cmp(&b.1)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.0.cmp(&b.0))
                });
            match due {
                Some((w, at)) => self.apply_worker_failure(w, at),
                None => break,
            }
        }
    }

    fn apply_shadow_failure(&mut self) {
        if let Some(at) = self.pending_shadow.take() {
            self.cluster.fail_shadow(at);
            self.shadow_free = self.shadow_free.max(at);
        }
    }

    /// Has the shadow node failed by time `t`? Applies the failure on
    /// first notice; idempotent afterwards.
    fn shadow_dead_by(&mut self, t: Ms) -> bool {
        if let Some(at) = self.pending_shadow {
            if at <= t {
                self.apply_shadow_failure();
            }
        }
        !self.cluster.shadow.is_alive()
    }

    /// The instant slot loads targeting worker `w` may start streaming:
    /// the eviction of the expert `prefetch_depth` computes back. Depth 0
    /// is the previous expert's eviction (strict single-expert residency,
    /// the seed behavior); depth D lets D future experts stream while
    /// older ones still compute (DESIGN.md §9).
    fn residency_gate(&self, w: usize) -> Ms {
        let ends = &self.workers[w].ec_ends;
        match ends.len().checked_sub(1 + self.cfg.prefetch_depth) {
            Some(i) => ends[i],
            None => 0.0,
        }
    }

    /// Publish the run's honest quality-debt fraction — downgraded
    /// residual error plus skipped gate weight, over all routed gate
    /// weight (DESIGN.md §14). Only meaningful under a runtime precision
    /// policy; Static publishes nothing (no controller, no new gauges).
    fn flush_quality_gauges(&mut self) {
        if self.precision.is_none() {
            return;
        }
        let frac =
            if self.route_weight > 0.0 { self.quality_debt / self.route_weight } else { 0.0 };
        self.registry.gauge_set("engine.quality_debt_frac", frac);
    }

    /// Book one expert load for slot `(layer, slot)` as a chunk train
    /// (`cfg.chunks` chunks; one chunk = the monolithic booking),
    /// rerouting around node deaths: a worker already dead when the load
    /// would be dispatched was skipped by the slot map; a worker that
    /// dies mid-transfer freezes at the failure instant, and the
    /// coordinator re-books **only the chunks the dead worker hadn't
    /// delivered** on the slot's replacement one LAN notification later
    /// (in-flight streams are mirrored at the slot's failover target;
    /// the mirror is dropped once the stream completes — cacheless — so
    /// a post-stream death re-streams in full, see DESIGN.md §9).
    /// `respect_residency` gates the
    /// stream start behind the target's residency window (prediction-
    /// driven and conventional reactive loads); mispredict reloads skip
    /// it, exactly like the seed's reload path.
    ///
    /// `expert` identifies the weights for the tiered cache (DESIGN.md
    /// §12; ignored — and the lookup skipped entirely — while the cache
    /// is disabled): a GPU-hot hit returns a ready-at-notice pseudo
    /// transfer without booking the link or touching the ledger (the
    /// bytes never left the GPU); an SSD-cold hit stages over the
    /// worker's storage link first; warm hits and misses stream exactly
    /// as today.
    /// `importance` is the expert's routing-importance signal (gate
    /// weight for reactive loads, SEP-rank decay for prefetches) feeding
    /// the runtime precision controller; ignored — like the controller
    /// itself — under [`PrecisionPolicy::Static`].
    fn load_with_failover(
        &mut self,
        layer: usize,
        slot: usize,
        mut earliest: Ms,
        respect_residency: bool,
        expert: Option<usize>,
        importance: f64,
    ) -> ChunkedTransfer {
        let bytes = self.cluster.profile.expert_bytes;
        let lan_lat = self.cluster.profile.lan_lat_ms;
        // Chunks already delivered before a failover; the replacement
        // re-books only the undelivered suffix — of ITS OWN class's
        // train, so a resumed stream pays the new link's honest times
        // (identical to the dead worker's on a uniform cluster).
        let mut done_chunks = 0usize;
        // Failover-forced downgrade floor: each mid-stream death pushes
        // the re-booked suffix at least one tier lower (DESIGN.md §14).
        let mut min_tier = 0usize;
        loop {
            let w = self.slots.worker_for(layer, slot);
            // The dispatch notice reaches a class-c worker its LAN
            // attach latency later (0 on wired classes and every uniform
            // cluster — bit-identical there).
            let notice = earliest + self.cluster.lan_extra(w);
            if let Some(at) = self.pending_worker_fail(w) {
                if at <= notice {
                    self.apply_worker_failure(w, at);
                    continue;
                }
            }
            let start_at = if respect_residency {
                notice.max(self.residency_gate(w))
            } else {
                notice
            };
            // Tiered-cache lookup (DESIGN.md §12). Skipped structurally
            // while the cache is disabled — budget 0 books the seed's
            // exact sequence.
            let hit = match (expert, self.tiers.as_mut()) {
                (Some(e), Some(tiers)) => Some(tiers[w].lookup((layer, e))),
                _ => None,
            };
            let mut stream_at = start_at;
            match hit {
                Some(Some(TierLevel::GpuHot)) => {
                    // Upgrade reload (DESIGN.md §14): a hot resident
                    // installed from a downgraded stream gets re-streamed
                    // at full precision when slack is plentiful — the
                    // worker's class lands a whole fp16 train in-window
                    // AND the controller would pick fp16 for this very
                    // load. Drop the low-precision copy (releasing its
                    // ledger bytes) and fall through to a normal stream;
                    // the upgraded copy re-installs at compute time.
                    let upgrade = match (self.precision.as_ref(), expert) {
                        (Some(ctl), Some(e)) => {
                            ctl.fp16_fits(w)
                                && self
                                    .stream_prec
                                    .get(&(w, layer, e))
                                    .is_some_and(|p| *p != Precision::Fp16)
                                && ctl.select(w, start_at, notice + ctl.window_ms(w), importance, 0, 0)
                                    == 0
                        }
                        _ => false,
                    };
                    if !upgrade {
                        // Hot hit: the expert never left the GPU. No link
                        // booking, no ledger change; ready the moment the
                        // dispatch notice lands. The single-element train
                        // keeps `first_ready == done == notice`.
                        self.registry.counter_add("engine.cache_hot_hits", 1);
                        return ChunkedTransfer {
                            worker: w,
                            start: notice,
                            chunk_ends: vec![notice],
                            free_before: self.cluster.workers[w].pcie.free_at(),
                        };
                    }
                    self.registry.counter_add("engine.upgrade_reloads", 1);
                    let e = expert.expect("upgrade implies an expert key");
                    if self.tiers.as_mut().expect("hot hit implies tiers")[w]
                        .remove_hot((layer, e))
                    {
                        self.cluster.workers[w].dealloc(bytes as u64);
                    }
                }
                Some(Some(TierLevel::SsdCold)) => {
                    // Cold hit: stage SSD -> DRAM on the worker's storage
                    // link, then the standard PCIe train.
                    self.registry.counter_add("engine.cache_cold_hits", 1);
                    let (_, staged) = self.cluster.ssd_stage(w, start_at, bytes);
                    stream_at = staged;
                }
                Some(Some(TierLevel::CpuWarm)) => {
                    // Warm = host DRAM = where on-demand streams already
                    // load from: the hit only changes accounting.
                    self.registry.counter_add("engine.cache_warm_hits", 1);
                }
                Some(None) => {
                    self.registry.counter_add("engine.cache_misses", 1);
                }
                None => {}
            }
            // A stream that jumps the residency gate (depth >= 1) is the
            // speculative slack-filler; tag it so timelines show it.
            let kind = if respect_residency
                && self.cfg.prefetch_depth > 0
                && start_at < self.workers[w].ec_ends.last().copied().unwrap_or(0.0)
            {
                EventKind::Prefetch
            } else {
                EventKind::ExpertLoad
            };
            // Runtime precision selection (DESIGN.md §14): the cheapest
            // [`TRANSFER_TIERS`] tier whose remaining train still lands
            // inside this worker's Eq. (1) window, measured from the
            // dispatch notice. `None` (policy Static) streams the
            // engine's static fp16 train byte-for-byte the seed way.
            let tier = self
                .precision
                .as_ref()
                .map(|ctl| ctl.select(w, stream_at, notice + ctl.window_ms(w), importance, done_chunks, min_tier));
            if let Some(ti) = tier {
                self.registry.counter_add(PrecisionController::tier_counter(ti), 1);
                if let Some(e) = expert {
                    self.stream_prec.insert((w, layer, e), TRANSFER_TIERS[ti]);
                }
            }
            let durs: &[Ms] = match tier {
                Some(ti) => {
                    let ctl = self.precision.as_ref().expect("tier implies a controller");
                    &ctl.durs(w, ti)[done_chunks..]
                }
                None => &self.chunk_durs[w][done_chunks..],
            };
            let t = self.cluster.expert_load_chunks(w, stream_at, durs, kind);
            if let Some(at) = self.pending_worker_fail(w) {
                if at < t.done() {
                    // The stream dies with the node: the link freezes at
                    // the failure instant; the replacement re-books the
                    // undelivered suffix of the train after the failure
                    // notice reaches the coordinator — at least one
                    // precision tier lower when a controller is active
                    // (the recovery is already behind schedule).
                    done_chunks += t.delivered_by(at);
                    if self.precision.is_some() {
                        min_tier = (min_tier + 1).min(TRANSFER_TIERS.len() - 1);
                    }
                    self.apply_worker_failure(w, at);
                    self.registry.counter_add("engine.failovers", 1);
                    earliest = earliest.max(at + lan_lat);
                    continue;
                }
            }
            self.cluster.workers[w].alloc(bytes as u64);
            // The ledger mutates in program order, but a stream that
            // jumped the residency gate co-resides (in virtual time)
            // with every expert still computing when its booking began —
            // their deallocs already happened in program order. Record
            // the true transient peak without moving steady-state usage.
            // (`t.start`, the actual booked start, not the requested
            // `start_at`: a backlogged link can begin far later, by when
            // older experts have genuinely left.)
            let overlap = self.workers[w].ec_ends.iter().filter(|&&e| e > t.start).count();
            if overlap > 0 {
                let extra = overlap as u64 * bytes as u64;
                self.cluster.workers[w].alloc(extra);
                self.cluster.workers[w].dealloc(extra);
            }
            return t;
        }
    }

    /// Gate result disagreed with a prediction-driven stream: evict the
    /// wrong expert and cancel whatever of its train is still in flight
    /// on the link. Chunks delivered before the abort stay booked (wasted
    /// but transferred); the in-flight chunk's tail and every unstarted
    /// chunk are reclaimed, and the cancellation never rewinds the link
    /// below work queued ahead of the aborted train (`free_before`). Only
    /// the frontier train on a link can be cancelled mid-flight (an
    /// earlier wasted train already completed behind it and is simply
    /// evicted). A worker that died meanwhile already lost both the
    /// expert and the stream with the node.
    fn abort_predicted(&mut self, t: &ChunkedTransfer, reactive_t: Ms) {
        let w = t.worker;
        if let Some(at) = self.pending_worker_fail(w) {
            if at <= reactive_t {
                self.apply_worker_failure(w, at);
            }
        }
        if self.cluster.workers[w].is_alive() {
            let bytes = self.cluster.profile.expert_bytes as u64;
            self.cluster.workers[w].dealloc(bytes);
            if self.cluster.workers[w].pcie.free_at() <= t.done() {
                self.cluster.workers[w].pcie.preempt(reactive_t.max(t.free_before));
            }
        }
    }

    /// Book the expert compute for slot `(layer, slot)` on `holder` (the
    /// worker its expert was streamed to), one tile per chunk gated on
    /// that chunk's arrival (`gates`) — the FFN pipelines behind the
    /// transfer and ends no later than the monolithic compute would. The
    /// FFN base duration is the *holder's class* time for a `rows`-token
    /// batched FFN ([`Cluster::expert_ffn_ms`]; `rows == 1` is the
    /// class's plain `t_expert_gpu_ms`), re-derived after a failover so
    /// a replacement of a different class computes at its own speed, and
    /// the compute gates on the embedding's arrival at the *current*
    /// holder's class (`ec_floor.max(embed_arrival + lan_extra)` — a
    /// replacement behind a slower LAN attach honestly waits for its own
    /// copy of the embedding; all extras are 0 on a uniform cluster). If
    /// the holder dies before the compute finishes, the expert is lost
    /// with the node: the slot's replacement re-streams it (one LAN
    /// notification after the failure) and the tiles re-gate on the new
    /// train. Evicts the expert after the compute (cacheless) — unless
    /// the tiered cache admits it GPU-hot, in which case the bytes stay
    /// on the ledger until the entry is demoted, dropped, or the node
    /// dies (DESIGN.md §12; install happens HERE, at compute time, so
    /// mispredicted streams never enter the cache) — and advances the
    /// worker's residency history. Returns the final (holder, compute
    /// end).
    #[allow(clippy::too_many_arguments)]
    fn compute_with_failover(
        &mut self,
        layer: usize,
        slot: usize,
        expert: usize,
        mut holder: usize,
        ec_floor: Ms,
        embed_arrival: Ms,
        rows: usize,
        gates: &[Ms],
        importance: f64,
    ) -> (usize, Ms) {
        let bytes = self.cluster.profile.expert_bytes as u64;
        let lan_lat = self.cluster.profile.lan_lat_ms;
        // Owned gates only materialize on the (rare) failover branch —
        // the common case computes straight off the caller's slice.
        let mut restreamed: Option<Vec<Ms>> = None;
        loop {
            let earliest = ec_floor.max(embed_arrival + self.cluster.lan_extra(holder));
            // The holder may have died since its stream completed (its own
            // pending failure applied below, or another slot's failover):
            // the expert is lost with the node, so the slot's replacement
            // re-streams and recomputes. This branch is the single
            // counting point for compute-side failovers — every compute
            // recovery (including a mid-compute abort, which re-enters
            // here) passes through it exactly once.
            if let Some(at) = self.cluster.workers[holder].failed_at() {
                self.registry.counter_add("engine.failovers", 1);
                let t =
                    self.load_with_failover(layer, slot, at + lan_lat, false, Some(expert), importance);
                holder = t.worker;
                restreamed = Some(t.chunk_ends);
                continue;
            }
            if let Some(at) = self.pending_worker_fail(holder) {
                if at <= earliest {
                    self.apply_worker_failure(holder, at);
                    continue;
                }
            }
            let tile_gates = restreamed.as_deref().unwrap_or(gates);
            let base_ms = self.cluster.expert_ffn_ms(holder, rows);
            let (_, ec_end) =
                self.cluster.expert_compute_chunked(holder, earliest, base_ms, tile_gates);
            if let Some(at) = self.pending_worker_fail(holder) {
                if at < ec_end {
                    // Node dies mid-compute: freeze it; the dead-holder
                    // branch above re-books (and counts) the recovery.
                    self.apply_worker_failure(holder, at);
                    continue;
                }
            }
            // Cacheless eviction — or, with the tiered cache enabled, an
            // install: the just-used expert promotes to GPU-hot (keeping
            // its bytes on the ledger) and any expert it displaced from
            // the hot tier releases its bytes as it demotes down the
            // warm/cold chain. A hot-hit compute never allocated, so the
            // skipped dealloc keeps the ledger balanced either way.
            let (retain, evicted_hot) = match self.tiers.as_mut() {
                Some(tiers) => {
                    let inst = tiers[holder].install((layer, expert), &self.protected);
                    (inst.hot_resident, inst.evicted_hot.len() as u64)
                }
                None => (false, 0),
            };
            if !retain {
                self.cluster.workers[holder].dealloc(bytes);
            }
            if evicted_hot > 0 {
                self.cluster.workers[holder].dealloc(evicted_hot * bytes);
            }
            let ends = &mut self.workers[holder].ec_ends;
            ends.push(ec_end);
            // Only the freshest entries are ever read: the residency
            // gate wants the (depth+1)-th newest, and the overlap count
            // involves experts still computing at a new stream's start —
            // which the gate bounds to the newest depth entries (plus
            // one for ungated reloads). Truncating keeps both reads
            // exact for gated loads and O(1) per token.
            let keep = self.cfg.prefetch_depth + 2;
            if ends.len() > keep {
                let drop = ends.len() - keep;
                ends.drain(..drop);
            }
            return (holder, ec_end);
        }
    }

    /// One decode iteration: returns (output token, logits, per-layer
    /// correct-prediction counts).
    ///
    /// NOTE: `decode_iteration_batch` mirrors this pipeline for N
    /// sessions and must stay in timing lockstep — a batch of one books
    /// the exact same resource sequence (pinned by
    /// `batch_of_one_matches_sequential_odmoe`, healthy and under
    /// failures). Both paths share the phase structure (predicted loads,
    /// gate-result aborts, reloads, computes) and the failover helpers.
    /// Change them together.
    fn decode_iteration(
        &mut self,
        token: u32,
        stall_ms: &mut Ms,
    ) -> Result<(u32, Vec<f32>, Vec<usize>)> {
        let cfg = self.main.cfg().clone();
        let p = self.cluster.profile.clone();
        let n_layers = cfg.n_layers;
        let t0 = self.now;
        self.apply_due_failures(t0);
        let shadow_alive = self.cfg.predictor != PredictorMode::Sep || !self.shadow_dead_by(t0);

        // ---- Shadow node: alignment + emulation (numerics first). -------
        let mut pred_routes: Vec<Option<Vec<usize>>> = vec![None; n_layers];
        let mut pred_avail: Vec<Ms> = vec![f64::INFINITY; n_layers];
        match self.cfg.predictor {
            PredictorMode::Sep if shadow_alive => {
                let cutoff = self.pending_shadow.unwrap_or(f64::INFINITY);
                let sep = self.sep.as_mut().unwrap();
                sep.begin_token(&self.main, token)?;
                // Late departure (Fig. 5): alignment payload must reach the
                // shadow node before S_0 starts.
                let align_delay = sep.alignment_delay_ms(&p);
                let start = self.shadow_free.max(t0 + align_delay);
                let mut died = false;
                for l in 0..n_layers {
                    let done = start + (l as f64 + 1.0) * p.t_shadow_layer_ms;
                    if done > cutoff {
                        // Shadow dies mid-emulation: layers it never
                        // reached stay unpredicted (reactive loads).
                        died = true;
                        break;
                    }
                    pred_avail[l] = done + p.lan_lat_ms; // notify worker
                    pred_routes[l] = Some(sep.predict(l).experts.clone());
                    self.cluster.trace.push(
                        EventKind::ShadowCompute,
                        self.cluster.shadow.id,
                        start + l as f64 * p.t_shadow_layer_ms,
                        done,
                        "S",
                    );
                }
                if died {
                    self.apply_shadow_failure();
                } else {
                    self.shadow_free = start + n_layers as f64 * p.t_shadow_layer_ms;
                }
            }
            // Dead shadow: no predictions — every load degrades to the
            // reactive (gate-result-driven) no-prefetch path; the token
            // stream is unchanged because routes come from the main model.
            PredictorMode::Sep => {}
            PredictorMode::Random => {
                let r = self.random.as_mut().unwrap();
                for l in 0..n_layers {
                    pred_routes[l] = r.predict(l);
                    pred_avail[l] = t0;
                }
            }
            PredictorMode::None => {}
        }

        // ---- Main model numerics (routes + token are ground truth). -----
        // Under the SlackImportance skip rule (DESIGN.md §14) the
        // weakest routed expert may be honestly dropped on a worker that
        // provably cannot land even the NF4 train in-window: its
        // contribution leaves the residual stream (a real fidelity cost
        // `workload::fidelity` measures) and the placement below loads
        // nothing for it. With skipping inactive this IS `decode_step` —
        // the decider path never runs.
        let mut skip_log: Vec<Vec<usize>> = Vec::new();
        let rec = if self.precision.as_ref().is_some_and(|c| c.skip_active()) {
            skip_log = vec![Vec::new(); n_layers];
            let ctl = self.precision.as_ref().expect("skip implies a controller");
            let slots = &self.slots;
            let reg = &mut self.registry;
            let debt = &mut self.quality_debt;
            let log = &mut skip_log;
            let mut decide = |l: usize, route: &Route| -> Option<usize> {
                let last = route.experts.len().checked_sub(1)?;
                if last == 0 {
                    return None; // never drop a layer's only expert
                }
                let weight = route.weights[last] as f64;
                let w = slots.worker_for(l, last);
                if !ctl.should_skip(w, weight) {
                    return None;
                }
                log[l].push(route.experts[last]);
                reg.counter_add("engine.skipped_experts", 1);
                *debt += weight; // the whole contribution is lost
                Some(last)
            };
            self.main.decode_step_skipping(token, &mut decide)?
        } else {
            self.main.decode_step(token)?
        };
        if self.precision.is_some() {
            self.route_weight += rec
                .routes
                .iter()
                .flat_map(|r| &r.weights)
                .map(|&w| w as f64)
                .sum::<f64>();
        }

        // ---- Virtual-time pipeline over main + workers (Fig. 2). --------
        let group_size = self.slots.group_size();
        let mut m_ready = t0;
        let mut correct = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            // M_l: attention + gating on the main node.
            let (m_start, m_end) =
                self.cluster.main.gpu.acquire(m_ready, p.t_nonexpert_ms);
            self.cluster
                .trace
                .push(EventKind::MainCompute, self.cluster.main.id, m_start, m_end, "M");

            let actual = &rec.routes[l];
            let predicted = pred_routes[l].as_deref().unwrap_or(&[]);
            correct.push(correct_count(predicted, &actual.experts));
            // Experts the skip rule dropped this layer: they are not
            // placed, loaded, or computed (empty unless skipping fired).
            let skipped: &[usize] = skip_log.get(l).map_or(&[], |v| v.as_slice());

            // Expert placement: slot j of the group takes predicted[j]
            // (or the actual expert when prediction is late/absent/wrong).
            // The prediction-driven load can begin once the prediction
            // reached the worker AND its previous expert was evicted; the
            // reactive (gate-result-driven) path starts at M_l end.
            let reactive_t = m_end + p.lan_lat_ms;
            // Reuse-distance protection: experts SEP predicts within the
            // lookahead window must not be evicted from the hot tier
            // (no-op while the cache is disabled).
            self.rebuild_protected(l, n_layers, |lf| {
                pred_routes[lf].as_deref().into_iter().collect()
            });
            // Phase 1 — prediction-driven streams, one per slot.
            // `owned[slot]` tracks which expert's weights a slot serves
            // (confirmed predictions keep their predicted expert even
            // when it sits at a different position in the actual route);
            // pure bookkeeping for the cache keys, no timing effect.
            let mut holders: Vec<Option<ChunkedTransfer>> =
                (0..group_size).map(|_| None).collect();
            let mut owned: Vec<Option<usize>> = vec![None; group_size];
            let mut aborts: Vec<ChunkedTransfer> = Vec::new();
            let mut pending: Vec<(usize, bool)> = Vec::new(); // (slot, residency-gated)
            for slot in 0..group_size {
                match predicted.get(slot).copied() {
                    Some(pe) if pred_avail[l] <= reactive_t => {
                        let t = self.load_with_failover(
                            l,
                            slot,
                            pred_avail[l],
                            true,
                            Some(pe),
                            prefetch_importance(slot),
                        );
                        // A GPU-hot hit streamed nothing: it is neither a
                        // counted load (confirmed) nor an abortable
                        // stream (mispredicted — the expert stays hot).
                        let hot = self.hot_resident(t.worker, l, pe);
                        if actual.experts.contains(&pe) && !skipped.contains(&pe) {
                            if !hot {
                                self.registry.counter_add("engine.expert_loads", 1);
                            }
                            holders[slot] = Some(t);
                            owned[slot] = Some(pe);
                        } else if hot {
                            pending.push((slot, false));
                        } else {
                            // Mispredict: the reload is gate-driven (the
                            // link is cancelled first, so no residency
                            // wait — the seed's reload path).
                            self.registry.counter_add("engine.aborted_loads", 1);
                            aborts.push(t);
                            pending.push((slot, false));
                        }
                    }
                    // No usable prediction: load the actual expert on the
                    // gate result (conventional offloading path).
                    _ => pending.push((slot, true)),
                }
            }
            // Unconfirmed slots take the actual experts no confirmed
            // stream already covers, in route order (multiset-exact:
            // each route entry is served exactly once). Skipped experts
            // are nobody's to serve — their slots simply idle.
            {
                let mut remaining: Vec<usize> =
                    actual.experts.iter().copied().filter(|e| !skipped.contains(e)).collect();
                for pe in owned.iter().flatten() {
                    if let Some(i) = remaining.iter().position(|x| x == pe) {
                        remaining.remove(i);
                    }
                }
                let mut rem = remaining.into_iter();
                for o in owned.iter_mut() {
                    if o.is_none() {
                        *o = rem.next();
                    }
                }
            }
            // Phase 2 — gate result: cancel mispredicted streams (their
            // undelivered chunks are reclaimed; delivered chunks stay
            // booked and are simply evicted).
            for t in &aborts {
                self.abort_predicted(t, reactive_t);
            }
            // Phase 3 — reloads + reactive loads. A slot left unowned by
            // a skip idles this layer (nothing to stream or compute).
            for &(slot, residency) in &pending {
                let Some(e) = owned[slot] else { continue };
                let t = self.load_with_failover(
                    l,
                    slot,
                    reactive_t,
                    residency,
                    Some(e),
                    gate_weight(actual, e),
                );
                if !self.hot_resident(t.worker, l, e) {
                    self.registry.counter_add("engine.expert_loads", 1);
                }
                holders[slot] = Some(t);
            }
            // EC may begin once every expert's FIRST chunk is resident
            // (at chunk count 1, first == last — the seed's whole-expert
            // gate); later tiles gate on their own chunks below. Idle
            // (skip-emptied) slots hold no transfer and gate nothing.
            let expert_ready =
                holders.iter().flatten().fold(0.0f64, |m, t| m.max(t.first_ready()));

            // Embedding ships to the group after M_l.
            let embed_arrival = self.cluster.lan_send(m_end, p.embed_msg_bytes, "embed");
            let ec_earliest = embed_arrival.max(expert_ready);
            *stall_ms += (expert_ready - embed_arrival).max(0.0);
            if expert_ready > embed_arrival {
                self.cluster.trace.push(
                    EventKind::Stall,
                    self.cluster.workers[self.slots.worker_for(l, 0)].id,
                    embed_arrival,
                    expert_ready,
                    "stall",
                );
            }

            // EC_l on the group's devices (parallel while slots map to
            // distinct workers; serialized where failures concentrated
            // slots on one survivor), tile-pipelined behind each stream.
            // Each holder computes at ITS class's FFN speed, gated on
            // the embedding's arrival at that class (wired + its LAN
            // attach extra); the combined output can leave for the main
            // node once the last holder's result reaches the wire —
            // again its attach extra later. All the extras are 0 on a
            // uniform cluster, collapsing to the old expressions.
            let mut out_ready = ec_earliest;
            for (slot, t) in holders.iter().enumerate() {
                let Some(t) = t else { continue }; // slot idled by a skip
                let e = owned[slot].expect("a held slot owns an expert");
                let (holder, ec_end) = self.compute_with_failover(
                    l,
                    slot,
                    e,
                    t.worker,
                    ec_earliest,
                    embed_arrival,
                    1,
                    &t.chunk_ends,
                    gate_weight(actual, e),
                );
                // Quality debt of the stream actually computed: charged
                // here — not at load issue — so aborted mispredicted
                // streams never pollute the fidelity account.
                if self.precision.is_some() {
                    let prec = self
                        .stream_prec
                        .get(&(holder, l, e))
                        .copied()
                        .unwrap_or(Precision::Fp16);
                    self.quality_debt += gate_weight(actual, e) * prec.rel_error();
                }
                out_ready = out_ready.max(ec_end + self.cluster.lan_extra(holder));
            }

            // Combined expert output returns to the main node.
            m_ready = self.cluster.lan_send(out_ready, p.embed_msg_bytes, "embed-back");
        }

        // LM head on the main node.
        let (_, lm_end) = self.cluster.main.gpu.acquire(m_ready, p.t_lm_head_ms);
        self.now = lm_end;
        Ok((rec.token_out, rec.logits, correct))
    }
}

impl<'rt> Engine for OdMoeEngine<'rt> {
    fn name(&self) -> String {
        let mode = match self.cfg.predictor {
            PredictorMode::Sep => format!(
                "sep-{}-T{}KV{}",
                self.cfg.shadow_precision.label(),
                self.cfg.align.token_period.label(),
                self.cfg.align.kv_period.label()
            ),
            PredictorMode::Random => "random-prefetch".into(),
            PredictorMode::None => "no-prefetch".into(),
        };
        let name = if self.cfg.chunks > 1 || self.cfg.prefetch_depth > 0 {
            format!(
                "od-moe({mode},chunks{},depth{})",
                self.cfg.chunks, self.cfg.prefetch_depth
            )
        } else {
            format!("od-moe({mode})")
        };
        let name = if self.cfg.cache.enabled() {
            format!("{name}+cache[{}]", self.cfg.cache.label())
        } else {
            name
        };
        let name = match self.cfg.precision_policy {
            PrecisionPolicy::Static => name,
            // The skip tag only when the rule can actually fire (it
            // requires the importance signal, i.e. SlackImportance).
            PrecisionPolicy::SlackImportance if self.cfg.precision_skip => {
                format!("{name}+prec[slack-importance+skip]")
            }
            policy => format!("{name}+prec[{}]", policy.label()),
        };
        match &self.cfg.fleet {
            Some(f) => format!("{name}@{}", f.label()),
            None => name,
        }
    }

    fn reset(&mut self) -> Result<()> {
        self.main.reset();
        if let Some(s) = self.sep.as_mut() {
            s.reset();
        }
        self.cluster.reset();
        self.slots = self.slots_blueprint.clone();
        self.pending_fail.clear();
        self.pending_shadow = None;
        for f in self.plan.clone() {
            self.arm(f);
        }
        self.registry.clear();
        self.token_spans.clear();
        if let Some(tiers) = self.tiers.as_mut() {
            for t in tiers {
                t.reset();
            }
        }
        self.protected.clear();
        self.stream_prec.clear();
        self.quality_debt = 0.0;
        self.route_weight = 0.0;
        self.expert_demand.clear();
        for w in &mut self.workers {
            w.ec_ends.clear();
        }
        self.now = 0.0;
        self.shadow_free = 0.0;
        self.charge_static_memory();
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        anyhow::ensure!(out_tokens >= 1, "need at least one output token");
        let mut res = PromptResult::default();

        // ---- Prefill: numerics + §3.3 mini-batched virtual time. --------
        let rec = self.main.prefill(prompt)?;
        if let Some(s) = self.sep.as_mut() {
            s.prefill(prompt)?;
        }
        let timing: PrefillTiming = simulate_odmoe_prefill(
            &mut self.cluster,
            self.main.cfg(),
            prompt.len(),
            self.cfg.prefill_minibatches,
        );
        res.ttft_ms = timing.ttft_ms;
        self.now = timing.ttft_ms;
        self.shadow_free = timing.ttft_ms;
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }

        // ---- Decode. -----------------------------------------------------
        let decode_start = self.now;
        let mut token = rec.token_out;
        let mut stall = 0.0;
        for _ in 1..out_tokens {
            let span_start = self.now;
            let (next, logits, correct) = self.decode_iteration(token, &mut stall)?;
            self.token_spans.push((span_start, self.now));
            res.correct_per_token.push(correct);
            res.tokens.push(next);
            if collect_logits {
                res.step_logits.push(logits);
            }
            token = next;
        }
        res.decode_ms = self.now - decode_start;
        res.stall_ms = stall;
        self.flush_quality_gauges();
        Ok(res)
    }
}

impl<'rt> OdMoeEngine<'rt> {
    /// One batched decode iteration: every session in `active` advances by
    /// one token. Numerics are per-session exact (KV swapped per session);
    /// virtual time merges the per-layer routes and books **one** load per
    /// distinct expert per layer, so PCIe traffic amortizes across the
    /// batch. With one active session this books exactly the sequence of
    /// resource acquisitions `decode_iteration` would — the `--max-batch 1
    /// == sequential` equivalence the tests pin down, healthy and under
    /// injected failures (both paths share the failover helpers).
    fn decode_iteration_batch(
        &mut self,
        batch: &mut BatchState,
        active: &[usize],
        out: &mut [PromptResult],
    ) -> Result<()> {
        let p = self.cluster.profile.clone();
        let n_layers = self.main.cfg().n_layers;
        let b = active.len();
        let t0 = self.now;
        self.apply_due_failures(t0);
        let shadow_alive = self.cfg.predictor != PredictorMode::Sep || !self.shadow_dead_by(t0);

        // ---- Numerics: shadow + main model for every active session. ----
        // The skip rule acts per session, exactly as in sequential
        // decode (lockstep: see `decode_iteration`); `skips[k][l]` lists
        // the experts session k's layer-l residual stream dropped.
        let skip_on = self.precision.as_ref().is_some_and(|c| c.skip_active());
        let mut skips: Vec<Vec<Vec<usize>>> = Vec::with_capacity(b);
        let mut recs: Vec<StepRecord> = Vec::with_capacity(b);
        let mut align_bytes = 0.0;
        for &s in active {
            let token = batch.slot(s).next_token;
            batch.activate(s, &mut self.main);
            if self.cfg.predictor == PredictorMode::Sep && shadow_alive {
                let sep = &mut self.sep_slots[s];
                sep.begin_token(&self.main, token)?;
                align_bytes += sep.alignment_bytes(&p);
            }
            let rec = if skip_on {
                let ctl = self.precision.as_ref().expect("skip implies a controller");
                let slots = &self.slots;
                let reg = &mut self.registry;
                let debt = &mut self.quality_debt;
                let mut log = vec![Vec::new(); n_layers];
                let rec = {
                    let mut decide = |l: usize, route: &Route| -> Option<usize> {
                        let last = route.experts.len().checked_sub(1)?;
                        if last == 0 {
                            return None; // never drop a layer's only expert
                        }
                        let weight = route.weights[last] as f64;
                        let w = slots.worker_for(l, last);
                        if !ctl.should_skip(w, weight) {
                            return None;
                        }
                        log[l].push(route.experts[last]);
                        reg.counter_add("engine.skipped_experts", 1);
                        *debt += weight; // the whole contribution is lost
                        Some(last)
                    };
                    self.main.decode_step_skipping(token, &mut decide)
                };
                skips.push(log);
                rec
            } else {
                self.main.decode_step(token)
            };
            batch.deactivate(s, &mut self.main);
            let rec = rec?;
            if self.precision.is_some() {
                self.route_weight += rec
                    .routes
                    .iter()
                    .flat_map(|r| &r.weights)
                    .map(|&w| w as f64)
                    .sum::<f64>();
            }
            batch.record_token(s, rec.token_out);
            recs.push(rec);
        }

        // ---- Shadow node: one batched emulation pass for all sessions
        // (late departure ships every session's alignment payload in one
        // message; per-layer time scales by the batch-efficiency factor).
        let mut pred: Vec<Vec<Option<Vec<usize>>>> = vec![vec![None; n_layers]; b];
        let mut pred_avail: Vec<Ms> = vec![f64::INFINITY; n_layers];
        match self.cfg.predictor {
            PredictorMode::Sep if shadow_alive => {
                let cutoff = self.pending_shadow.unwrap_or(f64::INFINITY);
                let delay = if align_bytes == 0.0 {
                    0.0
                } else {
                    p.lan_lat_ms + p.lan_transfer_ms(align_bytes)
                };
                let start = self.shadow_free.max(t0 + delay);
                let t_layer = p.batched_ms(p.t_shadow_layer_ms, b);
                let mut died = false;
                for l in 0..n_layers {
                    let done = start + (l as f64 + 1.0) * t_layer;
                    if done > cutoff {
                        died = true;
                        break;
                    }
                    pred_avail[l] = done + p.lan_lat_ms;
                    for (k, &s) in active.iter().enumerate() {
                        pred[k][l] = Some(self.sep_slots[s].predict(l).experts.clone());
                    }
                    self.cluster.trace.push(
                        EventKind::ShadowCompute,
                        self.cluster.shadow.id,
                        start + l as f64 * t_layer,
                        done,
                        "S",
                    );
                }
                if died {
                    self.apply_shadow_failure();
                } else {
                    self.shadow_free = start + n_layers as f64 * t_layer;
                }
            }
            // Dead shadow: reactive fallback, same as sequential decode.
            PredictorMode::Sep => {}
            PredictorMode::Random => {
                let r = self.random.as_mut().unwrap();
                for l in 0..n_layers {
                    for row in pred.iter_mut() {
                        row[l] = r.predict(l);
                    }
                    pred_avail[l] = t0;
                }
            }
            PredictorMode::None => {}
        }

        // ---- Main/worker pipeline per layer (Fig. 2, batched). ----------
        let group_size = self.slots.group_size();
        let mut m_ready = t0;
        let mut stall_iter: Ms = 0.0;
        let mut correct: Vec<Vec<usize>> = vec![Vec::with_capacity(n_layers); b];
        for l in 0..n_layers {
            // M_l: batched attention + gating for all B tokens.
            let (m_start, m_end) = self
                .cluster
                .main
                .gpu
                .acquire(m_ready, p.batched_ms(p.t_nonexpert_ms, b));
            self.cluster
                .trace
                .push(EventKind::MainCompute, self.cluster.main.id, m_start, m_end, "M");
            let reactive_t = m_end + p.lan_lat_ms;
            let usable = pred_avail[l] <= reactive_t;
            // Reuse-distance protection across the whole batch's
            // predicted routes (no-op while the cache is disabled).
            self.rebuild_protected(l, n_layers, |lf| {
                pred.iter().filter_map(|row| row[lf].as_deref()).collect()
            });

            for (k, c) in correct.iter_mut().enumerate() {
                let predicted = pred[k][l].as_deref().unwrap_or(&[]);
                c.push(correct_count(predicted, &recs[k].routes[l].experts));
            }

            // Route merge: distinct experts across the batch, with how
            // many sessions route to each (their batch-FFN row count).
            // Skipped experts leave each session's effective route first
            // (an expert skipped by every routing session is loaded for
            // none); structurally the seed merge while skipping is off.
            let actual_set = if skip_on {
                let effective: Vec<Vec<usize>> = recs
                    .iter()
                    .enumerate()
                    .map(|(k, r)| {
                        r.routes[l]
                            .experts
                            .iter()
                            .copied()
                            .filter(|e| !skips[k][l].contains(e))
                            .collect()
                    })
                    .collect();
                merge_distinct(effective.iter().map(|v| v.as_slice()))
            } else {
                merge_distinct(recs.iter().map(|r| r.routes[l].experts.as_slice()))
            };
            // Demand tally for the SLO control loop: each merged entry's
            // count is how many sessions routed to that expert here.
            for &(e, cnt) in &actual_set {
                if e >= self.expert_demand.len() {
                    self.expert_demand.resize(e + 1, 0);
                }
                self.expert_demand[e] += cnt as u64;
            }
            // Batched importance of an expert: the strongest gate weight
            // any non-skipping session gives it (reactive loads); debt
            // below instead sums weights, since every routed session's
            // residual stream carries the downgraded contribution.
            let max_weight = |e: usize| -> f64 {
                recs.iter()
                    .enumerate()
                    .filter(|(k, _)| !skip_on || !skips[*k][l].contains(&e))
                    .map(|(_, r)| gate_weight(&r.routes[l], e))
                    .fold(0.0, f64::max)
            };
            let pred_set: Vec<(usize, usize)> = if usable {
                merge_distinct(pred.iter().filter_map(|row| row[l].as_deref()))
            } else {
                Vec::new()
            };

            // Phase 1 — prediction-driven streams: ONE per distinct
            // predicted expert, round-robin over the layer's slots (the
            // slot map routes each slot to its current live worker).
            let mut pred_loaded: Vec<(usize, usize, ChunkedTransfer)> = Vec::new();
            for (i, &(pe, _)) in pred_set.iter().enumerate() {
                let slot = i % group_size;
                let t = self.load_with_failover(
                    l,
                    slot,
                    pred_avail[l],
                    true,
                    Some(pe),
                    prefetch_importance(i),
                );
                pred_loaded.push((pe, slot, t));
            }

            // Phase 2 — gate result: abort mispredicted streams (only the
            // frontier train on a link can be cancelled mid-flight;
            // earlier wasted trains already completed behind it and are
            // simply evicted — see `abort_predicted`; delivered chunks of
            // the frontier train stay booked). At batch 1 this is exactly
            // the sequential mispredict abort.
            let in_actual = |e: usize| actual_set.iter().any(|&(a, _)| a == e);
            for entry in &pred_loaded {
                if in_actual(entry.0) {
                    continue;
                }
                // A mispredicted GPU-hot hit streamed nothing; there is
                // no train to cancel and the expert simply stays hot.
                if self.hot_resident(entry.2.worker, l, entry.0) {
                    continue;
                }
                self.registry.counter_add("engine.aborted_loads", 1);
                self.abort_predicted(&entry.2, reactive_t);
            }

            // Phase 3 — place every distinct actual expert: inherit the
            // confirmed predicted stream, else load reactively on the
            // least-loaded slot. One load serves every session that
            // routed to the expert — the amortization at the heart of
            // batched decode.
            let mut ec_count: Vec<usize> = vec![0; group_size];
            // (expert, rows, slot, stream)
            let mut placed: Vec<(usize, usize, usize, ChunkedTransfer)> = Vec::new();
            let mut pending: Vec<(usize, usize)> = Vec::new(); // (expert, rows)
            for &(ae, cnt) in &actual_set {
                match pred_loaded.iter().find(|entry| entry.0 == ae) {
                    Some(entry) => {
                        ec_count[entry.1] += 1;
                        if !self.hot_resident(entry.2.worker, l, ae) {
                            self.registry.counter_add("engine.expert_loads", 1);
                        }
                        placed.push((ae, cnt, entry.1, entry.2.clone()));
                    }
                    None => pending.push((ae, cnt)),
                }
            }
            for (ae, cnt) in pending {
                let slot = (0..group_size)
                    .min_by_key(|&sl| (ec_count[sl], sl))
                    .expect("group has at least one slot");
                ec_count[slot] += 1;
                // Reactive path: on the gate result. With a usable (but
                // wrong) prediction the link was just cancelled, exactly
                // like the sequential mispredict reload; without one the
                // load also waits for the residency window.
                let t = self.load_with_failover(l, slot, reactive_t, !usable, Some(ae), max_weight(ae));
                if !self.hot_resident(t.worker, l, ae) {
                    self.registry.counter_add("engine.expert_loads", 1);
                }
                placed.push((ae, cnt, slot, t));
            }

            // Embeddings for all B tokens ship to the group after M_l.
            // EC gates on every placed expert's FIRST chunk (== the whole
            // expert at chunk count 1, the seed's gate).
            let expert_ready =
                placed.iter().fold(0.0f64, |m, (_, _, _, t)| m.max(t.first_ready()));
            let embed_arrival =
                self.cluster.lan_send(m_end, p.embed_msg_bytes * b as f64, "embed");
            let ec_earliest = embed_arrival.max(expert_ready);
            stall_iter += (expert_ready - embed_arrival).max(0.0);
            if expert_ready > embed_arrival {
                self.cluster.trace.push(
                    EventKind::Stall,
                    self.cluster.workers[self.slots.worker_for(l, 0)].id,
                    embed_arrival,
                    expert_ready,
                    "stall",
                );
            }

            // EC_l: each distinct expert computes its routed tokens as one
            // batched FFN at its holder's class speed, tile-pipelined
            // behind its stream; a worker hosting several experts runs
            // them back to back (evicting each — cacheless — right
            // after). Slot order matches the sequential EC loop at batch
            // 1; the order is aggregate-neutral otherwise (per-link
            // bookings commute under max). Embed arrival and the return
            // hop honor each holder's LAN attach extra, 0 on uniform
            // clusters — same collapse as sequential decode.
            placed.sort_by_key(|&(_, _, slot, _)| slot);
            let mut out_ready = ec_earliest;
            for (ae, cnt, slot, t) in &placed {
                let (holder, ec_end) = self.compute_with_failover(
                    l,
                    *slot,
                    *ae,
                    t.worker,
                    ec_earliest,
                    embed_arrival,
                    *cnt,
                    &t.chunk_ends,
                    max_weight(*ae),
                );
                // Quality debt of the computed stream (lockstep with the
                // sequential EC loop): every routed, non-skipping
                // session's residual carries the downgraded output, so
                // the charge sums their gate weights.
                if self.precision.is_some() {
                    let prec = self
                        .stream_prec
                        .get(&(holder, l, *ae))
                        .copied()
                        .unwrap_or(Precision::Fp16);
                    if prec.rel_error() > 0.0 {
                        let wsum: f64 = recs
                            .iter()
                            .enumerate()
                            .filter(|(k, _)| !skip_on || !skips[*k][l].contains(ae))
                            .map(|(_, r)| gate_weight(&r.routes[l], *ae))
                            .sum();
                        self.quality_debt += wsum * prec.rel_error();
                    }
                }
                out_ready = out_ready.max(ec_end + self.cluster.lan_extra(holder));
            }

            // Combined expert outputs return to the main node.
            m_ready = self
                .cluster
                .lan_send(out_ready, p.embed_msg_bytes * b as f64, "embed-back");
        }

        // LM head for all B tokens.
        let (_, lm_end) = self
            .cluster
            .main
            .gpu
            .acquire(m_ready, p.batched_ms(p.t_lm_head_ms, b));
        self.now = lm_end;

        for (&s, c) in active.iter().zip(correct) {
            out[s].correct_per_token.push(c);
            // The iteration's I/O stall is shared by the whole batch.
            out[s].stall_ms += stall_iter / b as f64;
        }
        Ok(())
    }
}

impl<'rt> BatchEngine for OdMoeEngine<'rt> {
    fn run_batch(&mut self, sessions: &[(&[u32], usize)]) -> Result<BatchRunResult> {
        anyhow::ensure!(!sessions.is_empty(), "batch needs at least one session");
        if self.cfg.predictor == PredictorMode::Sep {
            while self.sep_slots.len() < sessions.len() {
                let sep = SepPredictor::new(
                    self.main.rt,
                    &self.main.ws,
                    self.cfg.shadow_precision,
                    self.cfg.align,
                )?;
                self.sep_slots.push(sep);
            }
        }

        let mut batch = BatchState::new();
        let mut out: Vec<PromptResult> =
            (0..sessions.len()).map(|_| PromptResult::default()).collect();

        // ---- Prefill: sessions serialize on the shared cluster (each
        // books the §3.3 mini-batched prefill after its predecessor). ----
        for (i, &(prompt, target)) in sessions.iter().enumerate() {
            batch.join(&mut self.main, i, prompt, target)?;
            if self.cfg.predictor == PredictorMode::Sep {
                self.sep_slots[i].reset();
                self.sep_slots[i].prefill(prompt)?;
            }
            let timing: PrefillTiming = simulate_odmoe_prefill(
                &mut self.cluster,
                self.main.cfg(),
                prompt.len(),
                self.cfg.prefill_minibatches,
            );
            out[i].ttft_ms = timing.ttft_ms;
            self.now = timing.ttft_ms;
        }
        self.shadow_free = self.now;
        let decode_start = self.now;
        // Counter snapshots: the registry accumulates since reset, the
        // run result reports this run's deltas (DESIGN.md §7 tallies).
        let loads_before = self.registry.counter("engine.expert_loads");
        let aborts_before = self.registry.counter("engine.aborted_loads");
        let failovers_before = self.registry.counter("engine.failovers");

        // ---- Decode: all sessions step together; the batch shrinks at
        // the token boundary where a session reaches its target. ---------
        let mut decode_tokens = 0u64;
        let mut decode_iterations = 0u64;
        loop {
            let active = batch.active();
            if active.is_empty() {
                break;
            }
            let span_start = self.now;
            self.decode_iteration_batch(&mut batch, &active, &mut out)?;
            self.token_spans.push((span_start, self.now));
            decode_iterations += 1;
            decode_tokens += active.len() as u64;
            for &s in &active {
                if batch.slot(s).done() {
                    out[s].decode_ms = self.now - out[s].ttft_ms;
                }
            }
        }
        for (i, res) in out.iter_mut().enumerate() {
            res.tokens = batch.slot(i).tokens.clone();
        }
        let expert_loads = self.registry.counter("engine.expert_loads") - loads_before;
        if decode_tokens > 0 {
            let lpt = expert_loads as f64 / decode_tokens as f64;
            self.registry.gauge_set("engine.loads_per_token", lpt);
        }
        self.flush_quality_gauges();
        Ok(BatchRunResult {
            sessions: out,
            expert_loads,
            aborted_loads: self.registry.counter("engine.aborted_loads") - aborts_before,
            failovers: self.registry.counter("engine.failovers") - failovers_before,
            decode_tokens,
            decode_iterations,
            decode_span_ms: self.now - decode_start,
            expert_demand: std::mem::take(&mut self.expert_demand),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_spec_parses_worker_and_shadow() {
        assert_eq!(
            FailureSpec::parse("worker3@500").unwrap(),
            FailureSpec::Worker { worker: 3, at_ms: 500.0 }
        );
        assert_eq!(
            FailureSpec::parse("worker0@12.5ms").unwrap(),
            FailureSpec::Worker { worker: 0, at_ms: 12.5 }
        );
        assert_eq!(
            FailureSpec::parse("shadow@800ms").unwrap(),
            FailureSpec::Shadow { at_ms: 800.0 }
        );
        assert_eq!(
            FailureSpec::parse_list("worker1@10, shadow@20,").unwrap(),
            vec![
                FailureSpec::Worker { worker: 1, at_ms: 10.0 },
                FailureSpec::Shadow { at_ms: 20.0 },
            ]
        );
    }

    #[test]
    fn default_config_is_the_seed_behavior() {
        let cfg = OdMoeConfig::default();
        assert_eq!(cfg.chunks, 1, "default = monolithic transfers");
        assert_eq!(cfg.prefetch_depth, 0, "default = strict single-expert residency");
        assert!(cfg.fleet.is_none(), "default = the uniform shared-profile cluster");
        assert!(!cfg.cache.enabled(), "default = cacheless (tiered cache disabled)");
        assert_eq!(
            cfg.precision_policy,
            PrecisionPolicy::Static,
            "default = static deployed-precision transfers (no runtime controller)"
        );
        assert!(!cfg.precision_skip, "default = no expert skipping");
    }

    #[test]
    fn failure_spec_rejects_garbage() {
        assert!(FailureSpec::parse("worker3").is_err(), "missing time");
        assert!(FailureSpec::parse("main@10").is_err(), "main node cannot fail");
        assert!(FailureSpec::parse("worker@10").is_err(), "missing index");
        assert!(FailureSpec::parse("workerx@10").is_err());
        assert!(FailureSpec::parse("worker1@inf").is_err(), "non-finite time");
        assert!(FailureSpec::parse("worker1@-5").is_err(), "negative time");
    }
}
