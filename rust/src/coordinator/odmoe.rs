//! The OD-MoE engine: cacheless on-demand expert loading over distributed
//! edge nodes (paper §3.1–§3.2).
//!
//! Per decode iteration the engine interleaves three concerns exactly as
//! the paper's Fig. 2/4/5 timing diagrams do:
//!
//! 1. **Numerics** — the full-precision main model executes the real AOT
//!    artifacts; the SEP shadow model runs its quantized replica.
//! 2. **Prediction** — the shadow's routes become expert predictions with
//!    availability times `shadow_start + (l+1) * t_shadow_layer`.
//! 3. **Virtual time** — main-node blocks, LAN hops, per-worker expert
//!    loads (PCIe), expert computes and mispredict reloads are booked on
//!    the cluster's resources; each worker holds at most ONE expert at a
//!    time (loaded just-in-time, evicted right after use — the cacheless
//!    property).
//!
//! The engine also implements [`BatchEngine`]: `run_batch` steps several
//! concurrent sessions through each decode iteration together, merging
//! their per-layer routes so each *distinct* expert is loaded once per
//! layer per iteration (DESIGN.md §7). When a layer's distinct experts
//! exceed its group size, a worker runs several experts back to back and
//! the next transfer overlaps the previous compute — residency briefly
//! reaches two experts (current + in-flight); a batch of one preserves
//! strict single-expert residency and reproduces sequential decode
//! bookings exactly.

use anyhow::Result;

use super::batch::{merge_distinct, BatchEngine, BatchRunResult};
use super::prefill::{simulate_odmoe_prefill, PrefillTiming};
use super::schedule::GroupSchedule;
use super::{Engine, PromptResult};
use crate::cluster::{Cluster, HardwareProfile, Ms};
use crate::engine::{BatchState, ModelState, StepRecord};
use crate::metrics::correct_count;
use crate::model::{Precision, WeightStore};
use crate::predictor::baseline::RandomPredictor;
use crate::predictor::{AlignmentConfig, Predictor, SepPredictor};
use crate::runtime::Runtime;
use crate::trace::EventKind;

/// What drives expert prefetching (ablation cases of Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorMode {
    /// SEP shadow model (cases 1–4 depending on alignment config).
    Sep,
    /// Random prefetch at token start, no shadow node (case 5).
    Random,
    /// No prefetch: load after the gate result only (case 6).
    None,
}

/// Engine configuration (defaults = the paper's ten-node testbed).
#[derive(Debug, Clone)]
pub struct OdMoeConfig {
    pub n_workers: usize,
    pub shadow_precision: Precision,
    pub align: AlignmentConfig,
    pub predictor: PredictorMode,
    /// Mini-batches per worker transfer during prefill (Fig. 7; 1 = one
    /// large batch, 0 = adaptive per prompt length).
    pub prefill_minibatches: usize,
    pub profile: HardwareProfile,
}

impl Default for OdMoeConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            shadow_precision: Precision::Int8,
            align: AlignmentConfig::every_iteration(),
            predictor: PredictorMode::Sep,
            prefill_minibatches: 0, // adaptive
            profile: HardwareProfile::rtx3090(),
        }
    }
}

/// Per-worker pipeline state carried across layers/tokens.
#[derive(Debug, Clone, Copy)]
struct WorkerState {
    /// When this worker's previous expert compute ended (loads for its
    /// next layer may only start then — single-expert residency).
    last_ec_end: Ms,
}

/// The OD-MoE serving engine.
pub struct OdMoeEngine<'rt> {
    pub cfg: OdMoeConfig,
    pub cluster: Cluster,
    pub schedule: GroupSchedule,
    main: ModelState<'rt>,
    sep: Option<SepPredictor<'rt>>,
    /// Per-session shadow predictors for batched decode, lazily built on
    /// the first `run_batch` that needs them (same weights/quantization
    /// as `sep`, so a batch of one is numerically identical to
    /// sequential decode). Unused in sequential mode.
    sep_slots: Vec<SepPredictor<'rt>>,
    random: Option<RandomPredictor>,
    workers: Vec<WorkerState>,
    /// Virtual time at which the main node is ready for the next token.
    now: Ms,
    /// When the shadow node finished its previous iteration.
    shadow_free: Ms,
}

impl<'rt> OdMoeEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore, cfg: OdMoeConfig) -> Result<Self> {
        let schedule = GroupSchedule::new(cfg.n_workers, ws.cfg.top_k);
        let cluster = Cluster::new(cfg.profile.clone(), cfg.n_workers);
        let sep = match cfg.predictor {
            PredictorMode::Sep => Some(SepPredictor::new(
                rt,
                &ws,
                cfg.shadow_precision,
                cfg.align,
            )?),
            _ => None,
        };
        let random = match cfg.predictor {
            PredictorMode::Random => {
                Some(RandomPredictor::new(0xACE, ws.cfg.n_experts, ws.cfg.top_k))
            }
            _ => None,
        };
        let main = ModelState::new(rt, ws)?;
        let workers = vec![WorkerState { last_ec_end: 0.0 }; cfg.n_workers];
        let mut engine = Self {
            cfg,
            cluster,
            schedule,
            main,
            sep,
            sep_slots: Vec::new(),
            random,
            workers,
            now: 0.0,
            shadow_free: 0.0,
        };
        engine.charge_static_memory();
        Ok(engine)
    }

    fn charge_static_memory(&mut self) {
        let p = &self.cluster.profile;
        self.cluster.main.alloc(p.nonexpert_bytes as u64);
        if self.sep.is_some() {
            self.cluster.shadow.alloc(p.shadow_model_bytes as u64);
        }
        let act = p.activation_bytes as u64;
        for w in &mut self.cluster.workers {
            w.alloc(act);
        }
    }

    /// Enable Fig. 2-style trace recording.
    pub fn enable_trace(&mut self) {
        self.cluster.trace.enabled = true;
    }

    pub fn recall_correct(&self) -> &ModelState<'rt> {
        &self.main
    }

    /// One decode iteration: returns (output token, logits, per-layer
    /// correct-prediction counts).
    ///
    /// NOTE: `decode_iteration_batch` mirrors this pipeline for N
    /// sessions and must stay in timing lockstep — a batch of one books
    /// the exact same resource sequence (pinned by
    /// `batch_of_one_matches_sequential_odmoe`). Change them together.
    fn decode_iteration(
        &mut self,
        token: u32,
        stall_ms: &mut Ms,
    ) -> Result<(u32, Vec<f32>, Vec<usize>)> {
        let cfg = self.main.cfg().clone();
        let p = self.cluster.profile.clone();
        let n_layers = cfg.n_layers;
        let t0 = self.now;

        // ---- Shadow node: alignment + emulation (numerics first). -------
        let mut pred_routes: Vec<Option<Vec<usize>>> = vec![None; n_layers];
        let mut pred_avail: Vec<Ms> = vec![f64::INFINITY; n_layers];
        match self.cfg.predictor {
            PredictorMode::Sep => {
                let sep = self.sep.as_mut().unwrap();
                sep.begin_token(&self.main, token)?;
                // Late departure (Fig. 5): alignment payload must reach the
                // shadow node before S_0 starts.
                let align_delay = sep.alignment_delay_ms(&p);
                let start = self.shadow_free.max(t0 + align_delay);
                for l in 0..n_layers {
                    let done = start + (l as f64 + 1.0) * p.t_shadow_layer_ms;
                    pred_avail[l] = done + p.lan_lat_ms; // notify worker
                    pred_routes[l] = Some(sep.predict(l).experts.clone());
                    self.cluster.trace.push(
                        EventKind::ShadowCompute,
                        self.cluster.shadow.id,
                        start + l as f64 * p.t_shadow_layer_ms,
                        done,
                        "S",
                    );
                }
                self.shadow_free = start + n_layers as f64 * p.t_shadow_layer_ms;
            }
            PredictorMode::Random => {
                let r = self.random.as_mut().unwrap();
                for l in 0..n_layers {
                    pred_routes[l] = r.predict(l);
                    pred_avail[l] = t0;
                }
            }
            PredictorMode::None => {}
        }

        // ---- Main model numerics (routes + token are ground truth). -----
        let rec = self.main.decode_step(token)?;

        // ---- Virtual-time pipeline over main + workers (Fig. 2). --------
        let mut m_ready = t0; // when the main node may start M_l
        let mut correct = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            // M_l: attention + gating on the main node.
            let (m_start, m_end) =
                self.cluster.main.gpu.acquire(m_ready, p.t_nonexpert_ms);
            self.cluster
                .trace
                .push(EventKind::MainCompute, self.cluster.main.id, m_start, m_end, "M");

            let actual = &rec.routes[l];
            let predicted = pred_routes[l].as_deref().unwrap_or(&[]);
            correct.push(correct_count(predicted, &actual.experts));

            // Expert placement: slot j of the group takes predicted[j]
            // (or the actual expert when prediction is late/absent/wrong).
            let group = self.schedule.group_of(l);
            let mut expert_ready: Ms = 0.0;
            for slot in 0..self.schedule.group_size {
                let w = self.schedule.worker_for(l, slot);
                let ws = self.workers[w];
                let predicted_e = predicted.get(slot).copied();
                let actual_e = actual.experts[slot];
                // The prediction-driven load can begin once the prediction
                // reached the worker AND its previous expert was evicted.
                // The reactive (gate-result-driven) path starts at M_l end.
                let reactive_t = m_end + p.lan_lat_ms;
                let ready = match predicted_e {
                    Some(pe) if pred_avail[l] <= reactive_t => {
                        let start_at = pred_avail[l].max(ws.last_ec_end);
                        let (_, load_done) =
                            self.cluster.expert_load(w, start_at, p.expert_bytes);
                        self.cluster.workers[w].alloc(p.expert_bytes as u64);
                        if actual.experts.contains(&pe) {
                            load_done
                        } else {
                            // Mispredict: abort any in-flight transfer the
                            // moment the gate disagrees, evict, and reload
                            // the correct expert.
                            self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                            self.cluster.workers[w].pcie.preempt(reactive_t);
                            let (_, reload_done) =
                                self.cluster.expert_load(w, reactive_t, p.expert_bytes);
                            self.cluster.workers[w].alloc(p.expert_bytes as u64);
                            reload_done
                        }
                    }
                    _ => {
                        // No usable prediction: load the actual expert on
                        // the gate result (conventional offloading path).
                        let start_at = reactive_t.max(ws.last_ec_end);
                        let (_, load_done) =
                            self.cluster.expert_load(w, start_at, p.expert_bytes);
                        self.cluster.workers[w].alloc(p.expert_bytes as u64);
                        load_done
                    }
                };
                let _ = actual_e;
                expert_ready = expert_ready.max(ready);
            }

            // Embedding ships to the group after M_l.
            let embed_arrival = self.cluster.lan_send(m_end, p.embed_msg_bytes, "embed");
            let ec_earliest = embed_arrival.max(expert_ready);
            *stall_ms += (expert_ready - embed_arrival).max(0.0);
            if expert_ready > embed_arrival {
                self.cluster.trace.push(
                    EventKind::Stall,
                    self.cluster.workers[self.schedule.worker_for(l, 0)].id,
                    embed_arrival,
                    expert_ready,
                    "stall",
                );
            }

            // EC_l on both devices of the group in parallel.
            let mut ec_end_max = ec_earliest;
            for slot in 0..self.schedule.group_size {
                let w = self.schedule.worker_for(l, slot);
                let ec_dur = p.t_expert_gpu_ms * self.cluster.workers[w].gpu_slowdown;
                let (ec_start, ec_end) =
                    self.cluster.workers[w].gpu.acquire(ec_earliest, ec_dur);
                self.cluster.trace.push(
                    EventKind::ExpertCompute,
                    self.cluster.workers[w].id,
                    ec_start,
                    ec_end,
                    "EC",
                );
                // Cacheless: evict immediately after compute.
                self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                self.workers[w].last_ec_end = ec_end;
                ec_end_max = ec_end_max.max(ec_end);
            }
            let _ = group;

            // Combined expert output returns to the main node.
            m_ready = self.cluster.lan_send(ec_end_max, p.embed_msg_bytes, "embed-back");
        }

        // LM head on the main node.
        let (_, lm_end) = self.cluster.main.gpu.acquire(m_ready, p.t_lm_head_ms);
        self.now = lm_end;
        Ok((rec.token_out, rec.logits, correct))
    }
}

impl<'rt> Engine for OdMoeEngine<'rt> {
    fn name(&self) -> String {
        let mode = match self.cfg.predictor {
            PredictorMode::Sep => format!(
                "sep-{}-T{}KV{}",
                self.cfg.shadow_precision.label(),
                fmt_period(self.cfg.align.token_period),
                fmt_period(self.cfg.align.kv_period)
            ),
            PredictorMode::Random => "random-prefetch".into(),
            PredictorMode::None => "no-prefetch".into(),
        };
        format!("od-moe({mode})")
    }

    fn reset(&mut self) -> Result<()> {
        self.main.reset();
        if let Some(s) = self.sep.as_mut() {
            s.reset();
        }
        self.cluster.reset();
        for w in &mut self.workers {
            w.last_ec_end = 0.0;
        }
        self.now = 0.0;
        self.shadow_free = 0.0;
        self.charge_static_memory();
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        anyhow::ensure!(out_tokens >= 1, "need at least one output token");
        let mut res = PromptResult::default();

        // ---- Prefill: numerics + §3.3 mini-batched virtual time. --------
        let rec = self.main.prefill(prompt)?;
        if let Some(s) = self.sep.as_mut() {
            s.prefill(prompt)?;
        }
        let timing: PrefillTiming = simulate_odmoe_prefill(
            &mut self.cluster,
            self.main.cfg(),
            prompt.len(),
            self.cfg.prefill_minibatches,
        );
        res.ttft_ms = timing.ttft_ms;
        self.now = timing.ttft_ms;
        self.shadow_free = timing.ttft_ms;
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }

        // ---- Decode. -----------------------------------------------------
        let decode_start = self.now;
        let mut token = rec.token_out;
        let mut stall = 0.0;
        for _ in 1..out_tokens {
            let (next, logits, correct) = self.decode_iteration(token, &mut stall)?;
            res.correct_per_token.push(correct);
            res.tokens.push(next);
            if collect_logits {
                res.step_logits.push(logits);
            }
            token = next;
        }
        res.decode_ms = self.now - decode_start;
        res.stall_ms = stall;
        Ok(res)
    }
}

/// Load/abort tallies one batched run accumulates (DESIGN.md §7).
#[derive(Debug, Default)]
struct BatchCounters {
    expert_loads: u64,
    aborted_loads: u64,
}

impl<'rt> OdMoeEngine<'rt> {
    /// One batched decode iteration: every session in `active` advances by
    /// one token. Numerics are per-session exact (KV swapped per session);
    /// virtual time merges the per-layer routes and books **one** load per
    /// distinct expert per layer, so PCIe traffic amortizes across the
    /// batch. With one active session this books exactly the sequence of
    /// resource acquisitions `decode_iteration` would — the `--max-batch 1
    /// == sequential` equivalence the tests pin down.
    fn decode_iteration_batch(
        &mut self,
        batch: &mut BatchState,
        active: &[usize],
        counters: &mut BatchCounters,
        out: &mut [PromptResult],
    ) -> Result<()> {
        let p = self.cluster.profile.clone();
        let n_layers = self.main.cfg().n_layers;
        let b = active.len();
        let t0 = self.now;

        // ---- Numerics: shadow + main model for every active session. ----
        let mut recs: Vec<StepRecord> = Vec::with_capacity(b);
        let mut align_bytes = 0.0;
        for &s in active {
            let token = batch.slot(s).next_token;
            batch.activate(s, &mut self.main);
            if self.cfg.predictor == PredictorMode::Sep {
                let sep = &mut self.sep_slots[s];
                sep.begin_token(&self.main, token)?;
                align_bytes += sep.alignment_bytes(&p);
            }
            let rec = self.main.decode_step(token);
            batch.deactivate(s, &mut self.main);
            let rec = rec?;
            batch.record_token(s, rec.token_out);
            recs.push(rec);
        }

        // ---- Shadow node: one batched emulation pass for all sessions
        // (late departure ships every session's alignment payload in one
        // message; per-layer time scales by the batch-efficiency factor).
        let mut pred: Vec<Vec<Option<Vec<usize>>>> = vec![vec![None; n_layers]; b];
        let mut pred_avail: Vec<Ms> = vec![f64::INFINITY; n_layers];
        match self.cfg.predictor {
            PredictorMode::Sep => {
                let delay = if align_bytes == 0.0 {
                    0.0
                } else {
                    p.lan_lat_ms + p.lan_transfer_ms(align_bytes)
                };
                let start = self.shadow_free.max(t0 + delay);
                let t_layer = p.batched_ms(p.t_shadow_layer_ms, b);
                for l in 0..n_layers {
                    let done = start + (l as f64 + 1.0) * t_layer;
                    pred_avail[l] = done + p.lan_lat_ms;
                    for (k, &s) in active.iter().enumerate() {
                        pred[k][l] = Some(self.sep_slots[s].predict(l).experts.clone());
                    }
                    self.cluster.trace.push(
                        EventKind::ShadowCompute,
                        self.cluster.shadow.id,
                        start + l as f64 * t_layer,
                        done,
                        "S",
                    );
                }
                self.shadow_free = start + n_layers as f64 * t_layer;
            }
            PredictorMode::Random => {
                let r = self.random.as_mut().unwrap();
                for l in 0..n_layers {
                    for row in pred.iter_mut() {
                        row[l] = r.predict(l);
                    }
                    pred_avail[l] = t0;
                }
            }
            PredictorMode::None => {}
        }

        // ---- Main/worker pipeline per layer (Fig. 2, batched). ----------
        let group_size = self.schedule.group_size;
        let mut m_ready = t0;
        let mut stall_iter: Ms = 0.0;
        let mut correct: Vec<Vec<usize>> = vec![Vec::with_capacity(n_layers); b];
        for l in 0..n_layers {
            let group_start = self.schedule.worker_for(l, 0);
            // M_l: batched attention + gating for all B tokens.
            let (m_start, m_end) = self
                .cluster
                .main
                .gpu
                .acquire(m_ready, p.batched_ms(p.t_nonexpert_ms, b));
            self.cluster
                .trace
                .push(EventKind::MainCompute, self.cluster.main.id, m_start, m_end, "M");
            let reactive_t = m_end + p.lan_lat_ms;
            let usable = pred_avail[l] <= reactive_t;

            for (k, c) in correct.iter_mut().enumerate() {
                let predicted = pred[k][l].as_deref().unwrap_or(&[]);
                c.push(correct_count(predicted, &recs[k].routes[l].experts));
            }

            // Route merge: distinct experts across the batch, with how
            // many sessions route to each (their batch-FFN row count).
            let actual_set = merge_distinct(recs.iter().map(|r| r.routes[l].experts.as_slice()));
            let pred_set: Vec<(usize, usize)> = if usable {
                merge_distinct(pred.iter().filter_map(|row| row[l].as_deref()))
            } else {
                Vec::new()
            };

            // Phase 1 — prediction-driven loads: ONE per distinct predicted
            // expert, round-robin over the layer's group workers.
            // (expert, worker, done, link free_at before this booking)
            let mut pred_loaded: Vec<(usize, usize, Ms, Ms)> = Vec::new();
            let mut last_booking: Vec<Option<usize>> = vec![None; group_size];
            for (i, &(pe, _)) in pred_set.iter().enumerate() {
                let slot = i % group_size;
                let w = group_start + slot;
                let start_at = pred_avail[l].max(self.workers[w].last_ec_end);
                let free_before = self.cluster.workers[w].pcie.free_at();
                let (_, done) = self.cluster.expert_load(w, start_at, p.expert_bytes);
                self.cluster.workers[w].alloc(p.expert_bytes as u64);
                pred_loaded.push((pe, w, done, free_before));
                last_booking[slot] = Some(i);
            }

            // Phase 2 — gate result: abort mispredicted transfers. Only
            // the last in-flight transfer on a link can be cancelled
            // mid-flight; earlier wasted transfers already completed
            // behind it and are simply evicted. The cancellation never
            // rewinds the link below work queued ahead of the aborted
            // transfer (`free_before`), so confirmed loads keep their
            // booked span; at batch 1 the pipeline guarantees
            // `free_before < reactive_t` and this is exactly the
            // sequential `preempt(reactive_t)`.
            let in_actual = |e: usize| actual_set.iter().any(|&(a, _)| a == e);
            for (i, &(pe, w, _, free_before)) in pred_loaded.iter().enumerate() {
                if in_actual(pe) {
                    continue;
                }
                counters.aborted_loads += 1;
                self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                if last_booking[i % group_size] == Some(i) {
                    self.cluster.workers[w].pcie.preempt(reactive_t.max(free_before));
                }
            }

            // Phase 3 — place every distinct actual expert: inherit the
            // confirmed predicted load, else load reactively on the
            // least-loaded group worker. One load serves every session
            // that routed to the expert — the amortization at the heart
            // of batched decode.
            let mut ec_count: Vec<usize> = vec![0; group_size];
            let mut placed: Vec<(usize, usize, Ms)> = Vec::new(); // (count, worker, ready)
            let mut pending: Vec<(usize, usize)> = Vec::new();
            for &(ae, cnt) in &actual_set {
                match pred_loaded.iter().find(|&&(pe, _, _, _)| pe == ae) {
                    Some(&(_, w, done, _)) => {
                        ec_count[w - group_start] += 1;
                        counters.expert_loads += 1;
                        placed.push((cnt, w, done));
                    }
                    None => pending.push((ae, cnt)),
                }
            }
            for (_, cnt) in pending {
                let slot = (0..group_size)
                    .min_by_key(|&sl| (ec_count[sl], sl))
                    .expect("group has at least one worker");
                let w = group_start + slot;
                ec_count[slot] += 1;
                // Reactive path: on the gate result. With a usable (but
                // wrong) prediction the link was just preempted, exactly
                // like the sequential mispredict reload; without one the
                // load also waits for the previous expert's eviction.
                let start_at = if usable {
                    reactive_t
                } else {
                    reactive_t.max(self.workers[w].last_ec_end)
                };
                let (_, done) = self.cluster.expert_load(w, start_at, p.expert_bytes);
                self.cluster.workers[w].alloc(p.expert_bytes as u64);
                counters.expert_loads += 1;
                placed.push((cnt, w, done));
            }

            // Embeddings for all B tokens ship to the group after M_l.
            let expert_ready = placed.iter().fold(0.0f64, |m, &(_, _, r)| m.max(r));
            let embed_arrival =
                self.cluster.lan_send(m_end, p.embed_msg_bytes * b as f64, "embed");
            let ec_earliest = embed_arrival.max(expert_ready);
            stall_iter += (expert_ready - embed_arrival).max(0.0);
            if expert_ready > embed_arrival {
                self.cluster.trace.push(
                    EventKind::Stall,
                    self.cluster.workers[group_start].id,
                    embed_arrival,
                    expert_ready,
                    "stall",
                );
            }

            // EC_l: each distinct expert computes its routed tokens as one
            // batched FFN; a worker hosting several experts runs them
            // back to back (evicting each — cacheless — right after).
            let mut ec_end_max = ec_earliest;
            for &(cnt, w, _) in &placed {
                let ec_dur = p.expert_batch_ms(cnt) * self.cluster.workers[w].gpu_slowdown;
                let (ec_start, ec_end) = self.cluster.workers[w].gpu.acquire(ec_earliest, ec_dur);
                self.cluster.trace.push(
                    EventKind::ExpertCompute,
                    self.cluster.workers[w].id,
                    ec_start,
                    ec_end,
                    "EC",
                );
                self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                self.workers[w].last_ec_end = self.workers[w].last_ec_end.max(ec_end);
                ec_end_max = ec_end_max.max(ec_end);
            }

            // Combined expert outputs return to the main node.
            m_ready = self
                .cluster
                .lan_send(ec_end_max, p.embed_msg_bytes * b as f64, "embed-back");
        }

        // LM head for all B tokens.
        let (_, lm_end) = self
            .cluster
            .main
            .gpu
            .acquire(m_ready, p.batched_ms(p.t_lm_head_ms, b));
        self.now = lm_end;

        for (&s, c) in active.iter().zip(correct) {
            out[s].correct_per_token.push(c);
            // The iteration's I/O stall is shared by the whole batch.
            out[s].stall_ms += stall_iter / b as f64;
        }
        Ok(())
    }
}

impl<'rt> BatchEngine for OdMoeEngine<'rt> {
    fn run_batch(&mut self, sessions: &[(&[u32], usize)]) -> Result<BatchRunResult> {
        anyhow::ensure!(!sessions.is_empty(), "batch needs at least one session");
        if self.cfg.predictor == PredictorMode::Sep {
            while self.sep_slots.len() < sessions.len() {
                let sep = SepPredictor::new(
                    self.main.rt,
                    &self.main.ws,
                    self.cfg.shadow_precision,
                    self.cfg.align,
                )?;
                self.sep_slots.push(sep);
            }
        }

        let mut batch = BatchState::new();
        let mut out: Vec<PromptResult> =
            (0..sessions.len()).map(|_| PromptResult::default()).collect();

        // ---- Prefill: sessions serialize on the shared cluster (each
        // books the §3.3 mini-batched prefill after its predecessor). ----
        for (i, &(prompt, target)) in sessions.iter().enumerate() {
            batch.join(&mut self.main, i, prompt, target)?;
            if self.cfg.predictor == PredictorMode::Sep {
                self.sep_slots[i].reset();
                self.sep_slots[i].prefill(prompt)?;
            }
            let timing: PrefillTiming = simulate_odmoe_prefill(
                &mut self.cluster,
                self.main.cfg(),
                prompt.len(),
                self.cfg.prefill_minibatches,
            );
            out[i].ttft_ms = timing.ttft_ms;
            self.now = timing.ttft_ms;
        }
        self.shadow_free = self.now;
        let decode_start = self.now;

        // ---- Decode: all sessions step together; the batch shrinks at
        // the token boundary where a session reaches its target. ---------
        let mut counters = BatchCounters::default();
        let mut decode_tokens = 0u64;
        let mut decode_iterations = 0u64;
        loop {
            let active = batch.active();
            if active.is_empty() {
                break;
            }
            self.decode_iteration_batch(&mut batch, &active, &mut counters, &mut out)?;
            decode_iterations += 1;
            decode_tokens += active.len() as u64;
            for &s in &active {
                if batch.slot(s).done() {
                    out[s].decode_ms = self.now - out[s].ttft_ms;
                }
            }
        }
        for (i, res) in out.iter_mut().enumerate() {
            res.tokens = batch.slot(i).tokens.clone();
        }
        Ok(BatchRunResult {
            sessions: out,
            expert_loads: counters.expert_loads,
            aborted_loads: counters.aborted_loads,
            decode_tokens,
            decode_iterations,
            decode_span_ms: self.now - decode_start,
        })
    }
}

fn fmt_period(p: usize) -> String {
    if p == usize::MAX {
        "∞".into()
    } else {
        p.to_string()
    }
}
