//! The OD-MoE engine: cacheless on-demand expert loading over distributed
//! edge nodes (paper §3.1–§3.2).
//!
//! Per decode iteration the engine interleaves three concerns exactly as
//! the paper's Fig. 2/4/5 timing diagrams do:
//!
//! 1. **Numerics** — the full-precision main model executes the real AOT
//!    artifacts; the SEP shadow model runs its quantized replica.
//! 2. **Prediction** — the shadow's routes become expert predictions with
//!    availability times `shadow_start + (l+1) * t_shadow_layer`.
//! 3. **Virtual time** — main-node blocks, LAN hops, per-worker expert
//!    loads (PCIe), expert computes and mispredict reloads are booked on
//!    the cluster's resources; each worker holds at most ONE expert at a
//!    time (loaded just-in-time, evicted right after use — the cacheless
//!    property).

use anyhow::Result;

use super::prefill::{simulate_odmoe_prefill, PrefillTiming};
use super::schedule::GroupSchedule;
use super::{Engine, PromptResult};
use crate::cluster::{Cluster, HardwareProfile, Ms};
use crate::engine::ModelState;
use crate::metrics::correct_count;
use crate::model::{Precision, WeightStore};
use crate::predictor::baseline::RandomPredictor;
use crate::predictor::{AlignmentConfig, Predictor, SepPredictor};
use crate::runtime::Runtime;
use crate::trace::EventKind;

/// What drives expert prefetching (ablation cases of Fig. 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorMode {
    /// SEP shadow model (cases 1–4 depending on alignment config).
    Sep,
    /// Random prefetch at token start, no shadow node (case 5).
    Random,
    /// No prefetch: load after the gate result only (case 6).
    None,
}

/// Engine configuration (defaults = the paper's ten-node testbed).
#[derive(Debug, Clone)]
pub struct OdMoeConfig {
    pub n_workers: usize,
    pub shadow_precision: Precision,
    pub align: AlignmentConfig,
    pub predictor: PredictorMode,
    /// Mini-batches per worker transfer during prefill (Fig. 7; 1 = one
    /// large batch, 0 = adaptive per prompt length).
    pub prefill_minibatches: usize,
    pub profile: HardwareProfile,
}

impl Default for OdMoeConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            shadow_precision: Precision::Int8,
            align: AlignmentConfig::every_iteration(),
            predictor: PredictorMode::Sep,
            prefill_minibatches: 0, // adaptive
            profile: HardwareProfile::rtx3090(),
        }
    }
}

/// Per-worker pipeline state carried across layers/tokens.
#[derive(Debug, Clone, Copy)]
struct WorkerState {
    /// When this worker's previous expert compute ended (loads for its
    /// next layer may only start then — single-expert residency).
    last_ec_end: Ms,
}

/// The OD-MoE serving engine.
pub struct OdMoeEngine<'rt> {
    pub cfg: OdMoeConfig,
    pub cluster: Cluster,
    pub schedule: GroupSchedule,
    main: ModelState<'rt>,
    sep: Option<SepPredictor<'rt>>,
    random: Option<RandomPredictor>,
    workers: Vec<WorkerState>,
    /// Virtual time at which the main node is ready for the next token.
    now: Ms,
    /// When the shadow node finished its previous iteration.
    shadow_free: Ms,
}

impl<'rt> OdMoeEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore, cfg: OdMoeConfig) -> Result<Self> {
        let schedule = GroupSchedule::new(cfg.n_workers, ws.cfg.top_k);
        let cluster = Cluster::new(cfg.profile.clone(), cfg.n_workers);
        let sep = match cfg.predictor {
            PredictorMode::Sep => Some(SepPredictor::new(
                rt,
                &ws,
                cfg.shadow_precision,
                cfg.align,
            )?),
            _ => None,
        };
        let random = match cfg.predictor {
            PredictorMode::Random => {
                Some(RandomPredictor::new(0xACE, ws.cfg.n_experts, ws.cfg.top_k))
            }
            _ => None,
        };
        let main = ModelState::new(rt, ws)?;
        let workers = vec![WorkerState { last_ec_end: 0.0 }; cfg.n_workers];
        let mut engine = Self {
            cfg,
            cluster,
            schedule,
            main,
            sep,
            random,
            workers,
            now: 0.0,
            shadow_free: 0.0,
        };
        engine.charge_static_memory();
        Ok(engine)
    }

    fn charge_static_memory(&mut self) {
        let p = &self.cluster.profile;
        self.cluster.main.alloc(p.nonexpert_bytes as u64);
        if self.sep.is_some() {
            self.cluster.shadow.alloc(p.shadow_model_bytes as u64);
        }
        let act = p.activation_bytes as u64;
        for w in &mut self.cluster.workers {
            w.alloc(act);
        }
    }

    /// Enable Fig. 2-style trace recording.
    pub fn enable_trace(&mut self) {
        self.cluster.trace.enabled = true;
    }

    pub fn recall_correct(&self) -> &ModelState<'rt> {
        &self.main
    }

    /// One decode iteration: returns (output token, logits, per-layer
    /// correct-prediction counts).
    fn decode_iteration(
        &mut self,
        token: u32,
        stall_ms: &mut Ms,
    ) -> Result<(u32, Vec<f32>, Vec<usize>)> {
        let cfg = self.main.cfg().clone();
        let p = self.cluster.profile.clone();
        let n_layers = cfg.n_layers;
        let t0 = self.now;

        // ---- Shadow node: alignment + emulation (numerics first). -------
        let mut pred_routes: Vec<Option<Vec<usize>>> = vec![None; n_layers];
        let mut pred_avail: Vec<Ms> = vec![f64::INFINITY; n_layers];
        match self.cfg.predictor {
            PredictorMode::Sep => {
                let sep = self.sep.as_mut().unwrap();
                sep.begin_token(&self.main, token)?;
                // Late departure (Fig. 5): alignment payload must reach the
                // shadow node before S_0 starts.
                let align_delay = sep.alignment_delay_ms(&p);
                let start = self.shadow_free.max(t0 + align_delay);
                for l in 0..n_layers {
                    let done = start + (l as f64 + 1.0) * p.t_shadow_layer_ms;
                    pred_avail[l] = done + p.lan_lat_ms; // notify worker
                    pred_routes[l] = Some(sep.predict(l).experts.clone());
                    self.cluster.trace.push(
                        EventKind::ShadowCompute,
                        self.cluster.shadow.id,
                        start + l as f64 * p.t_shadow_layer_ms,
                        done,
                        "S",
                    );
                }
                self.shadow_free = start + n_layers as f64 * p.t_shadow_layer_ms;
            }
            PredictorMode::Random => {
                let r = self.random.as_mut().unwrap();
                for l in 0..n_layers {
                    pred_routes[l] = r.predict(l);
                    pred_avail[l] = t0;
                }
            }
            PredictorMode::None => {}
        }

        // ---- Main model numerics (routes + token are ground truth). -----
        let rec = self.main.decode_step(token)?;

        // ---- Virtual-time pipeline over main + workers (Fig. 2). --------
        let mut m_ready = t0; // when the main node may start M_l
        let mut correct = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            // M_l: attention + gating on the main node.
            let (m_start, m_end) =
                self.cluster.main.gpu.acquire(m_ready, p.t_nonexpert_ms);
            self.cluster
                .trace
                .push(EventKind::MainCompute, self.cluster.main.id, m_start, m_end, "M");

            let actual = &rec.routes[l];
            let predicted = pred_routes[l].as_deref().unwrap_or(&[]);
            correct.push(correct_count(predicted, &actual.experts));

            // Expert placement: slot j of the group takes predicted[j]
            // (or the actual expert when prediction is late/absent/wrong).
            let group = self.schedule.group_of(l);
            let mut expert_ready: Ms = 0.0;
            for slot in 0..self.schedule.group_size {
                let w = self.schedule.worker_for(l, slot);
                let ws = self.workers[w];
                let predicted_e = predicted.get(slot).copied();
                let actual_e = actual.experts[slot];
                // The prediction-driven load can begin once the prediction
                // reached the worker AND its previous expert was evicted.
                // The reactive (gate-result-driven) path starts at M_l end.
                let reactive_t = m_end + p.lan_lat_ms;
                let ready = match predicted_e {
                    Some(pe) if pred_avail[l] <= reactive_t => {
                        let start_at = pred_avail[l].max(ws.last_ec_end);
                        let (_, load_done) =
                            self.cluster.expert_load(w, start_at, p.expert_bytes);
                        self.cluster.workers[w].alloc(p.expert_bytes as u64);
                        if actual.experts.contains(&pe) {
                            load_done
                        } else {
                            // Mispredict: abort any in-flight transfer the
                            // moment the gate disagrees, evict, and reload
                            // the correct expert.
                            self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                            self.cluster.workers[w].pcie.preempt(reactive_t);
                            let (_, reload_done) =
                                self.cluster.expert_load(w, reactive_t, p.expert_bytes);
                            self.cluster.workers[w].alloc(p.expert_bytes as u64);
                            reload_done
                        }
                    }
                    _ => {
                        // No usable prediction: load the actual expert on
                        // the gate result (conventional offloading path).
                        let start_at = reactive_t.max(ws.last_ec_end);
                        let (_, load_done) =
                            self.cluster.expert_load(w, start_at, p.expert_bytes);
                        self.cluster.workers[w].alloc(p.expert_bytes as u64);
                        load_done
                    }
                };
                let _ = actual_e;
                expert_ready = expert_ready.max(ready);
            }

            // Embedding ships to the group after M_l.
            let embed_arrival = self.cluster.lan_send(m_end, p.embed_msg_bytes, "embed");
            let ec_earliest = embed_arrival.max(expert_ready);
            *stall_ms += (expert_ready - embed_arrival).max(0.0);
            if expert_ready > embed_arrival {
                self.cluster.trace.push(
                    EventKind::Stall,
                    self.cluster.workers[self.schedule.worker_for(l, 0)].id,
                    embed_arrival,
                    expert_ready,
                    "stall",
                );
            }

            // EC_l on both devices of the group in parallel.
            let mut ec_end_max = ec_earliest;
            for slot in 0..self.schedule.group_size {
                let w = self.schedule.worker_for(l, slot);
                let ec_dur = p.t_expert_gpu_ms * self.cluster.workers[w].gpu_slowdown;
                let (ec_start, ec_end) =
                    self.cluster.workers[w].gpu.acquire(ec_earliest, ec_dur);
                self.cluster.trace.push(
                    EventKind::ExpertCompute,
                    self.cluster.workers[w].id,
                    ec_start,
                    ec_end,
                    "EC",
                );
                // Cacheless: evict immediately after compute.
                self.cluster.workers[w].dealloc(p.expert_bytes as u64);
                self.workers[w].last_ec_end = ec_end;
                ec_end_max = ec_end_max.max(ec_end);
            }
            let _ = group;

            // Combined expert output returns to the main node.
            m_ready = self.cluster.lan_send(ec_end_max, p.embed_msg_bytes, "embed-back");
        }

        // LM head on the main node.
        let (_, lm_end) = self.cluster.main.gpu.acquire(m_ready, p.t_lm_head_ms);
        self.now = lm_end;
        Ok((rec.token_out, rec.logits, correct))
    }
}

impl<'rt> Engine for OdMoeEngine<'rt> {
    fn name(&self) -> String {
        let mode = match self.cfg.predictor {
            PredictorMode::Sep => format!(
                "sep-{}-T{}KV{}",
                self.cfg.shadow_precision.label(),
                fmt_period(self.cfg.align.token_period),
                fmt_period(self.cfg.align.kv_period)
            ),
            PredictorMode::Random => "random-prefetch".into(),
            PredictorMode::None => "no-prefetch".into(),
        };
        format!("od-moe({mode})")
    }

    fn reset(&mut self) -> Result<()> {
        self.main.reset();
        if let Some(s) = self.sep.as_mut() {
            s.reset();
        }
        self.cluster.reset();
        for w in &mut self.workers {
            w.last_ec_end = 0.0;
        }
        self.now = 0.0;
        self.shadow_free = 0.0;
        self.charge_static_memory();
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        anyhow::ensure!(out_tokens >= 1, "need at least one output token");
        let mut res = PromptResult::default();

        // ---- Prefill: numerics + §3.3 mini-batched virtual time. --------
        let rec = self.main.prefill(prompt)?;
        if let Some(s) = self.sep.as_mut() {
            s.prefill(prompt)?;
        }
        let timing: PrefillTiming = simulate_odmoe_prefill(
            &mut self.cluster,
            self.main.cfg(),
            prompt.len(),
            self.cfg.prefill_minibatches,
        );
        res.ttft_ms = timing.ttft_ms;
        self.now = timing.ttft_ms;
        self.shadow_free = timing.ttft_ms;
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }

        // ---- Decode. -----------------------------------------------------
        let decode_start = self.now;
        let mut token = rec.token_out;
        let mut stall = 0.0;
        for _ in 1..out_tokens {
            let (next, logits, correct) = self.decode_iteration(token, &mut stall)?;
            res.correct_per_token.push(correct);
            res.tokens.push(next);
            if collect_logits {
                res.step_logits.push(logits);
            }
            token = next;
        }
        res.decode_ms = self.now - decode_start;
        res.stall_ms = stall;
        Ok(res)
    }
}

fn fmt_period(p: usize) -> String {
    if p == usize::MAX {
        "∞".into()
    } else {
        p.to_string()
    }
}
