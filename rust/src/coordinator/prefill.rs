//! OD-MoE prefill virtual-time model (paper §3.3, Fig. 7).
//!
//! During prefill every expert of every layer is needed (long prompts
//! activate all 8 with ~99.8% probability), so prediction is pointless:
//! each of the 8 workers loads one expert per layer while computing, and
//! the main node streams batched embeddings to workers in `B` mini-batches
//! so LAN transfer pipelines with expert compute (Fig. 7b) instead of
//! serializing before it (Fig. 7a).

use crate::cluster::{Cluster, Ms};
use crate::model::ModelConfig;

/// Prefill timing summary.
#[derive(Debug, Clone)]
pub struct PrefillTiming {
    pub ttft_ms: Ms,
    /// Total worker idle time spent waiting on LAN transfers (the
    /// quantity mini-batching shrinks).
    pub worker_wait_ms: Ms,
}

/// Pick a mini-batch count for a prompt of `t` tokens: roughly one chunk
/// per 8 tokens of per-worker traffic, capped at 4 (Fig. 7's sweep shows
/// per-message latency dominating beyond that).
pub fn adaptive_minibatches(cfg: &ModelConfig, t: usize, n_workers: usize) -> usize {
    let tokens_per_worker = (t * cfg.top_k).div_ceil(n_workers);
    (tokens_per_worker / 8).clamp(1, 4)
}

/// Simulate OD-MoE's prefill over `t` prompt tokens with `minibatches`
/// chunks per worker transfer (0 = adaptive). Returns TTFT.
pub fn simulate_odmoe_prefill(
    cluster: &mut Cluster,
    cfg: &ModelConfig,
    t: usize,
    minibatches: usize,
) -> PrefillTiming {
    let p = cluster.profile.clone();
    let n_workers = cluster.n_workers();
    let b = if minibatches == 0 {
        adaptive_minibatches(cfg, t, n_workers)
    } else {
        minibatches
    };

    // Per layer, each token's embedding goes to top_k experts; expert e
    // lives on worker e (one expert of every layer per worker, §3.3).
    // Average tokens per worker per layer:
    let tokens_per_worker = (t * cfg.top_k) as f64 / n_workers as f64;
    let bytes_per_worker = tokens_per_worker * p.embed_msg_bytes;
    let chunk_tokens = (tokens_per_worker / b as f64).ceil().max(1.0) as usize;
    let chunk_bytes = bytes_per_worker / b as f64;

    let mut main_free: Ms = 0.0;
    let mut worker_free: Vec<Ms> = vec![0.0; n_workers];
    let mut worker_wait: Ms = 0.0;

    for _layer in 0..cfg.n_layers {
        // Main-node batched attention over the whole prompt.
        let t_main = p.t_nonexpert_ms * (1.0 + (t as f64 - 1.0) * p.prefill_attn_marginal);
        let (_, m_end) = cluster.main.gpu.acquire(main_free, t_main);

        // Each worker loads this layer's expert over its own PCIe link
        // (pipelines with the previous layer's compute automatically via
        // the per-worker link resource). Load and FFN durations come
        // from the owning node's class (== the base profile on a
        // uniform cluster), and embeddings reach a class's workers its
        // LAN attach extra later.
        let mut layer_end: Ms = 0.0;
        for w in 0..n_workers {
            let (_, load_done) = cluster.expert_load(w, 0.0, p.expert_bytes);
            cluster.workers[w].alloc(p.expert_bytes as u64);

            // Stream B mini-batches to this worker; compute pipelines
            // behind the arrivals (Fig. 7b).
            let mut compute_free = worker_free[w].max(load_done);
            let mut sent_from = m_end;
            let lan_extra = cluster.lan_extra(w);
            let dur = cluster.worker_profile(w).expert_batch_ms(chunk_tokens);
            for _chunk in 0..b {
                let arrival = cluster.lan_send(sent_from, chunk_bytes, "prefill-embed") + lan_extra;
                sent_from = arrival;
                if arrival > compute_free {
                    worker_wait += arrival - compute_free;
                }
                let start = arrival.max(compute_free);
                let (_, end) = cluster.workers[w].gpu.acquire(start.max(start), dur);
                compute_free = end;
            }
            // Results return to the main node.
            let back = cluster.lan_send(compute_free, chunk_bytes, "prefill-back");
            cluster.workers[w].dealloc(p.expert_bytes as u64);
            worker_free[w] = compute_free;
            layer_end = layer_end.max(back);
        }
        main_free = layer_end;
    }
    let (_, ttft) = cluster.main.gpu.acquire(main_free, p.t_lm_head_ms);
    PrefillTiming { ttft_ms: ttft, worker_wait_ms: worker_wait }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HardwareProfile;

    fn run(t: usize, b: usize) -> PrefillTiming {
        let mut c = Cluster::new(HardwareProfile::rtx3090(), 8);
        simulate_odmoe_prefill(&mut c, &ModelConfig::default(), t, b)
    }

    #[test]
    fn minibatching_beats_single_large_batch() {
        // Fig. 7: pipelined mini-batches lower prefill latency even though
        // total compute time grows.
        let single = run(128, 1);
        let mini = run(128, 4);
        assert!(
            mini.ttft_ms < single.ttft_ms,
            "mini {} vs single {}",
            mini.ttft_ms,
            single.ttft_ms
        );
        assert!(mini.worker_wait_ms <= single.worker_wait_ms);
    }

    #[test]
    fn longer_prompts_take_longer() {
        assert!(run(128, 4).ttft_ms > run(16, 4).ttft_ms);
    }

    #[test]
    fn too_many_minibatches_backfire() {
        // Fig. 7's trade-off: mini-batching pipelines LAN and compute, but
        // each extra chunk pays per-message latency and loses batching
        // efficiency — the optimum is an interior B, not B→∞.
        let b1 = run(128, 1);
        let b4 = run(128, 4);
        let b16 = run(128, 16);
        assert!(b4.ttft_ms < b1.ttft_ms, "some mini-batching must help");
        assert!(b16.ttft_ms > b4.ttft_ms, "excessive chunking must cost");
    }

    #[test]
    fn heterogeneous_fleet_prefill_books_honest_class_times() {
        use crate::cluster::NodeClass;
        let base = HardwareProfile::rtx3090();
        let uniform = run(64, 4).ttft_ms;
        // Same worker count, half the nodes swapped for jetsons: their
        // thin links and slow FFNs must show up in TTFT.
        let mut classes = vec![NodeClass::of_profile(&base); 4];
        classes.extend(vec![NodeClass::jetson(); 4]);
        let mut c = Cluster::with_classes(base.clone(), classes);
        let het = simulate_odmoe_prefill(&mut c, &ModelConfig::default(), 64, 4).ttft_ms;
        assert!(het > uniform, "jetson links must slow prefill: {het} vs {uniform}");
        // An all-uniform class list reproduces the shared-profile TTFT
        // exactly (the bit-identical single-class pin, prefill edition).
        let mut c =
            Cluster::with_classes(base.clone(), vec![NodeClass::of_profile(&base); 8]);
        let same = simulate_odmoe_prefill(&mut c, &ModelConfig::default(), 64, 4).ttft_ms;
        assert_eq!(same, uniform);
    }

    #[test]
    fn ttft_in_plausible_paper_range() {
        // Paper: ~1.3 s (16 tokens) and ~3.1 s (128 tokens) over 32 layers.
        // Our 12-layer sim scales by 12/32: ~0.5 s and ~1.2 s. Accept a
        // generous band — shape matters, not the third digit.
        let t16 = run(16, 4).ttft_ms;
        let t128 = run(128, 4).ttft_ms;
        assert!(t16 > 200.0 && t16 < 1200.0, "ttft16 = {t16}");
        assert!(t128 > 600.0 && t128 < 3000.0, "ttft128 = {t128}");
        assert!(t128 / t16 > 1.5, "long prompts must cost visibly more");
    }
}
