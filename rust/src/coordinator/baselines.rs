//! Baseline serving engines (paper §4.4, Table 2): the fully GPU-cached
//! Transformers reference, the CPU-only llama.cpp reference, and the four
//! single-GPU expert-offloading systems re-implemented as cache/predictor
//! policies over the same simulator and the same real numerics.

use anyhow::Result;

use super::batch::{merge_distinct, BatchEngine, BatchRunResult};
use super::{Engine, PromptResult};
use crate::cache::{ExpertCache, Policy};
use crate::cluster::{Cluster, HardwareProfile, Ms};
use crate::engine::{BatchState, ModelState};
use crate::model::{Precision, WeightStore};
use crate::predictor::{GateLookahead, MultiLayerGate, Predictor, Statistical};
use crate::runtime::{DeviceModel, Runtime};
use std::collections::HashMap;

/// Which lookahead predictor an offloading system uses for prefetching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchKind {
    GateLookahead,
    MultiLayerGate4,
    Statistical,
    None,
}

/// Configuration of a single-GPU offloading baseline.
#[derive(Debug, Clone)]
pub struct OffloadConfig {
    pub system: &'static str,
    /// GPU expert-cache capacity in expert slots.
    pub cache_experts: usize,
    pub policy: Policy,
    /// Expert bytes transferred, as a fraction of FP32 (quantized loads).
    pub load_factor: f64,
    /// Numerics precision of the offloaded experts.
    pub expert_precision: Precision,
    pub prefetch: PrefetchKind,
    /// AdapMoE's bypass: skip experts that miss the cache.
    pub skip_on_miss: bool,
    /// Per-layer engine overhead (calibration to the published systems'
    /// measured inefficiencies; see EXPERIMENTS.md §Calibration).
    pub overhead_ms: Ms,
    pub profile: HardwareProfile,
}

impl OffloadConfig {
    /// Mixtral-Offloading: LRU cache, HQQ-quantized experts, gate
    /// lookahead prefetch (paper reports ~2.2 tok/s, ~80% hit rate).
    pub fn mixtral_offloading(n_layers: usize) -> Self {
        Self {
            system: "mixtral-offloading",
            cache_experts: 2 * n_layers,
            policy: Policy::Lru,
            load_factor: 0.143, // ~4.5 bit/param
            expert_precision: Precision::Nf4,
            prefetch: PrefetchKind::GateLookahead,
            skip_on_miss: false,
            overhead_ms: 1.5,
            profile: HardwareProfile::gpu_server(),
        }
    }

    /// MoE-Infinity: LFU cache, full-precision experts (fp16 transfers),
    /// request-statistics prefetch (paper: 0.69 tok/s).
    pub fn moe_infinity(n_layers: usize) -> Self {
        Self {
            system: "moe-infinity",
            cache_experts: (n_layers * 4) / 3, // ~1.3 experts/layer budget
            policy: Policy::Lfu,
            load_factor: 0.5, // fp16
            expert_precision: Precision::Fp16,
            prefetch: PrefetchKind::Statistical,
            skip_on_miss: false,
            overhead_ms: 6.0,
            profile: HardwareProfile::gpu_server(),
        }
    }

    /// HOBBIT: mixed-precision expert tiers + multi-layer gate prediction
    /// (paper: 0.79 tok/s, recall 0.91 four layers ahead).
    pub fn hobbit(n_layers: usize) -> Self {
        Self {
            system: "hobbit",
            cache_experts: 2 * n_layers,
            policy: Policy::Lru,
            load_factor: 0.25, // int8/int4 tier mix
            expert_precision: Precision::Int8,
            prefetch: PrefetchKind::MultiLayerGate4,
            skip_on_miss: false,
            overhead_ms: 8.0,
            profile: HardwareProfile::gpu_server(),
        }
    }

    /// AdapMoE: quantized experts + gate lookahead + miss bypass
    /// (paper: 3.13 tok/s, at an answer-quality cost).
    pub fn adapmoe(n_layers: usize) -> Self {
        Self {
            system: "adapmoe",
            cache_experts: (n_layers * 4) / 3,
            policy: Policy::Lru,
            load_factor: 0.143,
            expert_precision: Precision::Nf4,
            prefetch: PrefetchKind::GateLookahead,
            skip_on_miss: true,
            overhead_ms: 0.5,
            profile: HardwareProfile::gpu_server(),
        }
    }
}

/// Single-GPU expert-offloading engine.
pub struct OffloadEngine<'rt> {
    pub cfg: OffloadConfig,
    rt: &'rt Runtime,
    state: ModelState<'rt>,
    /// Device weights with experts at the system's serving precision
    /// (used for expert numerics; attention stays full precision).
    expert_dm: DeviceModel,
    cache: ExpertCache,
    /// Load-completion times of cached/pending experts.
    ready_at: HashMap<(usize, usize), Ms>,
    predictor: Option<Box<dyn Predictor>>,
    pub cluster: Cluster,
    now: Ms,
    pub skipped_experts: u64,
}

impl<'rt> OffloadEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore, cfg: OffloadConfig) -> Result<Self> {
        let quant_ws = ws.with_quantized_experts(cfg.expert_precision);
        let expert_dm = DeviceModel::upload(rt, &quant_ws)?;
        let predictor: Option<Box<dyn Predictor>> = match cfg.prefetch {
            PrefetchKind::GateLookahead => Some(Box::new(GateLookahead::new(&ws))),
            PrefetchKind::MultiLayerGate4 => Some(Box::new(MultiLayerGate::new(&ws, 4))),
            PrefetchKind::Statistical => Some(Box::new(Statistical::new(
                ws.cfg.n_layers,
                ws.cfg.n_experts,
                ws.cfg.top_k,
            ))),
            PrefetchKind::None => None,
        };
        let cache = ExpertCache::new(cfg.cache_experts, cfg.policy);
        let cluster = Cluster::new(cfg.profile.clone(), 0);
        // Full-precision attention stack for numerics.
        let state = ModelState::new(rt, ws)?;
        let mut eng = Self {
            cfg,
            rt,
            state,
            expert_dm,
            cache,
            ready_at: HashMap::new(),
            predictor,
            cluster,
            now: 0.0,
            skipped_experts: 0,
        };
        eng.charge_static_memory();
        Ok(eng)
    }

    fn charge_static_memory(&mut self) {
        let p = self.cluster.profile.clone();
        let cache_bytes =
            self.cfg.cache_experts as f64 * p.expert_bytes_fp32 * self.cfg.load_factor;
        self.cluster
            .main
            .alloc((p.nonexpert_bytes + cache_bytes + p.activation_bytes) as u64);
    }

    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    fn expert_bytes(&self) -> f64 {
        self.cluster.profile.expert_bytes_fp32 * self.cfg.load_factor
    }

    /// Book a load on the single PCIe link; cache-insert when done.
    fn load_expert(&mut self, key: (usize, usize), earliest: Ms) -> Ms {
        let bytes = self.expert_bytes();
        let dur = self.cluster.profile.pcie_lat_ms + self.cluster.profile.pcie_transfer_ms(bytes);
        let (_, done) = self.cluster.main.pcie.acquire(earliest, dur);
        for victim in self.cache.insert(key) {
            self.ready_at.remove(&victim);
        }
        self.ready_at.insert(key, done);
        done
    }

    fn decode_iteration(&mut self, token: u32, stall_ms: &mut Ms) -> Result<(u32, Vec<f32>)> {
        let p = self.cluster.profile.clone();
        let cfg = self.cfg.clone();
        let t_expert = p.t_expert_gpu_ms;

        // Split-borrow everything the per-layer closure needs.
        let rt = self.rt;
        let expert_dm = &self.expert_dm;
        let cache = &mut self.cache;
        let ready_at = &mut self.ready_at;
        let predictor = &mut self.predictor;
        let cluster = &mut self.cluster;
        let now = &mut self.now;
        let skipped = &mut self.skipped_experts;
        let mut stall_local: Ms = 0.0;

        if let Some(pred) = predictor.as_mut() {
            pred.begin_token(token);
        }

        let d = self.state.cfg().d_model;
        let n_layers = self.state.cfg().n_layers;
        let mut exec = |layer: usize,
                        route: &crate::engine::Route,
                        x_resid: &[f32],
                        _h: &[f32]|
         -> Result<Vec<f32>> {
            // ---- virtual time: non-expert compute + gate at its end. ----
            let (_, gate_end) = cluster.main.gpu.acquire(*now, p.t_nonexpert_ms + cfg.overhead_ms);
            *now = gate_end;

            // Prefetch for upcoming layers per the system's predictor
            // (overlaps with this layer's expert compute).
            if let Some(pred) = predictor.as_mut() {
                pred.observe(layer, x_resid, _h, route);
                let ahead = pred.lookahead().min(4);
                for j in 1..=ahead {
                    let target = layer + j;
                    if target >= n_layers {
                        break;
                    }
                    if let Some(experts) = pred.predict(target) {
                        for e in experts {
                            let key = (target, e);
                            if !cache.contains(key) {
                                // Book prefetch load (earliest = now).
                                let bytes = p.expert_bytes_fp32 * cfg.load_factor;
                                let dur = p.pcie_lat_ms + p.pcie_transfer_ms(bytes);
                                let (_, done) = cluster.main.pcie.acquire(gate_end, dur);
                                for victim in cache.insert(key) {
                                    ready_at.remove(&victim);
                                }
                                ready_at.insert(key, done);
                            }
                        }
                    }
                }
            }

            // ---- needed experts: hit/miss, stalls, compute + numerics. ----
            let mut acc = vec![0f32; d];
            let mut used_weight = 0f32;
            for (i, &e) in route.experts.iter().enumerate() {
                let key = (layer, e);
                let hit = cache.touch(key);
                let ready = if hit {
                    ready_at.get(&key).copied().unwrap_or(0.0).max(*now)
                } else if cfg.skip_on_miss {
                    *skipped += 1;
                    continue; // AdapMoE bypass: no load, no compute.
                } else {
                    let bytes = p.expert_bytes_fp32 * cfg.load_factor;
                    let dur = p.pcie_lat_ms + p.pcie_transfer_ms(bytes);
                    let (_, done) = cluster.main.pcie.acquire(*now, dur);
                    for victim in cache.insert(key) {
                        ready_at.remove(&victim);
                    }
                    ready_at.insert(key, done);
                    done
                };
                stall_local += (ready - *now).max(0.0);
                let (_, ec_end) = cluster.main.gpu.acquire(ready.max(*now), t_expert);
                *now = ec_end;

                // Numerics at the system's expert precision.
                let y = rt.expert_ffn(expert_dm, layer, e, _h, 1)?;
                let w = route.weights[i];
                used_weight += w;
                for j in 0..d {
                    acc[j] += w * y[j];
                }
            }
            // Renormalize over the experts actually used (bypass case).
            if cfg.skip_on_miss && used_weight > 0.0 && used_weight < 0.999 {
                for v in &mut acc {
                    *v /= used_weight;
                }
            }
            Ok(acc)
        };

        let rec = self.state.decode_step_with(token, &mut exec)?;
        let (_, lm_end) = self.cluster.main.gpu.acquire(self.now, p.t_lm_head_ms);
        self.now = lm_end;
        *stall_ms += stall_local;
        Ok((rec.token_out, rec.logits))
    }

    fn prefill_timing(&mut self, t: usize) -> Ms {
        // Batched prefill on one GPU: per layer, attention + ALL experts
        // (all activated for long prompts), each possibly loaded through
        // the single PCIe link first.
        let p = self.cluster.profile.clone();
        let n_experts = self.state.cfg().n_experts;
        let n_layers = self.state.cfg().n_layers;
        let tokens_per_expert =
            ((t * self.state.cfg().top_k) as f64 / n_experts as f64).ceil() as usize;
        for layer in 0..n_layers {
            let t_main = p.t_nonexpert_ms * (1.0 + (t as f64 - 1.0) * p.prefill_attn_marginal)
                + self.cfg.overhead_ms;
            let (_, m_end) = self.cluster.main.gpu.acquire(self.now, t_main);
            self.now = m_end;
            for e in 0..n_experts {
                let key = (layer, e);
                let ready = if self.cache.touch(key) {
                    self.ready_at.get(&key).copied().unwrap_or(0.0).max(self.now)
                } else if self.cfg.skip_on_miss {
                    // AdapMoE still loads during prefill (skipping every
                    // expert would destroy the prompt encoding); bypass is
                    // a decode-stage mechanism.
                    self.load_expert(key, self.now)
                } else {
                    self.load_expert(key, self.now)
                };
                let dur = p.expert_batch_ms(tokens_per_expert);
                let (_, ec_end) = self.cluster.main.gpu.acquire(ready.max(self.now), dur);
                self.now = ec_end;
            }
        }
        let (_, ttft) = self.cluster.main.gpu.acquire(self.now, p.t_lm_head_ms);
        self.now = ttft;
        ttft
    }
}

impl<'rt> Engine for OffloadEngine<'rt> {
    fn name(&self) -> String {
        self.cfg.system.to_string()
    }

    fn reset(&mut self) -> Result<()> {
        self.state.reset();
        self.cache = ExpertCache::new(self.cfg.cache_experts, self.cfg.policy);
        self.ready_at.clear();
        self.cluster.reset();
        self.now = 0.0;
        self.skipped_experts = 0;
        self.charge_static_memory();
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        let mut res = PromptResult::default();
        let rec = self.state.prefill(prompt)?;
        res.ttft_ms = self.prefill_timing(prompt.len());
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }
        let decode_start = self.now;
        let mut token = rec.token_out;
        let mut stall = 0.0;
        for _ in 1..out_tokens {
            let (next, logits) = self.decode_iteration(token, &mut stall)?;
            res.tokens.push(next);
            if collect_logits {
                res.step_logits.push(logits);
            }
            token = next;
        }
        res.decode_ms = self.now - decode_start;
        res.stall_ms = stall;
        Ok(res)
    }
}

/// Fully GPU-cached full-precision reference (HuggingFace Transformers on
/// an 8-GPU server): zero expert loads.
pub struct FullyCachedEngine<'rt> {
    state: ModelState<'rt>,
    profile: HardwareProfile,
    now: Ms,
}

impl<'rt> FullyCachedEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore) -> Result<Self> {
        Ok(Self {
            state: ModelState::new(rt, ws)?,
            profile: HardwareProfile::gpu_server(),
            now: 0.0,
        })
    }
}

impl<'rt> Engine for FullyCachedEngine<'rt> {
    fn name(&self) -> String {
        "transformers".into()
    }

    fn reset(&mut self) -> Result<()> {
        self.state.reset();
        self.now = 0.0;
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        let p = &self.profile;
        let cfg = self.state.cfg().clone();
        let mut res = PromptResult::default();
        let rec = self.state.prefill(prompt)?;
        let t = prompt.len();
        let tokens_per_expert = ((t * cfg.top_k) as f64 / cfg.n_experts as f64).ceil() as usize;
        let per_layer = p.t_nonexpert_ms * (1.0 + (t as f64 - 1.0) * p.prefill_attn_marginal)
            + cfg.n_experts as f64 * p.expert_batch_ms(tokens_per_expert);
        res.ttft_ms = cfg.n_layers as f64 * per_layer + p.t_lm_head_ms;
        self.now = res.ttft_ms;
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }
        let decode_start = self.now;
        let mut token = rec.token_out;
        let per_token = cfg.n_layers as f64
            * (p.t_nonexpert_ms + cfg.top_k as f64 * p.t_expert_gpu_ms)
            + p.t_lm_head_ms;
        for _ in 1..out_tokens {
            let step = self.state.decode_step(token)?;
            self.now += per_token;
            res.tokens.push(step.token_out);
            if collect_logits {
                res.step_logits.push(step.logits.clone());
            }
            token = step.token_out;
        }
        res.decode_ms = self.now - decode_start;
        Ok(res)
    }
}

impl<'rt> BatchEngine for FullyCachedEngine<'rt> {
    /// Batched decode on the fully-cached server — the fair ceiling for
    /// OD-MoE's batched mode: zero expert loads by construction, so the
    /// only batch effect is compute amortization (batched attention/LM
    /// head plus one batched FFN per distinct expert per layer). A batch
    /// of one reproduces `run_prompt` timings exactly.
    fn run_batch(&mut self, sessions: &[(&[u32], usize)]) -> Result<BatchRunResult> {
        anyhow::ensure!(!sessions.is_empty(), "batch needs at least one session");
        let p = self.profile.clone();
        let cfg = self.state.cfg().clone();
        let mut batch = BatchState::new();
        let mut out: Vec<PromptResult> =
            (0..sessions.len()).map(|_| PromptResult::default()).collect();

        // Prefills serialize on the one server.
        for (i, &(prompt, target)) in sessions.iter().enumerate() {
            batch.join(&mut self.state, i, prompt, target)?;
            let t = prompt.len();
            let tokens_per_expert =
                ((t * cfg.top_k) as f64 / cfg.n_experts as f64).ceil() as usize;
            let per_layer = p.t_nonexpert_ms * (1.0 + (t as f64 - 1.0) * p.prefill_attn_marginal)
                + cfg.n_experts as f64 * p.expert_batch_ms(tokens_per_expert);
            self.now += cfg.n_layers as f64 * per_layer + p.t_lm_head_ms;
            out[i].ttft_ms = self.now;
        }
        let decode_start = self.now;

        let mut decode_tokens = 0u64;
        let mut decode_iterations = 0u64;
        loop {
            let active = batch.active();
            if active.is_empty() {
                break;
            }
            let b = active.len();
            let mut recs = Vec::with_capacity(b);
            for &s in &active {
                let token = batch.slot(s).next_token;
                batch.activate(s, &mut self.state);
                let rec = self.state.decode_step(token);
                batch.deactivate(s, &mut self.state);
                let rec = rec?;
                batch.record_token(s, rec.token_out);
                recs.push(rec);
            }
            // Per layer: batched attention + one batched FFN per distinct
            // expert over the sessions that routed to it.
            let mut iter_ms = p.batched_ms(p.t_lm_head_ms, b);
            for l in 0..cfg.n_layers {
                iter_ms += p.batched_ms(p.t_nonexpert_ms, b);
                for (_, cnt) in merge_distinct(recs.iter().map(|r| r.routes[l].experts.as_slice()))
                {
                    iter_ms += p.expert_batch_ms(cnt);
                }
            }
            self.now += iter_ms;
            decode_iterations += 1;
            decode_tokens += b as u64;
            for &s in &active {
                if batch.slot(s).done() {
                    out[s].decode_ms = self.now - out[s].ttft_ms;
                }
            }
        }
        for (i, res) in out.iter_mut().enumerate() {
            res.tokens = batch.slot(i).tokens.clone();
        }
        Ok(BatchRunResult {
            sessions: out,
            expert_loads: 0,
            aborted_loads: 0,
            failovers: 0,
            decode_tokens,
            decode_iterations,
            decode_span_ms: self.now - decode_start,
            expert_demand: Vec::new(),
        })
    }
}

/// CPU-only reference (llama.cpp): all weights in DRAM, no GPU.
pub struct CpuEngine<'rt> {
    state: ModelState<'rt>,
    profile: HardwareProfile,
    now: Ms,
}

impl<'rt> CpuEngine<'rt> {
    pub fn new(rt: &'rt Runtime, ws: WeightStore) -> Result<Self> {
        Ok(Self {
            state: ModelState::new(rt, ws)?,
            profile: HardwareProfile::gpu_server(),
            now: 0.0,
        })
    }
}

impl<'rt> Engine for CpuEngine<'rt> {
    fn name(&self) -> String {
        "llama.cpp".into()
    }

    fn reset(&mut self) -> Result<()> {
        self.state.reset();
        self.now = 0.0;
        Ok(())
    }

    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult> {
        let p = &self.profile;
        let cfg = self.state.cfg().clone();
        let mut res = PromptResult::default();
        let rec = self.state.prefill(prompt)?;
        let t = prompt.len();
        let tokens_per_expert = ((t * cfg.top_k) as f64 / cfg.n_experts as f64).ceil() as usize;
        // CPU expert matmuls are weight-memory-bound: a T-token batch costs
        // barely more than one token (why llama.cpp's prefill is strong
        // relative to its decode — paper Table 2 TTFT).
        let per_layer = p.cpu_nonexpert_ms * (1.0 + (t as f64 - 1.0) * 0.02)
            + cfg.n_experts as f64
                * p.cpu_expert_ms
                * (0.45 + tokens_per_expert as f64 * 0.04);
        res.ttft_ms = cfg.n_layers as f64 * per_layer + p.t_lm_head_ms;
        self.now = res.ttft_ms;
        res.tokens.push(rec.token_out);
        if collect_logits {
            res.step_logits.push(rec.logits.clone());
        }
        let decode_start = self.now;
        let mut token = rec.token_out;
        let per_token = cfg.n_layers as f64
            * (p.cpu_nonexpert_ms + cfg.top_k as f64 * p.cpu_expert_ms)
            + p.t_lm_head_ms;
        for _ in 1..out_tokens {
            let step = self.state.decode_step(token)?;
            self.now += per_token;
            res.tokens.push(step.token_out);
            if collect_logits {
                res.step_logits.push(step.logits.clone());
            }
            token = step.token_out;
        }
        res.decode_ms = self.now - decode_start;
        Ok(res)
    }
}
