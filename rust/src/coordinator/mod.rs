//! The serving engines: OD-MoE itself plus every baseline system the paper
//! benchmarks against, all running real numerics over the PJRT runtime and
//! virtual-time durations over the cluster simulator.

pub mod baselines;
pub mod batch;
pub mod odmoe;
pub mod precision;
pub mod prefill;
pub mod schedule;
pub mod replication;
pub mod server;

pub use batch::{BatchEngine, BatchRunResult};
pub use odmoe::{FailureSpec, OdMoeConfig, OdMoeEngine, PredictorMode};
pub use precision::{PrecisionController, PrecisionPolicy};
pub use schedule::{GroupSchedule, SlotMap};
// `server` is a compatibility shim; the serving layer proper lives in
// [`crate::serve`].
pub use server::{Request, Server, ServerStats};

use crate::cluster::Ms;
use anyhow::Result;

/// Result of serving one prompt through an engine.
#[derive(Debug, Clone, Default)]
pub struct PromptResult {
    /// Virtual time to first token (prefill), ms.
    pub ttft_ms: Ms,
    /// Virtual decode time for the remaining tokens, ms.
    pub decode_ms: Ms,
    /// All generated tokens (first produced by prefill).
    pub tokens: Vec<u32>,
    /// LM-head logits per generated token (only when requested).
    pub step_logits: Vec<Vec<f32>>,
    /// For predictor-driven engines: per decode iteration, per layer,
    /// the number of correctly predicted experts (recall input, Eq. 2).
    pub correct_per_token: Vec<Vec<usize>>,
    /// Total I/O stall during decode (expert-wait beyond data arrival).
    pub stall_ms: Ms,
}

impl PromptResult {
    /// Decoded tokens per second (excludes the prefill token).
    pub fn decode_tps(&self) -> f64 {
        let n = self.tokens.len().saturating_sub(1);
        if self.decode_ms <= 0.0 || n == 0 {
            return 0.0;
        }
        n as f64 / (self.decode_ms / 1000.0)
    }
}

/// A serving engine: prefill + autoregressive decode over one prompt.
pub trait Engine {
    fn name(&self) -> String;

    /// Clear all per-request state (KV caches, virtual clocks, caches).
    fn reset(&mut self) -> Result<()>;

    /// Serve one prompt, generating `out_tokens` tokens (the first via
    /// prefill). `collect_logits` retains per-step logits for fidelity
    /// evaluation (memory-heavy; off for speed runs).
    fn run_prompt(
        &mut self,
        prompt: &[u32],
        out_tokens: usize,
        collect_logits: bool,
    ) -> Result<PromptResult>;
}
