//! Worker grouping + round-robin layer assignment (paper §3.1, Fig. 2)
//! and the Eq. (1) I/O-bottleneck condition.

use crate::cluster::{HardwareProfile, Ms};

/// Static group schedule: `n_workers` split into groups of `group_size`
/// (= top-k, one expert per device); MoE layers are assigned to groups
/// round-robin.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupSchedule {
    pub n_workers: usize,
    pub group_size: usize,
}

impl GroupSchedule {
    pub fn new(n_workers: usize, group_size: usize) -> Self {
        assert!(group_size > 0 && n_workers >= group_size,
                "need at least one full group ({n_workers} workers, group {group_size})");
        assert_eq!(n_workers % group_size, 0, "workers must split into equal groups");
        Self { n_workers, group_size }
    }

    pub fn n_groups(&self) -> usize {
        self.n_workers / self.group_size
    }

    /// Group responsible for `layer` (round-robin, Fig. 2).
    pub fn group_of(&self, layer: usize) -> usize {
        layer % self.n_groups()
    }

    /// Worker ids of a group.
    pub fn workers_of(&self, group: usize) -> std::ops::Range<usize> {
        let g = group % self.n_groups();
        g * self.group_size..(g + 1) * self.group_size
    }

    /// The worker that hosts slot `slot` (0..group_size) of `layer`.
    pub fn worker_for(&self, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < self.group_size);
        self.group_of(layer) * self.group_size + slot
    }

    /// Paper Eq. (1): maximum expert-load duration that causes no compute
    /// stall, given the per-layer main/worker task times.
    pub fn t_maxload(&self, t_main: Ms, t_worker: Ms) -> Ms {
        let n = self.n_groups() as f64;
        n * t_main + (n - 1.0) * t_worker
    }

    /// Is the pipeline I/O-bottleneck-free for `profile` at full
    /// precision? (The §3.1 feasibility check.)
    pub fn io_bottleneck_free(&self, p: &HardwareProfile) -> bool {
        p.expert_load_ms(1.0) <= self.t_maxload(p.t_main_ms(), p.t_worker_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_grouping() {
        // 8 workers, top-2 -> 4 groups of 2.
        let s = GroupSchedule::new(8, 2);
        assert_eq!(s.n_groups(), 4);
        assert_eq!(s.group_of(0), 0);
        assert_eq!(s.group_of(5), 1);
        assert_eq!(s.workers_of(1), 2..4);
        assert_eq!(s.worker_for(5, 1), 3);
    }

    #[test]
    fn round_robin_covers_all_groups() {
        let s = GroupSchedule::new(8, 2);
        let groups: Vec<usize> = (0..8).map(|l| s.group_of(l)).collect();
        assert_eq!(groups, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn eq1_matches_paper_example() {
        // Paper: t_maxload(EL_{l+4}) = 4 t_M + 3 t_W for the 4-group testbed.
        let s = GroupSchedule::new(8, 2);
        assert_eq!(s.t_maxload(4.0, 2.0), 4.0 * 4.0 + 3.0 * 2.0);
    }

    #[test]
    fn testbed_profile_is_feasible() {
        let s = GroupSchedule::new(8, 2);
        assert!(s.io_bottleneck_free(&HardwareProfile::rtx3090()));
    }

    #[test]
    fn two_workers_single_group_is_io_bound() {
        // With one group there is no staggered loading: window = t_M only.
        let s = GroupSchedule::new(2, 2);
        assert!(!s.io_bottleneck_free(&HardwareProfile::rtx3090()));
    }

    #[test]
    #[should_panic(expected = "equal groups")]
    fn uneven_split_rejected() {
        GroupSchedule::new(7, 2);
    }
}
