//! Worker grouping + round-robin layer assignment (paper §3.1, Fig. 2),
//! the Eq. (1) I/O-bottleneck condition, and the dynamic [`SlotMap`] that
//! routes expert slots around failed workers.

use crate::cluster::{HardwareProfile, Ms};

/// Static group schedule: `n_workers` split into groups of `group_size`
/// (= top-k, one expert per device); MoE layers are assigned to groups
/// round-robin. This is the healthy-cluster *blueprint*; the engine
/// routes through a [`SlotMap`] built from it, which can reassign a dead
/// worker's slots at runtime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupSchedule {
    pub n_workers: usize,
    pub group_size: usize,
}

impl GroupSchedule {
    pub fn new(n_workers: usize, group_size: usize) -> Self {
        assert!(group_size > 0 && n_workers >= group_size,
                "need at least one full group ({n_workers} workers, group {group_size})");
        assert_eq!(n_workers % group_size, 0, "workers must split into equal groups");
        Self { n_workers, group_size }
    }

    pub fn n_groups(&self) -> usize {
        self.n_workers / self.group_size
    }

    /// Group responsible for `layer` (round-robin, Fig. 2).
    pub fn group_of(&self, layer: usize) -> usize {
        layer % self.n_groups()
    }

    /// Worker ids of a group. Panics on an out-of-range group — callers
    /// must map layers through [`GroupSchedule::group_of`] first (the old
    /// silent `group % n_groups` wrap hid indexing bugs while
    /// `worker_for` did not wrap, so the two could disagree).
    pub fn workers_of(&self, group: usize) -> std::ops::Range<usize> {
        assert!(group < self.n_groups(), "group {group} out of range ({} groups)", self.n_groups());
        group * self.group_size..(group + 1) * self.group_size
    }

    /// The worker that hosts slot `slot` (0..group_size) of `layer`.
    /// Panics on an out-of-range slot.
    pub fn worker_for(&self, layer: usize, slot: usize) -> usize {
        assert!(slot < self.group_size, "slot {slot} out of range (group size {})", self.group_size);
        self.group_of(layer) * self.group_size + slot
    }

    /// Paper Eq. (1): maximum expert-load duration that causes no compute
    /// stall, given the per-layer main/worker task times.
    pub fn t_maxload(&self, t_main: Ms, t_worker: Ms) -> Ms {
        let n = self.n_groups() as f64;
        n * t_main + (n - 1.0) * t_worker
    }

    /// Is the pipeline I/O-bottleneck-free for `profile` at full
    /// precision? (The §3.1 feasibility check.)
    pub fn io_bottleneck_free(&self, p: &HardwareProfile) -> bool {
        p.expert_load_ms(1.0) <= self.t_maxload(p.t_main_ms(), p.t_worker_ms())
    }
}

/// Dynamic slot→worker assignment: the runtime counterpart of
/// [`GroupSchedule`]. Construction is first-fit — groups of `group_size`
/// fill from worker 0, and when the split is uneven the leftover workers
/// start as idle spares (relaxing the blueprint's equal-split
/// requirement). When a worker fail-stops, [`SlotMap::fail`] reassigns
/// each of its slots to a survivor, preferring targets whose *projected*
/// per-cycle load still fits the Eq. (1) no-stall window
/// `N·t_M + (N−1)·t_W` (a worker serving `k` slots must fit `k` expert
/// loads into the one-slot window), and falling back to the least-loaded
/// survivor when no target fits — the same "which node serves this
/// expert, under a deadline" decision SlimCaching/HOBBIT treat as a
/// first-class online choice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotMap {
    group_size: usize,
    /// `assign[g * group_size + s]` = worker currently hosting slot `s`
    /// of group `g`.
    assign: Vec<usize>,
    alive: Vec<bool>,
}

impl SlotMap {
    /// First-fit identity assignment over `n_workers` (which need not
    /// split evenly; leftovers become spares).
    pub fn new(n_workers: usize, group_size: usize) -> Self {
        assert!(
            group_size > 0 && n_workers >= group_size,
            "need at least one full group ({n_workers} workers, group {group_size})"
        );
        let n_groups = n_workers / group_size;
        Self::first_fit(n_workers, group_size, n_groups, |_| true)
    }

    /// Capability-aware first-fit over a heterogeneous fleet: slots fill
    /// from the lowest-id worker whose class `capable(w)` — i.e. whose
    /// class keeps [`HardwareProfile::reroute_feasible`] true for one
    /// slot — and only fall back to incapable workers (still in id
    /// order) when capable ones run out, so under-provisioned node
    /// classes start as spares instead of hosting slots. With every
    /// worker capable this is the identity assignment — the single-class
    /// special case [`SlotMap::new`] delegates to.
    pub fn first_fit(
        n_workers: usize,
        group_size: usize,
        n_groups: usize,
        capable: impl Fn(usize) -> bool,
    ) -> Self {
        assert!(
            group_size > 0 && n_groups > 0 && n_groups * group_size <= n_workers,
            "{n_groups} groups of {group_size} need <= {n_workers} workers"
        );
        let n_slots = n_groups * group_size;
        let mut assign = Vec::with_capacity(n_slots);
        assign.extend((0..n_workers).filter(|&w| capable(w)).take(n_slots));
        if assign.len() < n_slots {
            // Not enough capable nodes: the remaining slots land on
            // incapable workers anyway (degraded but live), id order.
            let short = n_slots - assign.len();
            assign.extend((0..n_workers).filter(|&w| !capable(w)).take(short));
        }
        assert_eq!(assign.len(), n_slots, "every slot must find a host");
        Self { group_size, assign, alive: vec![true; n_workers] }
    }

    pub fn from_schedule(s: &GroupSchedule) -> Self {
        Self::new(s.n_workers, s.group_size)
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    pub fn n_groups(&self) -> usize {
        self.assign.len() / self.group_size
    }

    pub fn n_workers(&self) -> usize {
        self.alive.len()
    }

    pub fn group_of(&self, layer: usize) -> usize {
        layer % self.n_groups()
    }

    /// The worker currently hosting slot `slot` of `layer`.
    pub fn worker_for(&self, layer: usize, slot: usize) -> usize {
        assert!(slot < self.group_size, "slot {slot} out of range (group size {})", self.group_size);
        self.assign[self.group_of(layer) * self.group_size + slot]
    }

    /// Workers currently serving a group's slots (may repeat a worker
    /// after failures concentrate slots).
    pub fn workers_of(&self, group: usize) -> Vec<usize> {
        assert!(group < self.n_groups(), "group {group} out of range ({} groups)", self.n_groups());
        self.assign[group * self.group_size..(group + 1) * self.group_size].to_vec()
    }

    pub fn is_alive(&self, w: usize) -> bool {
        self.alive[w]
    }

    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Slots currently assigned to worker `w` (its per-cycle load: one
    /// expert load + compute per assigned slot every `n_groups` layers).
    pub fn load_of(&self, w: usize) -> usize {
        self.assign.iter().filter(|&&x| x == w).count()
    }

    /// Mark `w` dead and reassign each of its slots to a survivor.
    /// `feasible(slots)` answers whether a worker serving `slots` expert
    /// slots still fits all of its per-cycle loads in the Eq. (1)
    /// no-stall window. This is the homogeneous-fleet entry point (every
    /// worker shares one predicate and one load time); heterogeneous
    /// fleets use [`SlotMap::fail_with`], which this delegates to.
    pub fn fail(
        &mut self,
        w: usize,
        feasible: impl Fn(usize) -> bool,
    ) -> Vec<(usize, usize, usize)> {
        self.fail_with(w, |_, slots| feasible(slots), |_| 1.0)
    }

    /// Capability-aware failure rerouting: mark `w` dead and reassign
    /// each of its slots to a survivor. `feasible(worker, slots)` is the
    /// per-class Eq. (1) predicate — pass the candidate's own
    /// [`HardwareProfile::reroute_feasible`], so a slot only lands on a
    /// node whose *class* keeps the no-stall window; `load_ms(worker)`
    /// is one slot's per-cycle load time on that worker's class
    /// (`effective_load_ms` under the current chunking). Among feasible
    /// candidates the one with the least *projected load time* wins —
    /// `(slots + 1) * load_ms(w)`, not the bare slot count, so a fast
    /// survivor carrying two slots can beat a slow empty one — with ties
    /// broken by slot count then lowest id; when nothing is feasible the
    /// least-loaded-by-time survivor takes the slot anyway (degraded but
    /// live). With a uniform `load_ms` this is exactly the old
    /// least-loaded-by-count order. Returns the (group, slot, new
    /// worker) moves. Panics if no worker survives.
    pub fn fail_with(
        &mut self,
        w: usize,
        feasible: impl Fn(usize, usize) -> bool,
        load_ms: impl Fn(usize) -> Ms,
    ) -> Vec<(usize, usize, usize)> {
        assert!(w < self.alive.len(), "worker {w} out of range");
        if !self.alive[w] {
            return Vec::new();
        }
        self.alive[w] = false;
        assert!(self.n_alive() > 0, "no surviving workers to reroute to");
        let mut moves = Vec::new();
        for i in 0..self.assign.len() {
            if self.assign[i] != w {
                continue;
            }
            let target = self.choose_target(&feasible, &load_ms);
            self.assign[i] = target;
            moves.push((i / self.group_size, i % self.group_size, target));
        }
        moves
    }

    /// Route an expert through a replication [`Placement`] (DESIGN.md
    /// §15): among the expert's replica hosts that are still alive, pick
    /// the one with the lowest placement load share (ties by lowest
    /// worker id — deterministic). An expert the placement does not
    /// cover, or whose replica hosts are all dead, falls back to the
    /// slot's default host [`SlotMap::worker_for`] — replication only
    /// ever *adds* routing options, it never strands a route.
    pub fn route_replicated(
        &self,
        placement: &crate::coordinator::replication::Placement,
        layer: usize,
        slot: usize,
        expert: usize,
    ) -> usize {
        let best = placement
            .replicas
            .get(expert)
            .into_iter()
            .flatten()
            .copied()
            .filter(|&w| w < self.alive.len() && self.alive[w])
            .min_by(|&a, &b| {
                placement.load[a]
                    .partial_cmp(&placement.load[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        best.unwrap_or_else(|| self.worker_for(layer, slot))
    }

    /// Least projected-load-time feasible survivor, else least loaded by
    /// time outright (ties: slot count, then lowest id — deterministic).
    fn choose_target(
        &self,
        feasible: &impl Fn(usize, usize) -> bool,
        load_ms: &impl Fn(usize) -> Ms,
    ) -> usize {
        let score = |c: usize| {
            let slots = self.load_of(c);
            let t = (slots + 1) as f64 * load_ms(c);
            debug_assert!(t.is_finite() && t >= 0.0, "worker {c}: bad load time {t}");
            (t, slots, c)
        };
        let by_time = |a: &(Ms, usize, usize), b: &(Ms, usize, usize)| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        };
        let candidates = || (0..self.alive.len()).filter(|&c| self.alive[c]).map(score);
        let best = candidates()
            .filter(|&(_, slots, c)| feasible(c, slots + 1))
            .min_by(by_time);
        let (_, _, target) =
            best.or_else(|| candidates().min_by(by_time)).expect("a survivor exists");
        target
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_grouping() {
        // 8 workers, top-2 -> 4 groups of 2.
        let s = GroupSchedule::new(8, 2);
        assert_eq!(s.n_groups(), 4);
        assert_eq!(s.group_of(0), 0);
        assert_eq!(s.group_of(5), 1);
        assert_eq!(s.workers_of(1), 2..4);
        assert_eq!(s.worker_for(5, 1), 3);
    }

    #[test]
    fn round_robin_covers_all_groups() {
        let s = GroupSchedule::new(8, 2);
        let groups: Vec<usize> = (0..8).map(|l| s.group_of(l)).collect();
        assert_eq!(groups, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn eq1_matches_paper_example() {
        // Paper: t_maxload(EL_{l+4}) = 4 t_M + 3 t_W for the 4-group testbed.
        let s = GroupSchedule::new(8, 2);
        assert_eq!(s.t_maxload(4.0, 2.0), 4.0 * 4.0 + 3.0 * 2.0);
    }

    #[test]
    fn testbed_profile_is_feasible() {
        let s = GroupSchedule::new(8, 2);
        assert!(s.io_bottleneck_free(&HardwareProfile::rtx3090()));
    }

    #[test]
    fn two_workers_single_group_is_io_bound() {
        // With one group there is no staggered loading: window = t_M only.
        let s = GroupSchedule::new(2, 2);
        assert!(!s.io_bottleneck_free(&HardwareProfile::rtx3090()));
    }

    #[test]
    #[should_panic(expected = "equal groups")]
    fn uneven_split_rejected() {
        GroupSchedule::new(7, 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn workers_of_rejects_out_of_range_group() {
        // The old implementation silently wrapped `group % n_groups()`
        // while `worker_for` did not — the two could disagree.
        GroupSchedule::new(8, 2).workers_of(4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn worker_for_rejects_out_of_range_slot() {
        GroupSchedule::new(8, 2).worker_for(0, 2);
    }

    #[test]
    fn slotmap_identity_matches_blueprint() {
        let s = GroupSchedule::new(8, 2);
        let m = SlotMap::from_schedule(&s);
        for l in 0..16 {
            for slot in 0..2 {
                assert_eq!(m.worker_for(l, slot), s.worker_for(l, slot));
            }
        }
        assert_eq!(m.n_groups(), 4);
        assert_eq!(m.n_alive(), 8);
    }

    #[test]
    fn slotmap_first_fit_accepts_uneven_split_with_spares() {
        // 7 workers, groups of 2: three full groups, worker 6 a spare.
        let m = SlotMap::new(7, 2);
        assert_eq!(m.n_groups(), 3);
        assert_eq!(m.load_of(6), 0, "leftover worker starts idle");
        // A failure reroutes onto the idle spare first (least loaded).
        let mut m = m;
        let moves = m.fail(1, |slots| slots as f64 * 10.0 <= 100.0);
        assert_eq!(moves, vec![(0, 1, 6)]);
        assert_eq!(m.worker_for(0, 1), 6);
    }

    #[test]
    fn fail_prefers_window_feasible_target() {
        // load 10, window 25: a worker with 1 slot projects 2*10 <= 25
        // (feasible); with 2 slots projects 3*10 > 25. Kill two workers:
        // the second reroute must skip the now-2-slot worker 0 and pick
        // the feasible least-loaded survivor.
        let fits = |slots: usize| slots as f64 * 10.0 <= 25.0;
        let mut m = SlotMap::new(8, 2);
        let moves = m.fail(1, fits);
        assert_eq!(moves, vec![(0, 1, 0)], "least-loaded feasible = worker 0");
        assert_eq!(m.load_of(0), 2);
        let moves = m.fail(2, fits);
        assert_eq!(moves, vec![(1, 0, 3)], "worker 0 now infeasible; 3 is next");
    }

    #[test]
    fn fail_falls_back_to_least_loaded_when_nothing_fits() {
        // Window smaller than a single load: nothing is ever feasible,
        // but slots must still land somewhere (least-loaded, lowest id).
        let never = |_slots: usize| false;
        let mut m = SlotMap::new(4, 2);
        let moves = m.fail(3, never);
        assert_eq!(moves, vec![(1, 1, 0)]);
        assert_eq!(m.load_of(0), 2);
        // Worker of the same group can end up hosting both slots.
        let moves = m.fail(2, never);
        assert_eq!(moves, vec![(1, 0, 1)]);
        assert_eq!(m.workers_of(1), vec![1, 0]);
    }

    #[test]
    fn fail_uses_the_profile_feasibility_predicate() {
        // The engine passes HardwareProfile::reroute_feasible directly:
        // on the knife's-edge paper profile nothing absorbs a second
        // slot monolithically, so the reroute falls back to the
        // least-loaded survivor.
        let p = HardwareProfile::rtx3090();
        let mut m = SlotMap::new(8, 2);
        let moves = m.fail(7, |slots| p.reroute_feasible(slots, 4, 1));
        assert_eq!(moves, vec![(3, 1, 0)], "least-loaded fallback, lowest id");
    }

    #[test]
    fn chunked_feasibility_is_never_stricter_than_monolithic() {
        // Earliest-first-chunk deadlines only ever widen the window: any
        // (slots, groups) pair feasible monolithically stays feasible
        // under chunked streaming.
        let p = HardwareProfile::rtx3090();
        for slots in 1..4usize {
            for groups in [2usize, 4, 8] {
                for chunks in [2usize, 4, 8] {
                    if p.reroute_feasible(slots, groups, 1) {
                        assert!(
                            p.reroute_feasible(slots, groups, chunks),
                            "chunking ({chunks}) must not shrink the window \
                             ({slots} slots, {groups} groups)"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn first_fit_prefers_capable_workers_and_falls_back_in_order() {
        // 6 workers, groups of 2, 2 groups (4 slots); workers 1 and 3
        // incapable: slots fill from {0, 2, 4, 5}, incapable start idle.
        let capable = |w: usize| w != 1 && w != 3;
        let m = SlotMap::first_fit(6, 2, 2, capable);
        assert_eq!(m.workers_of(0), vec![0, 2]);
        assert_eq!(m.workers_of(1), vec![4, 5]);
        assert_eq!(m.load_of(1), 0, "incapable worker starts as a spare");
        assert_eq!(m.load_of(3), 0);
        // Not enough capable nodes: the shortfall lands on incapable
        // workers in id order (degraded but live).
        let m = SlotMap::first_fit(4, 2, 2, |w| w >= 3);
        assert_eq!(m.workers_of(0), vec![3, 0]);
        assert_eq!(m.workers_of(1), vec![1, 2]);
        // All capable == the identity assignment SlotMap::new builds.
        assert_eq!(SlotMap::first_fit(8, 2, 4, |_| true), SlotMap::new(8, 2));
    }

    #[test]
    fn fail_with_prefers_least_projected_load_time_not_slot_count() {
        // Worker 0 is 4x faster than workers 2..: after absorbing one
        // slot (2 total, projected 3 * 10 = 30) it still beats an
        // empty slow worker (projected 1 * 45 = 45) — the by-count order
        // would have picked the empty one.
        let load_ms = |w: usize| if w == 0 { 10.0 } else { 45.0 };
        let mut m = SlotMap::new(6, 2);
        let moves = m.fail_with(1, |_, _| true, load_ms);
        assert_eq!(moves, vec![(0, 1, 0)]);
        assert_eq!(m.load_of(0), 2);
        let moves = m.fail_with(2, |_, _| true, load_ms);
        assert_eq!(moves, vec![(1, 0, 0)], "fast worker wins again by time");
        assert_eq!(m.load_of(0), 3);
    }

    #[test]
    fn fail_with_per_worker_feasibility_skips_incapable_classes() {
        // Per-candidate predicate: worker 0's class can never absorb a
        // second slot, worker 3's can. The slot must land on 3 even
        // though 0 and 3 tie on load.
        let feasible = |c: usize, slots: usize| match c {
            0 => slots <= 1,
            _ => slots <= 3,
        };
        let mut m = SlotMap::new(4, 2);
        let moves = m.fail_with(1, feasible, |_| 1.0);
        assert_eq!(moves, vec![(0, 1, 2)], "first feasible-by-class candidate");
        let moves = m.fail_with(3, feasible, |_| 1.0);
        assert_eq!(moves, vec![(1, 1, 2)], "worker 0 skipped: its class cannot absorb");
        assert_eq!(m.load_of(2), 3);
    }

    #[test]
    fn fail_is_idempotent_and_survivors_cover_all_slots() {
        let mut m = SlotMap::new(8, 2);
        m.fail(5, |_| true);
        assert!(m.fail(5, |_| true).is_empty(), "second failure is a no-op");
        for g in 0..m.n_groups() {
            for w in m.workers_of(g) {
                assert!(m.is_alive(w), "group {g} routed to dead worker {w}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "no surviving workers")]
    fn losing_every_worker_panics() {
        let mut m = SlotMap::new(2, 2);
        m.fail(0, |_| true);
        m.fail(1, |_| true);
    }

    #[test]
    fn route_replicated_picks_least_loaded_alive_replica() {
        use crate::coordinator::replication::Placement;
        let m = SlotMap::new(4, 2);
        let p = Placement {
            replicas: vec![vec![1, 3], vec![2]],
            load: vec![0.0, 8.0, 4.0, 2.0],
        };
        // Expert 0 is held on workers 1 and 3; 3 carries less load.
        assert_eq!(m.route_replicated(&p, 0, 0, 0), 3);
        assert_eq!(m.route_replicated(&p, 0, 1, 1), 2);
        // Load ties break by lowest worker id.
        let tied = Placement { replicas: vec![vec![3, 1]], load: vec![0.0; 4] };
        assert_eq!(m.route_replicated(&tied, 0, 0, 0), 1);
    }

    #[test]
    fn route_replicated_falls_back_past_dead_or_missing_hosts() {
        use crate::coordinator::replication::Placement;
        let mut m = SlotMap::new(4, 2);
        let p = Placement { replicas: vec![vec![3]], load: vec![0.0, 0.0, 0.0, 9.0] };
        assert_eq!(m.route_replicated(&p, 0, 1, 0), 3, "alive replica wins");
        m.fail(3, |_| true);
        // All replica hosts dead -> the slot's default host.
        assert_eq!(m.route_replicated(&p, 0, 1, 0), m.worker_for(0, 1));
        // Expert the placement does not cover -> default host too.
        assert_eq!(m.route_replicated(&p, 2, 0, 7), m.worker_for(2, 0));
    }
}
