//! Prediction-driven expert replication (paper §1, Benefit 3): in a data
//! center serving many concurrent sequences, SEP's lookahead gives the
//! per-expert demand for upcoming layers, which can drive on-demand
//! replica placement to balance worker load (the paper cites Grace-MoE's
//! replication as the proven mechanism this would feed).
//!
//! Implementation: greedy largest-demand-first placement with demand
//! splitting — an expert whose predicted demand exceeds the ideal
//! per-worker share is replicated and its demand divided across replicas.

use std::collections::BTreeMap;

/// Predicted demand for one layer: tokens routed to each expert.
pub type Demand = Vec<usize>;

/// A placement: for each expert, the workers holding a replica.
#[derive(Debug, Clone)]
pub struct Placement {
    pub replicas: Vec<Vec<usize>>,
    /// Load (token count) per worker under this placement.
    pub load: Vec<f64>,
}

impl Placement {
    pub fn max_load(&self) -> f64 {
        self.load.iter().cloned().fold(0.0, f64::max)
    }

    /// Load imbalance: max / mean (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let total: f64 = self.load.iter().sum();
        if total == 0.0 {
            return 1.0;
        }
        let mean = total / self.load.len() as f64;
        self.max_load() / mean
    }

    /// Total expert-replica slots used (memory cost of replication).
    pub fn replica_count(&self) -> usize {
        self.replicas.iter().map(|r| r.len()).sum()
    }
}

/// Baseline: one replica per expert, round-robin over workers (the
/// decode-stage assignment OD-MoE's edge deployment uses).
pub fn place_single(demand: &Demand, n_workers: usize) -> Placement {
    let mut load = vec![0f64; n_workers];
    let mut replicas = vec![Vec::new(); demand.len()];
    for (e, &d) in demand.iter().enumerate() {
        let w = e % n_workers;
        replicas[e].push(w);
        load[w] += d as f64;
    }
    Placement { replicas, load }
}

/// Prediction-driven replication: greedy placement with demand splitting.
///
/// `max_replicas_per_expert` bounds the memory cost; demand above the
/// ideal share `total/n_workers` triggers additional replicas.
pub fn place_replicated(
    demand: &Demand,
    n_workers: usize,
    max_replicas_per_expert: usize,
) -> Placement {
    let total: f64 = demand.iter().map(|&d| d as f64).sum();
    let ideal = (total / n_workers as f64).max(1.0);
    let mut load = vec![0f64; n_workers];
    let mut replicas = vec![Vec::new(); demand.len()];

    // Largest demand first.
    let mut order: Vec<usize> = (0..demand.len()).collect();
    order.sort_by(|&a, &b| demand[b].cmp(&demand[a]).then(a.cmp(&b)));

    for e in order {
        let d = demand[e] as f64;
        if d == 0.0 {
            // Still place one replica (the expert may be needed next layer).
            let w = argmin(&load);
            replicas[e].push(w);
            continue;
        }
        let n_rep = ((d / ideal).ceil() as usize).clamp(1, max_replicas_per_expert);
        let share = d / n_rep as f64;
        let mut used = BTreeMap::new();
        for _ in 0..n_rep {
            // Least-loaded worker not already holding this expert.
            let w = (0..n_workers)
                .filter(|w| !used.contains_key(w))
                .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                .unwrap_or_else(|| argmin(&load));
            used.insert(w, ());
            replicas[e].push(w);
            load[w] += share;
        }
    }
    Placement { replicas, load }
}

fn argmin(v: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x < v[best] {
            best = i;
        }
    }
    best
}

/// Aggregate predicted demand over a batch of per-sequence routes for one
/// layer (each route = that sequence's top-k experts).
pub fn demand_from_routes(routes: &[Vec<usize>], n_experts: usize) -> Demand {
    let mut d = vec![0usize; n_experts];
    for r in routes {
        for &e in r {
            d[e] += 1;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demand_aggregation() {
        let routes = vec![vec![0, 1], vec![0, 2], vec![0, 1]];
        assert_eq!(demand_from_routes(&routes, 4), vec![3, 2, 1, 0]);
    }

    #[test]
    fn replication_reduces_imbalance_under_skew() {
        // One ultra-hot expert: single placement pins all its load on one
        // worker; replication splits it.
        let demand = vec![64, 2, 2, 2, 2, 2, 2, 2];
        let single = place_single(&demand, 8);
        let repl = place_replicated(&demand, 8, 8);
        assert!(repl.imbalance() < single.imbalance());
        assert!(repl.max_load() < single.max_load());
    }

    #[test]
    fn uniform_demand_needs_no_replicas() {
        let demand = vec![4; 8];
        let repl = place_replicated(&demand, 8, 8);
        assert_eq!(repl.replica_count(), 8, "no replication when balanced");
        assert!((repl.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn replica_budget_is_respected() {
        let demand = vec![1000, 0, 0, 0, 0, 0, 0, 0];
        let repl = place_replicated(&demand, 8, 3);
        assert!(repl.replicas[0].len() <= 3);
        // Replicas of one expert land on distinct workers.
        let mut ws = repl.replicas[0].clone();
        ws.sort_unstable();
        ws.dedup();
        assert_eq!(ws.len(), repl.replicas[0].len());
    }

    #[test]
    fn every_expert_gets_at_least_one_replica() {
        let demand = vec![10, 0, 5, 0, 0, 0, 1, 0];
        let repl = place_replicated(&demand, 4, 2);
        assert!(repl.replicas.iter().all(|r| !r.is_empty()));
    }

    #[test]
    fn greedy_bound_holds_for_random_demands() {
        // Soundness of the greedy splitter: every placed share is <= the
        // ideal per-worker load and lands on the least-loaded worker, so
        // max load <= 2 * ideal (classic list-scheduling bound). Under
        // heavy skew it additionally beats single placement (next test);
        // near-uniform demand with E == W the tailored one-per-worker map
        // can win slightly, which is fine — replication is for skew.
        crate::util::prop::check("replicated max load <= 2*ideal", 64, 99, |rng| {
            let n_experts = 8;
            let n_workers = 8;
            let demand: Demand = (0..n_experts).map(|_| rng.below(50)).collect();
            let total: f64 = demand.iter().map(|&d| d as f64).sum();
            let ideal = (total / n_workers as f64).max(1.0);
            let repl = place_replicated(&demand, n_workers, n_workers);
            if repl.max_load() > 2.0 * ideal + 1e-9 {
                return Err(format!(
                    "max load {} > 2*ideal {} for {demand:?}",
                    repl.max_load(),
                    2.0 * ideal
                ));
            }
            Ok(())
        });
    }

    // ---- Placement invariants (DESIGN.md §15) ----------------------
    //
    // The SLO control loop consumes placements live (replication plans
    // between epochs, `SlotMap::route_replicated` per route), so the
    // four invariants below are what the controller is allowed to
    // assume without re-checking.

    #[test]
    fn invariant_every_expert_is_placed_on_valid_distinct_workers() {
        crate::util::prop::check("placement covers every expert", 64, 7, |rng| {
            let n_workers = 2 + rng.below(7);
            let n_experts = 1 + rng.below(12);
            let demand: Demand = (0..n_experts).map(|_| rng.below(40)).collect();
            let max_rep = 1 + rng.below(n_workers);
            let p = place_replicated(&demand, n_workers, max_rep);
            for (e, hosts) in p.replicas.iter().enumerate() {
                if hosts.is_empty() {
                    return Err(format!("expert {e} unplaced for {demand:?}"));
                }
                let mut ws = hosts.clone();
                ws.sort_unstable();
                ws.dedup();
                if ws.len() != hosts.len() || ws.iter().any(|&w| w >= n_workers) {
                    return Err(format!("expert {e}: bad hosts {hosts:?} ({n_workers} workers)"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invariant_demand_is_conserved_across_split_replicas() {
        crate::util::prop::check("split shares sum to total demand", 64, 11, |rng| {
            let n_workers = 2 + rng.below(7);
            let demand: Demand = (0..1 + rng.below(12)).map(|_| rng.below(40)).collect();
            let total: f64 = demand.iter().map(|&d| d as f64).sum();
            let p = place_replicated(&demand, n_workers, 1 + rng.below(n_workers));
            let placed: f64 = p.load.iter().sum();
            if (placed - total).abs() > 1e-9 {
                return Err(format!("placed load {placed} != demand {total} for {demand:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn invariant_imbalance_is_at_least_one() {
        crate::util::prop::check("imbalance >= 1.0 (max >= mean)", 64, 13, |rng| {
            let n_workers = 2 + rng.below(7);
            let demand: Demand = (0..1 + rng.below(12)).map(|_| rng.below(40)).collect();
            for p in [
                place_single(&demand, n_workers),
                place_replicated(&demand, n_workers, 1 + rng.below(n_workers)),
            ] {
                if p.imbalance() < 1.0 - 1e-9 {
                    return Err(format!("imbalance {} < 1 for {demand:?}", p.imbalance()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invariant_replica_count_is_monotone_in_demand_skew() {
        // Fixed total demand (64) over 8 experts, 4 workers: as the
        // share of the hottest expert grows the splitter can only add
        // replicas, never remove them.
        let n_workers = 4;
        let mut prev = 0usize;
        for hot in [8usize, 16, 24, 32, 48, 57] {
            let rest = (64 - hot) / 7;
            let mut demand: Demand = vec![rest; 8];
            demand[0] = hot + (64 - hot - rest * 7); // keep the total at 64
            assert_eq!(demand.iter().sum::<usize>(), 64);
            let p = place_replicated(&demand, n_workers, n_workers);
            assert!(
                p.replica_count() >= prev,
                "replicas dropped {} -> {} at hot={hot} ({demand:?})",
                prev,
                p.replica_count()
            );
            prev = p.replica_count();
        }
        assert!(prev > 8, "the skew ladder must end replicated");
    }

    #[test]
    fn beats_single_placement_under_heavy_skew() {
        crate::util::prop::check("replication wins under skew", 32, 101, |rng| {
            let n_workers = 8;
            // One dominant expert (>= half the traffic).
            let mut demand: Demand = (0..8).map(|_| rng.below(8)).collect();
            demand[rng.below(8)] = 64 + rng.below(64);
            let single = place_single(&demand, n_workers);
            let repl = place_replicated(&demand, n_workers, n_workers);
            if repl.max_load() >= single.max_load() {
                return Err(format!(
                    "replicated {} !< single {} for {demand:?}",
                    repl.max_load(),
                    single.max_load()
                ));
            }
            Ok(())
        });
    }
}
