//! Baseline expert-activation predictors (paper §2.3 / Table 1).
//!
//! * [`GateLookahead`] — AdapMoE/DAOP/Mixtral-Offloading family: feed the
//!   current layer's hidden state to the *next* layer's gating network.
//! * [`MultiLayerGate`] — HOBBIT family: chain the same hidden through the
//!   gates of the next `depth` layers at once.
//! * [`Statistical`] — EdgeMoE/fMoE family: per-layer expert popularity
//!   from observed history.
//! * [`RandomPredictor`] — the Fig. 8 Case-5 control (random prefetch).

use super::math::{matvec, rms_norm, topk_idx};
use super::Predictor;
use crate::engine::Route;
use crate::model::rng::Rng;
use crate::model::WeightStore;

/// Next-layer gate lookahead (AdapMoE-style, recall ≈ 0.86 in Table 1).
pub struct GateLookahead {
    /// (ffn_norm gain, w_gate) per layer, host copies.
    gates: Vec<(Vec<f32>, Vec<f32>)>,
    n_experts: usize,
    top_k: usize,
    eps: f32,
    /// predictions[l] for the current token.
    predictions: Vec<Option<Vec<usize>>>,
}

impl GateLookahead {
    pub fn new(ws: &WeightStore) -> Self {
        Self {
            gates: ws
                .layers
                .iter()
                .map(|l| (l.ffn_norm.clone(), l.w_gate.clone()))
                .collect(),
            n_experts: ws.cfg.n_experts,
            top_k: ws.cfg.top_k,
            eps: ws.cfg.rms_eps as f32,
            predictions: vec![None; ws.cfg.n_layers],
        }
    }
}

impl Predictor for GateLookahead {
    fn name(&self) -> &'static str {
        "gate-lookahead"
    }

    fn begin_token(&mut self, _token: u32) {
        self.predictions.fill(None);
    }

    fn predict(&mut self, layer: usize) -> Option<Vec<usize>> {
        self.predictions[layer].clone()
    }

    fn observe(&mut self, layer: usize, x_resid: &[f32], _h_norm: &[f32], _route: &Route) {
        // Feed this layer's residual into the NEXT layer's gate.
        if layer + 1 < self.gates.len() {
            let (g, wg) = &self.gates[layer + 1];
            let h = rms_norm(x_resid, g, self.eps);
            let logits = matvec(&h, wg, self.n_experts);
            self.predictions[layer + 1] = Some(topk_idx(&logits, self.top_k));
        }
    }

    fn lookahead(&self) -> usize {
        1
    }
}

/// HOBBIT-style multi-layer gate chaining (recall ≈ 0.91 up to 4 ahead).
pub struct MultiLayerGate {
    gates: Vec<(Vec<f32>, Vec<f32>)>,
    n_experts: usize,
    top_k: usize,
    eps: f32,
    depth: usize,
    predictions: Vec<Option<Vec<usize>>>,
}

impl MultiLayerGate {
    pub fn new(ws: &WeightStore, depth: usize) -> Self {
        Self {
            gates: ws
                .layers
                .iter()
                .map(|l| (l.ffn_norm.clone(), l.w_gate.clone()))
                .collect(),
            n_experts: ws.cfg.n_experts,
            top_k: ws.cfg.top_k,
            eps: ws.cfg.rms_eps as f32,
            depth,
            predictions: vec![None; ws.cfg.n_layers],
        }
    }
}

impl Predictor for MultiLayerGate {
    fn name(&self) -> &'static str {
        "multi-layer-gate"
    }

    fn begin_token(&mut self, _token: u32) {
        self.predictions.fill(None);
    }

    fn predict(&mut self, layer: usize) -> Option<Vec<usize>> {
        self.predictions[layer].clone()
    }

    fn observe(&mut self, layer: usize, x_resid: &[f32], _h_norm: &[f32], _route: &Route) {
        // Apply the gates of layers l+1..l+depth to this hidden state.
        for j in 1..=self.depth {
            let target = layer + j;
            if target >= self.gates.len() {
                break;
            }
            let (g, wg) = &self.gates[target];
            let h = rms_norm(x_resid, g, self.eps);
            let logits = matvec(&h, wg, self.n_experts);
            self.predictions[target] = Some(topk_idx(&logits, self.top_k));
        }
    }

    fn lookahead(&self) -> usize {
        self.depth
    }
}

/// Frequency-based prediction from observed history (EdgeMoE/fMoE family).
pub struct Statistical {
    /// counts[layer][expert].
    counts: Vec<Vec<u64>>,
    top_k: usize,
}

impl Statistical {
    pub fn new(n_layers: usize, n_experts: usize, top_k: usize) -> Self {
        Self { counts: vec![vec![0; n_experts]; n_layers], top_k }
    }
}

impl Predictor for Statistical {
    fn name(&self) -> &'static str {
        "statistical"
    }

    fn begin_token(&mut self, _token: u32) {}

    fn predict(&mut self, layer: usize) -> Option<Vec<usize>> {
        let c = &self.counts[layer];
        if c.iter().all(|&x| x == 0) {
            return None;
        }
        let as_f: Vec<f32> = c.iter().map(|&x| x as f32).collect();
        Some(topk_idx(&as_f, self.top_k))
    }

    fn observe(&mut self, layer: usize, _x: &[f32], _h: &[f32], route: &Route) {
        for &e in &route.experts {
            self.counts[layer][e] += 1;
        }
    }

    fn lookahead(&self) -> usize {
        usize::MAX // history-based: available for any layer at any time
    }
}

/// Random prefetch (ablation Case 5). Expected recall = k / E.
pub struct RandomPredictor {
    rng: Rng,
    n_experts: usize,
    top_k: usize,
}

impl RandomPredictor {
    pub fn new(seed: u64, n_experts: usize, top_k: usize) -> Self {
        Self { rng: Rng::new(seed), n_experts, top_k }
    }
}

impl Predictor for RandomPredictor {
    fn name(&self) -> &'static str {
        "random"
    }

    fn begin_token(&mut self, _token: u32) {}

    fn predict(&mut self, _layer: usize) -> Option<Vec<usize>> {
        let mut picks = Vec::with_capacity(self.top_k);
        while picks.len() < self.top_k {
            let e = self.rng.below(self.n_experts);
            if !picks.contains(&e) {
                picks.push(e);
            }
        }
        Some(picks)
    }

    fn observe(&mut self, _l: usize, _x: &[f32], _h: &[f32], _r: &Route) {}

    fn lookahead(&self) -> usize {
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn ws() -> WeightStore {
        WeightStore::generate(&ModelConfig::default(), 3)
    }

    fn route(experts: Vec<usize>) -> Route {
        let k = experts.len();
        Route { experts, weights: vec![1.0 / k as f32; k] }
    }

    #[test]
    fn gate_lookahead_predicts_only_next_layer() {
        let w = ws();
        let mut p = GateLookahead::new(&w);
        p.begin_token(0);
        assert_eq!(p.predict(0), None, "no prediction for layer 0");
        let x = vec![0.1f32; 64];
        p.observe(0, &x, &x, &route(vec![1, 2]));
        assert!(p.predict(1).is_some());
        assert_eq!(p.predict(2), None);
        // New token clears state.
        p.begin_token(1);
        assert_eq!(p.predict(1), None);
    }

    #[test]
    fn multi_layer_gate_predicts_depth_layers() {
        let w = ws();
        let mut p = MultiLayerGate::new(&w, 4);
        p.begin_token(0);
        let x = vec![0.1f32; 64];
        p.observe(0, &x, &x, &route(vec![1, 2]));
        for l in 1..=4 {
            assert!(p.predict(l).is_some(), "layer {l}");
        }
        assert_eq!(p.predict(5), None);
    }

    #[test]
    fn statistical_learns_popularity() {
        let mut p = Statistical::new(2, 4, 2);
        assert_eq!(p.predict(0), None, "cold start");
        for _ in 0..5 {
            p.observe(0, &[], &[], &route(vec![3, 1]));
        }
        p.observe(0, &[], &[], &route(vec![2, 1]));
        let pred = p.predict(0).unwrap();
        assert!(pred.contains(&1) && pred.contains(&3), "{pred:?}");
    }

    #[test]
    fn random_predicts_distinct_valid_experts() {
        let mut p = RandomPredictor::new(1, 8, 2);
        for _ in 0..50 {
            let pred = p.predict(0).unwrap();
            assert_eq!(pred.len(), 2);
            assert_ne!(pred[0], pred[1]);
            assert!(pred.iter().all(|&e| e < 8));
        }
    }

    #[test]
    fn predictions_are_valid_expert_sets() {
        let w = ws();
        let mut p = GateLookahead::new(&w);
        p.begin_token(0);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin()).collect();
        p.observe(0, &x, &x, &route(vec![0, 1]));
        let pred = p.predict(1).unwrap();
        assert_eq!(pred.len(), 2);
        assert_ne!(pred[0], pred[1]);
        assert!(pred.iter().all(|&e| e < 8));
    }
}
