//! Expert-activation predictors: SEP (the paper's contribution) and the
//! baseline families it is compared against in Table 1.
//!
//! Baselines implement [`Predictor`]: they observe the main model's
//! per-layer activations as decoding progresses and emit per-layer expert
//! predictions. SEP ([`sep::SepPredictor`]) has a wider interface because
//! it owns a whole shadow model and participates in alignment.

pub mod baseline;
pub mod math;
pub mod sep;

pub use baseline::{GateLookahead, MultiLayerGate, RandomPredictor, Statistical};
pub use sep::{AlignPeriod, AlignmentConfig, SepPredictor};

use crate::engine::Route;

/// A lookahead expert-activation predictor (baseline families §2.3).
///
/// Protocol per decode iteration:
/// 1. `begin_token(input_token)`;
/// 2. for each layer `l` (in order): the engine asks `predict(l)` *before*
///    the main model runs layer `l`, then calls
///    `observe(l, x_resid, h_norm, route)` with the actual outcome.
pub trait Predictor {
    fn name(&self) -> &'static str;

    fn begin_token(&mut self, token: u32);

    /// Predicted expert set for `layer` of the current token, or `None`
    /// if this predictor has nothing yet (e.g. lookahead depth not
    /// reached, no history).
    fn predict(&mut self, layer: usize) -> Option<Vec<usize>>;

    /// Observe the actual activations after the main model's gate ran.
    /// `x_resid` is the post-attention residual stream, `h_norm` the
    /// normalized hidden the gate consumed.
    fn observe(&mut self, layer: usize, x_resid: &[f32], h_norm: &[f32], route: &Route);

    /// How many layers ahead of the observed layer this predictor can
    /// predict (1 for next-layer heuristics, 4 for HOBBIT-style, the full
    /// model depth for SEP).
    fn lookahead(&self) -> usize;
}
