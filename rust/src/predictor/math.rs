//! Small host-side f32 math used by the *baseline* predictors.
//!
//! These heuristics (gate lookahead, chained gates) are control-plane
//! estimators in the original systems, not model computation — they run on
//! host here exactly as the paper's baselines run them beside the model.
//! All real model numerics go through the PJRT artifacts.

/// RMSNorm over `x` with gain `g` (matches the model's norm).
pub fn rms_norm(x: &[f32], g: &[f32], eps: f32) -> Vec<f32> {
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    x.iter().zip(g).map(|(v, gi)| v * inv * gi).collect()
}

/// `x [d] @ w [d, out]` row-major.
pub fn matvec(x: &[f32], w: &[f32], out: usize) -> Vec<f32> {
    let d = x.len();
    debug_assert_eq!(w.len(), d * out);
    let mut y = vec![0f32; out];
    for (i, &xi) in x.iter().enumerate() {
        let row = &w[i * out..(i + 1) * out];
        for j in 0..out {
            y[j] += xi * row[j];
        }
    }
    y
}

/// Indices of the top-k values (first-occurrence tie-break, matching the
/// model's `topk_small`).
pub fn topk_idx(v: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_small() {
        // x=[1,2], w=[[1,0],[0,1]] -> [1,2]
        assert_eq!(matvec(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], 2), vec![1.0, 2.0]);
    }

    #[test]
    fn topk_orders_desc_and_breaks_ties_low_index() {
        assert_eq!(topk_idx(&[0.1, 0.9, 0.9, 0.5], 2), vec![1, 2]);
        assert_eq!(topk_idx(&[3.0, 1.0, 2.0], 2), vec![0, 2]);
    }

    #[test]
    fn rms_norm_unit_scale() {
        let x = vec![2.0f32; 4];
        let g = vec![1.0f32; 4];
        let y = rms_norm(&x, &g, 1e-5);
        for v in y {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }
}
