//! SEP — Scaled Emulative Prediction (the paper's §2.3/§3.2 contribution).
//!
//! A quantized *shadow* replica of the model decodes the same sequence a
//! few layers ahead of the full-precision model; its router decisions are
//! the predictions. Two alignment mechanisms stop autoregressive drift:
//!
//! * **token alignment** — the shadow adopts the main model's generated
//!   token (instead of its own) every `token_period` iterations;
//! * **KV alignment** — the shadow's KV caches are overwritten with the
//!   main model's every `kv_period` iterations.
//!
//! Numerics are real: the shadow is a [`ModelState`] over fake-quantized
//! weights executing the same AOT artifacts. The *timing* consequences
//! (late departure, Fig. 5) are handled by the OD-MoE engine using
//! [`SepPredictor::alignment_delay_ms`].

use anyhow::Result;

use crate::cluster::{HardwareProfile, Ms};
use crate::engine::{ModelState, Route};
use crate::model::{Precision, WeightStore};
use crate::runtime::Runtime;

/// How often one alignment mechanism fires, in decode iterations. A
/// typed period instead of the old `usize::MAX` sentinel: "disabled" is
/// a variant the compiler can see, not a magic value every consumer must
/// remember to test for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlignPeriod {
    /// Align every `n` decode iterations (`n >= 1`).
    Every(usize),
    /// Alignment disabled.
    Never,
}

impl AlignPeriod {
    /// Does alignment fire on this (0-based) decode iteration?
    pub fn due(self, iteration: usize) -> bool {
        match self {
            AlignPeriod::Every(n) => n > 0 && iteration % n == 0,
            AlignPeriod::Never => false,
        }
    }

    /// Short label for engine names and tables (`∞` when disabled).
    pub fn label(self) -> String {
        match self {
            AlignPeriod::Every(n) => n.to_string(),
            AlignPeriod::Never => "∞".into(),
        }
    }
}

/// Alignment periods in decode iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlignmentConfig {
    pub token_period: AlignPeriod,
    pub kv_period: AlignPeriod,
}

impl AlignmentConfig {
    /// The paper's best configuration on the 3090 testbed (T1_KV1).
    pub fn every_iteration() -> Self {
        Self { token_period: AlignPeriod::Every(1), kv_period: AlignPeriod::Every(1) }
    }

    pub fn none() -> Self {
        Self { token_period: AlignPeriod::Never, kv_period: AlignPeriod::Never }
    }

    pub fn token_only() -> Self {
        Self { token_period: AlignPeriod::Every(1), kv_period: AlignPeriod::Never }
    }

    pub fn kv_only() -> Self {
        Self { token_period: AlignPeriod::Never, kv_period: AlignPeriod::Every(1) }
    }
}

/// The shadow-model predictor.
pub struct SepPredictor<'rt> {
    pub shadow: ModelState<'rt>,
    pub align: AlignmentConfig,
    pub precision: Precision,
    iteration: usize,
    /// Shadow's own previous output token (its divergent stream).
    own_prev: Option<u32>,
    /// Shadow routes for the current iteration (one per layer).
    routes: Vec<Route>,
    /// Whether alignment happened at the start of the current iteration.
    pub aligned_token: bool,
    pub aligned_kv: bool,
}

impl<'rt> SepPredictor<'rt> {
    /// Build the shadow from the full-precision store, quantized at `p`.
    pub fn new(
        rt: &'rt Runtime,
        full: &WeightStore,
        p: Precision,
        align: AlignmentConfig,
    ) -> Result<Self> {
        let shadow = ModelState::new(rt, full.quantized(p))?;
        Ok(Self {
            shadow,
            align,
            precision: p,
            iteration: 0,
            own_prev: None,
            routes: Vec::new(),
            aligned_token: false,
            aligned_kv: false,
        })
    }

    /// Prefill the shadow with the prompt (it mirrors the main model's
    /// prefill so decode-stage emulation starts from the same context).
    pub fn prefill(&mut self, prompt: &[u32]) -> Result<()> {
        let rec = self.shadow.prefill(prompt)?;
        self.own_prev = Some(rec.token_out);
        Ok(())
    }

    /// Run the shadow for one decode iteration.
    ///
    /// `main` is the full-precision model state *before* it decodes this
    /// iteration (its caches hold the previous tokens — the freshest state
    /// alignment can use); `main_input` is the token the main model will
    /// decode now (its previous output / last prompt token).
    pub fn begin_token(&mut self, main: &ModelState, main_input: u32) -> Result<()> {
        self.aligned_token = self.align.token_period.due(self.iteration);
        self.aligned_kv = self.align.kv_period.due(self.iteration);
        if self.aligned_kv {
            self.shadow.align_kv_from(main);
        }
        let token = if self.aligned_token {
            main_input
        } else {
            self.own_prev.unwrap_or(main_input)
        };
        let rec = self.shadow.decode_step(token)?;
        self.own_prev = Some(rec.token_out);
        self.routes = rec.routes;
        self.iteration += 1;
        Ok(())
    }

    /// Predicted experts for `layer` of the current iteration.
    pub fn predict(&self, layer: usize) -> &Route {
        &self.routes[layer]
    }

    pub fn routes(&self) -> &[Route] {
        &self.routes
    }

    /// Alignment payload bytes for the current iteration: KV alignment
    /// ships the newly generated token's KV rows for every layer; token
    /// alignment ships the token id. Batched decode sums this over
    /// co-scheduled sessions to price one combined late-departure message.
    pub fn alignment_bytes(&self, p: &HardwareProfile) -> f64 {
        let mut bytes = 0.0;
        if self.aligned_kv {
            bytes += p.kv_align_bytes;
        }
        if self.aligned_token {
            bytes += p.token_msg_bytes;
        }
        bytes
    }

    /// Extra LAN delay before the shadow node can start this iteration
    /// (the Fig. 5 "late departure" input), from [`Self::alignment_bytes`].
    pub fn alignment_delay_ms(&self, p: &HardwareProfile) -> Ms {
        let bytes = self.alignment_bytes(p);
        if bytes == 0.0 {
            0.0
        } else {
            p.lan_lat_ms + p.lan_transfer_ms(bytes)
        }
    }

    /// Reset for a fresh request.
    pub fn reset(&mut self) {
        self.shadow.reset();
        self.iteration = 0;
        self.own_prev = None;
        self.routes.clear();
        self.aligned_token = false;
        self.aligned_kv = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn due_periods() {
        assert!(AlignPeriod::Every(1).due(0));
        assert!(AlignPeriod::Every(1).due(5));
        assert!(AlignPeriod::Every(4).due(8));
        assert!(!AlignPeriod::Every(4).due(9));
        assert!(!AlignPeriod::Never.due(0));
        assert!(!AlignPeriod::Every(0).due(0), "degenerate period never fires");
        assert_eq!(AlignPeriod::Every(16).label(), "16");
        assert_eq!(AlignPeriod::Never.label(), "∞");
    }

    #[test]
    fn presets() {
        let e = AlignmentConfig::every_iteration();
        let one = AlignPeriod::Every(1);
        assert_eq!((e.token_period, e.kv_period), (one, one));
        let n = AlignmentConfig::none();
        assert_eq!(n.token_period, AlignPeriod::Never);
        assert_eq!(AlignmentConfig::token_only().kv_period, AlignPeriod::Never);
        assert_eq!(AlignmentConfig::kv_only().token_period, AlignPeriod::Never);
    }
}
