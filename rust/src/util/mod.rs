//! In-tree replacements for crates unavailable in this offline image:
//! a minimal JSON parser/writer (`json`), a flag-style CLI parser (`cli`),
//! a micro-bench harness (`bench`), and a property-test driver (`prop`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod table;
