//! Minimal JSON parser + writer (serde_json is not available offline).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! the BMP only). Parsing is recursive-descent over bytes; numbers are f64
//! (all values this repo reads — checks.json, config.json — fit exactly).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Array of numbers -> Vec<f64> (fast path for checks.json payloads).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`to_string()` comes with it).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Build a [`Json::Obj`] from `(key, value)` pairs (duplicate keys keep
/// the last value, matching JSON object semantics).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A [`Json::Num`] rounded to 1e-6 so artifact files stay byte-stable
/// across platforms (last-digit FP noise would otherwise leak into the
/// committed BENCH_*.json diffs).
pub fn num(v: f64) -> Json {
    Json::Num((v * 1e6).round() / 1e6)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"config":{"d":64,"eps":1e-5},"list":[1,2.5,"x",false,null]}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn obj_and_num_builders() {
        let v = obj(vec![("a", num(1.0000000004)), ("b", Json::Str("x".into()))]);
        assert_eq!(v.to_string(), r#"{"a":1,"b":"x"}"#, "num rounds to 1e-6");
        assert_eq!(num(0.1234567).to_string(), "0.123457");
    }

    #[test]
    fn big_number_array() {
        let arr: Vec<String> = (0..10_000).map(|i| format!("{}.5", i)).collect();
        let src = format!("[{}]", arr.join(","));
        let v = Json::parse(&src).unwrap();
        let nums = v.as_f64_vec().unwrap();
        assert_eq!(nums.len(), 10_000);
        assert_eq!(nums[9_999], 9999.5);
    }
}
