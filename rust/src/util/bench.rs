//! Micro-bench harness (criterion is not available offline).
//!
//! `Bench::run` warms up, then samples wall-clock over batched iterations
//! and reports mean / p50 / p95 per iteration. Figure/table benches use
//! [`crate::util::table`] for paper-style output instead; this harness is
//! for the L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf).

use std::time::Instant;

/// One benchmark's timing summary (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95"
    );
    println!("{}", "-".repeat(84));
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` over `samples` batches of `iters_per_sample` iterations.
pub fn run<F: FnMut()>(name: &str, samples: usize, iters_per_sample: usize, mut f: F) -> Summary {
    // Warm-up.
    for _ in 0..iters_per_sample.min(3) {
        f();
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let p = |q: f64| crate::metrics::percentile_sorted(&per_iter, q);
    Summary {
        name: name.to_string(),
        mean_ns: mean,
        p50_ns: p(0.5),
        p95_ns: p(0.95),
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut x = 0u64;
        let s = run("spin", 5, 100, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert_eq!(s.samples, 5);
    }
}
