//! Micro-bench harness (criterion is not available offline).
//!
//! `Bench::run` warms up, then samples wall-clock over batched iterations
//! and reports mean / p50 / p95 per iteration. Figure/table benches use
//! [`crate::util::table`] for paper-style output instead; this harness is
//! for the L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf).

use std::time::Instant;

use crate::util::json::{num, obj, Json};

/// One benchmark's timing summary (nanoseconds per iteration), carrying
/// the full per-invocation distribution shape (min/max/stddev alongside
/// mean/p50/p95) so `od-moe bench` can export honest wall-clock spreads
/// instead of a single point estimate.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }

    /// JSON export for `BENCH_perf.json`'s wall-clock section.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_ns", num(self.mean_ns)),
            ("p50_ns", num(self.p50_ns)),
            ("p95_ns", num(self.p95_ns)),
            ("min_ns", num(self.min_ns)),
            ("max_ns", num(self.max_ns)),
            ("stddev_ns", num(self.stddev_ns)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }
}

pub fn header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95"
    );
    println!("{}", "-".repeat(84));
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` over `samples` batches of `iters_per_sample` iterations.
pub fn run<F: FnMut()>(name: &str, samples: usize, iters_per_sample: usize, mut f: F) -> Summary {
    // Warm-up.
    for _ in 0..iters_per_sample.min(3) {
        f();
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let p = |q: f64| crate::metrics::percentile_sorted(&per_iter, q);
    Summary {
        name: name.to_string(),
        mean_ns: mean,
        p50_ns: p(0.5),
        p95_ns: p(0.95),
        min_ns: per_iter.first().copied().unwrap_or(0.0),
        max_ns: per_iter.last().copied().unwrap_or(0.0),
        stddev_ns: crate::metrics::std_dev(&per_iter),
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut x = 0u64;
        let s = run("spin", 5, 100, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert_eq!(s.samples, 5);
        assert!(s.min_ns <= s.p50_ns && s.p95_ns <= s.max_ns);
        assert!(s.stddev_ns >= 0.0 && s.stddev_ns.is_finite());
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "spin");
        assert_eq!(j.get("samples").unwrap().as_usize().unwrap(), 5);
        let (lo, hi) = (j.get("min_ns").unwrap(), j.get("max_ns").unwrap());
        assert!(lo.as_f64().unwrap() <= hi.as_f64().unwrap());
    }
}
