//! Tiny `--flag value` CLI parser (clap is not available offline).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` / `--switch` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                }
                _ => out.switches.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("recall --prompts 8 --out-tokens 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("recall"));
        assert_eq!(a.usize_or("prompts", 0).unwrap(), 8);
        assert_eq!(a.usize_or("out-tokens", 0).unwrap(), 64);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("speed");
        assert_eq!(a.usize_or("prompts", 3).unwrap(), 3);
        assert_eq!(a.f64_or("bw", 25.0).unwrap(), 25.0);
        assert_eq!(a.get_or("engine", "odmoe"), "odmoe");
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--seed 7");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(["x".into(), "y".into()]).is_err());
    }
}
