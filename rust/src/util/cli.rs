//! Tiny `--flag value` CLI parser (clap is not available offline), plus
//! the single flag table `od-moe --help` renders from.
//!
//! Usage text and flag validation share one [`CommandSpec`] table (see
//! `rust/src/main.rs`): the help section for each subcommand is
//! *generated* from the table, and [`Args::validate_against`] rejects any
//! provided flag the table does not list — so the accumulated sweep
//! flags (`--rates`, `--batch-sweep`, `--fail*`, `--chunks`,
//! `--overlap-sweep`, `--fleet`/`--plan`, …) cannot drift from the
//! parser: an undocumented flag is an error, not silence.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// One CLI flag: name (without `--`), an optional value placeholder
/// (`None` = boolean switch), and a one-line help string (conventions:
/// include the default in parentheses).
#[derive(Debug, Clone, Copy)]
pub struct Flag {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// One subcommand's row in the flag table.
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: &'static [Flag],
}

impl CommandSpec {
    /// Render this subcommand's help section.
    pub fn usage(&self) -> String {
        let mut out = format!("od-moe {:<11} {}\n", self.name, self.summary);
        for f in self.flags {
            let head = match f.value {
                Some(v) => format!("--{} {v}", f.name),
                None => format!("--{}", f.name),
            };
            out.push_str(&format!("  {head:<26} {}\n", f.help));
        }
        out
    }
}

/// Render the full `od-moe` usage text from the flag table.
pub fn render_usage(commands: &[CommandSpec], globals: &[Flag]) -> String {
    let mut out = String::from("usage: od-moe <command> [--flags]\n\n");
    for c in commands {
        out.push_str(&c.usage());
        out.push('\n');
    }
    out.push_str("global flags (any command):\n");
    for f in globals {
        let head = match f.value {
            Some(v) => format!("--{} {v}", f.name),
            None => format!("--{}", f.name),
        };
        out.push_str(&format!("  {head:<26} {}\n", f.help));
    }
    out
}

/// Parsed command line: a subcommand plus `--key value` / `--switch` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument {a:?}");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                }
                _ => out.switches.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    /// Every flag/switch name the user provided (deduplicated order not
    /// guaranteed; used for table validation).
    pub fn provided(&self) -> impl Iterator<Item = &str> {
        self.flags.keys().map(|s| s.as_str()).chain(self.switches.iter().map(|s| s.as_str()))
    }

    /// Reject any provided flag that is neither in `cmd`'s table row nor
    /// a global — the mechanism that keeps usage text and parser in
    /// lockstep (a flag added to the code without a table entry fails
    /// loudly on first use).
    pub fn validate_against(&self, cmd: &CommandSpec, globals: &[Flag]) -> Result<()> {
        for name in self.provided() {
            let known = cmd.flags.iter().chain(globals).any(|f| f.name == name);
            if !known {
                bail!(
                    "unknown flag --{name} for `od-moe {}` (run `od-moe help` for the flag table)",
                    cmd.name
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("recall --prompts 8 --out-tokens 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("recall"));
        assert_eq!(a.usize_or("prompts", 0).unwrap(), 8);
        assert_eq!(a.usize_or("out-tokens", 0).unwrap(), 64);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("speed");
        assert_eq!(a.usize_or("prompts", 3).unwrap(), 3);
        assert_eq!(a.f64_or("bw", 25.0).unwrap(), 25.0);
        assert_eq!(a.get_or("engine", "odmoe"), "odmoe");
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--seed 7");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(["x".into(), "y".into()]).is_err());
    }

    const TEST_CMD: CommandSpec = CommandSpec {
        name: "demo",
        summary: "a test command",
        flags: &[
            Flag { name: "prompts", value: Some("N"), help: "prompt count (default 8)" },
            Flag { name: "verbose", value: None, help: "chatty output" },
        ],
    };
    const TEST_GLOBALS: &[Flag] =
        &[Flag { name: "seed", value: Some("N"), help: "deterministic seed" }];

    #[test]
    fn validate_against_accepts_table_flags_and_rejects_strays() {
        let ok = parse("demo --prompts 4 --verbose --seed 7");
        ok.validate_against(&TEST_CMD, TEST_GLOBALS).unwrap();
        let bad = parse("demo --prompst 4");
        let err = bad.validate_against(&TEST_CMD, TEST_GLOBALS).unwrap_err();
        assert!(err.to_string().contains("--prompst"), "{err}");
        assert!(err.to_string().contains("demo"), "{err}");
    }

    #[test]
    fn usage_renders_every_table_row() {
        let text = render_usage(&[TEST_CMD], TEST_GLOBALS);
        assert!(text.contains("od-moe demo"), "{text}");
        assert!(text.contains("--prompts N"), "{text}");
        assert!(text.contains("--verbose"), "{text}");
        assert!(text.contains("prompt count (default 8)"), "{text}");
        assert!(text.contains("global flags"), "{text}");
        assert!(text.contains("--seed N"), "{text}");
    }
}
