//! Paper-style table/series printing for the figure and table benches —
//! every bench prints the same rows/columns the paper reports, plus a
//! `paper:` reference line so shape comparisons are one `diff` away.

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            widths: headers.iter().map(|h| h.len().max(8)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:<w$}"))
            .collect();
        println!("{}", line.join("  "));
        println!(
            "{}",
            self.widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

/// Print an (x, y) series as a compact aligned block (figure data).
pub fn print_series(title: &str, xs: &[f64], ys: &[f64]) {
    println!("# {title}");
    for (x, y) in xs.iter().zip(ys) {
        println!("{x:>10.3}  {y:>12.6}");
    }
}

/// Render a unicode sparkline for quick visual shape checks in the logs.
pub fn sparkline(ys: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if ys.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &y in ys {
        lo = lo.min(y);
        hi = hi.max(y);
    }
    let span = (hi - lo).max(1e-12);
    ys.iter()
        .map(|&y| BARS[(((y - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "hello".into()]);
        t.row(&["2".into(), "world".into()]);
        t.print(); // smoke: no panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
        let chars: Vec<char> = s.chars().collect();
        assert!(chars[0] < chars[2]);
    }
}
