//! Property-test driver (proptest is not available offline).
//!
//! `check` runs a property over `n` randomized cases from a seeded
//! [`crate::model::rng::Rng`]; on failure it reports the case index and
//! seed so the case replays deterministically. Coordinator invariants
//! (routing, batching, scheduling) use this throughout `rust/tests/`.

use crate::model::rng::Rng;

/// Run `prop` over `n` random cases. Panics with the failing case's seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, n: usize, base_seed: u64, mut prop: F) {
    for case in 0..n {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("uniform in range", 50, 1, |rng| {
            let v = rng.uniform();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range: {v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn reports_failing_case() {
        check("always fails eventually", 10, 2, |rng| {
            if rng.uniform() < 0.95 {
                Ok(())
            } else {
                Err("hit".into())
            }
        });
    }
}
