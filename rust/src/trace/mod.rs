//! Virtual-time event traces + ASCII timeline rendering.
//!
//! Every engine records its bookings here; `render_timeline` reproduces
//! the paper's Fig. 2/4/5-style timing diagrams as text, which is how
//! `examples/timing_analysis.rs` visualizes the round-robin pipeline and
//! the late-departure effect.

use std::collections::BTreeMap;

use crate::cluster::Ms;

/// What a trace event represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Main-node non-expert computation `M_l`.
    MainCompute,
    /// Shadow-node layer step `S_l`.
    ShadowCompute,
    /// Worker expert loading `EL_l`.
    ExpertLoad,
    /// Speculative chunk stream: a predicted expert's chunks filling
    /// residual PCIe slack ahead of the worker's previous eviction
    /// (prefetch depth >= 1, DESIGN.md §9). Cancelled chunks simply
    /// vanish from the booked spans.
    Prefetch,
    /// Worker expert computation `EC_l`.
    ExpertCompute,
    /// LAN message.
    LanSend,
    /// Stall (I/O bottleneck, misprediction reload, alignment wait).
    Stall,
    /// Fail-stop of a node (zero-width marker at the failure instant).
    Failure,
}

impl EventKind {
    pub fn glyph(self) -> char {
        match self {
            EventKind::MainCompute => 'M',
            EventKind::ShadowCompute => 'S',
            EventKind::ExpertLoad => 'L',
            EventKind::Prefetch => 'p',
            EventKind::ExpertCompute => 'C',
            EventKind::LanSend => '·',
            EventKind::Stall => 'x',
            EventKind::Failure => '!',
        }
    }
}

/// Where an event was booked: a numbered node, or the shared LAN wire.
///
/// Replaces the old `usize::MAX = shared LAN` sentinel so consumers
/// (timeline rendering, critical-path attribution) match on the variant
/// instead of comparing against a magic id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum NodeRef {
    /// A cluster node by id (0 = main, 1 = shadow, 2+i = worker i).
    Node(usize),
    /// The shared LAN segment (no per-node row).
    Lan,
}

impl NodeRef {
    /// The node id, when this is a [`NodeRef::Node`].
    pub fn index(self) -> Option<usize> {
        match self {
            NodeRef::Node(n) => Some(n),
            NodeRef::Lan => None,
        }
    }
}

/// One booked interval on one node.
#[derive(Debug, Clone)]
pub struct Event {
    pub kind: EventKind,
    /// Where the interval was booked ([`NodeRef::Lan`] = shared wire).
    pub node: NodeRef,
    pub start: Ms,
    pub end: Ms,
    /// For LAN messages: when the payload reaches its destination
    /// (`end` + propagation latency). The shared segment is held only
    /// for `[start, end]` — arrival is carried separately so timelines
    /// and trace-derived utilization never count propagation as busy
    /// span, yet consumers can still explain why a dependent event
    /// starts after the wire freed.
    pub arrival: Option<Ms>,
    pub label: &'static str,
    /// Hardware class of the node the event booked on, when the cluster
    /// registered one (mixed fleets — see [`Trace::tag_node`]); `None`
    /// on uniform clusters and for shared-LAN events. Makes `!`
    /// (failure) and `p` (prefetch) lines attributable on fleets where
    /// "worker 3" alone no longer says what kind of node died.
    pub class: Option<&'static str>,
}

/// Append-only event log.
#[derive(Debug, Default)]
pub struct Trace {
    events: Vec<Event>,
    pub enabled: bool,
    /// Node id → hardware-class name (survives [`Trace::clear`]: the
    /// cluster's composition does not change between runs).
    node_class: BTreeMap<usize, &'static str>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `node`'s hardware class; every later event on that node
    /// carries it, and [`Trace::render_timeline`] annotates the row.
    pub fn tag_node(&mut self, node: usize, class: &'static str) {
        self.node_class.insert(node, class);
    }

    /// The registered class of `node`, if any.
    pub fn class_of(&self, node: usize) -> Option<&'static str> {
        self.node_class.get(&node).copied()
    }

    pub fn push(&mut self, kind: EventKind, node: usize, start: Ms, end: Ms, label: &'static str) {
        if self.enabled {
            let class = self.class_of(node);
            self.events.push(Event {
                kind,
                node: NodeRef::Node(node),
                start,
                end,
                arrival: None,
                label,
                class,
            });
        }
    }

    /// Record a LAN message: the booked wire interval `[start, end]`
    /// plus the (later) arrival instant at the destination.
    pub fn push_lan(&mut self, start: Ms, end: Ms, arrival: Ms, label: &'static str) {
        if self.enabled {
            self.events.push(Event {
                kind: EventKind::LanSend,
                node: NodeRef::Lan,
                start,
                end,
                arrival: Some(arrival),
                label,
                class: None,
            });
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn clear(&mut self) {
        self.events.clear();
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Render a Fig. 2-style ASCII timeline: one row per node, `cols`
    /// character cells over `[t0, t1]` ms. Rows of nodes with a
    /// registered hardware class ([`Trace::tag_node`]) are labelled
    /// `name·class`, so mixed-fleet timelines say *what kind* of node a
    /// `!`/`p` line belongs to.
    pub fn render_timeline(&self, t0: Ms, t1: Ms, cols: usize, node_names: &[String]) -> String {
        let span = (t1 - t0).max(1e-9);
        let mut rows: Vec<Vec<char>> = vec![vec![' '; cols]; node_names.len()];
        for ev in &self.events {
            let Some(node) = ev.node.index().filter(|&n| n < node_names.len()) else {
                continue;
            };
            if ev.end < t0 || ev.start > t1 {
                continue;
            }
            let a = (((ev.start - t0) / span) * cols as f64).floor().max(0.0) as usize;
            let b = (((ev.end - t0) / span) * cols as f64).ceil().min(cols as f64) as usize;
            for c in a..b.max(a + 1).min(cols) {
                rows[node][c] = ev.kind.glyph();
            }
        }
        let labels: Vec<String> = node_names
            .iter()
            .enumerate()
            .map(|(i, n)| match self.class_of(i) {
                Some(c) => format!("{n}·{c}"),
                None => n.clone(),
            })
            .collect();
        let mut out = String::new();
        let width = labels.iter().map(|n| n.len()).max().unwrap_or(0);
        for (name, row) in labels.iter().zip(rows) {
            out.push_str(&format!("{name:>width$} |"));
            out.extend(row);
            out.push_str("|\n");
        }
        out.push_str(&format!(
            "{:>width$}  {}\n",
            "",
            format!(
                "[{t0:.1} ms .. {t1:.1} ms]  M=main S=shadow L=load p=prefetch C=expert x=stall !=fail"
            )
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::new();
        t.push(EventKind::MainCompute, 0, 0.0, 1.0, "M0");
        assert!(t.is_empty());
    }

    #[test]
    fn enabled_trace_records() {
        let mut t = Trace::new();
        t.enabled = true;
        t.push(EventKind::MainCompute, 0, 0.0, 1.0, "M0");
        t.push(EventKind::ExpertLoad, 1, 0.5, 2.0, "EL1");
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn tagged_nodes_carry_their_class_and_annotate_rows() {
        let mut t = Trace::new();
        t.enabled = true;
        t.tag_node(1, "jetson");
        t.push(EventKind::Failure, 1, 3.0, 3.0, "fail");
        t.push(EventKind::Prefetch, 0, 0.0, 1.0, "EL");
        assert_eq!(t.events()[0].class, Some("jetson"), "! events name the class");
        assert_eq!(t.events()[1].class, None, "untagged node stays bare");
        let s = t.render_timeline(0.0, 4.0, 8, &["main".into(), "w0".into()]);
        assert!(s.contains("w0·jetson |"), "{s}");
        assert!(s.lines().next().unwrap().contains("main |"), "{s}");
        // The registry survives clear(): composition outlives one run.
        t.clear();
        assert_eq!(t.class_of(1), Some("jetson"));
        assert!(t.is_empty());
    }

    #[test]
    fn lan_events_have_no_node_row() {
        let mut t = Trace::new();
        t.enabled = true;
        t.push(EventKind::MainCompute, 0, 0.0, 1.0, "M0");
        t.push_lan(1.0, 2.0, 2.5, "embed");
        assert_eq!(t.events()[0].node, NodeRef::Node(0));
        assert_eq!(t.events()[0].node.index(), Some(0));
        assert_eq!(t.events()[1].node, NodeRef::Lan);
        assert_eq!(t.events()[1].node.index(), None);
        // A LAN event never paints a row, even with rows present.
        let s = t.render_timeline(0.0, 3.0, 12, &["main".into()]);
        assert!(!s.contains('·'), "{s}");
    }

    #[test]
    fn timeline_places_events() {
        let mut t = Trace::new();
        t.enabled = true;
        t.push(EventKind::MainCompute, 0, 0.0, 5.0, "M");
        t.push(EventKind::ExpertCompute, 1, 5.0, 10.0, "C");
        let s = t.render_timeline(0.0, 10.0, 20, &["main".into(), "w1".into()]);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].contains("MMMMMMMMMM"), "{s}");
        assert!(lines[1].contains("CCCCCCCCCC"), "{s}");
        // Main's Ms occupy the first half, worker's Cs the second.
        let mpos = lines[0].find('M').unwrap();
        let cpos = lines[1].find('C').unwrap();
        assert!(cpos > mpos);
    }
}
