//! Seeded workload generation: open-loop arrival processes (Poisson,
//! bursty ON-OFF, replayed traces) and closed-loop clients with think
//! time, with per-request prompt/output lengths drawn from
//! [`crate::workload::Corpus`].
//!
//! Everything is generated from [`crate::model::rng::Rng`] streams, so
//! the same seed yields a byte-identical request list — the property the
//! whole load-test subsystem's determinism rests on.

use anyhow::{bail, Result};

use super::{Request, Slo};
use crate::cluster::Ms;
use crate::model::rng::Rng;
use crate::workload::Corpus;

/// Per-request length distribution.
#[derive(Debug, Clone)]
pub enum LenDist {
    Fixed(usize),
    /// Inclusive range.
    Uniform(usize, usize),
    /// The paper's corpus shape: short with probability `1 - p_long`.
    Bimodal { short: usize, long: usize, p_long: f64 },
}

impl LenDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => {
                debug_assert!(lo <= hi);
                lo + rng.below(hi - lo + 1)
            }
            LenDist::Bimodal { short, long, p_long } => {
                if rng.uniform() < p_long {
                    long
                } else {
                    short
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            LenDist::Fixed(n) => format!("fixed({n})"),
            LenDist::Uniform(lo, hi) => format!("uniform({lo},{hi})"),
            LenDist::Bimodal { short, long, p_long } => {
                format!("bimodal({short},{long},p_long={p_long})")
            }
        }
    }
}

/// When requests show up.
#[derive(Debug, Clone)]
pub enum ArrivalModel {
    /// Open loop, exponential inter-arrival gaps at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Open loop, ON-OFF modulated Poisson: exponential ON windows (mean
    /// `mean_on_ms`) with instantaneous rate `rate_per_s * burstiness`,
    /// separated by silent OFF windows (mean `mean_off_ms`). Long-run
    /// average rate is `rate_per_s * burstiness * on / (on + off)`.
    Bursty { rate_per_s: f64, burstiness: f64, mean_on_ms: Ms, mean_off_ms: Ms },
    /// Open loop, replayed inter-arrival gaps (cycled), scaled by
    /// `scale`.
    Trace { gaps_ms: Vec<Ms>, scale: f64 },
    /// Open loop, non-homogeneous Poisson for traffic drift: a seeded
    /// sinusoidal base rate (the diurnal swing) times any active
    /// flash-crowd burst window, sampled by Lewis–Shedler thinning so
    /// the instantaneous rate is exactly
    /// `rate_per_s * (1 + amplitude*sin(2πt/period)) * burst_mult(t)`.
    /// This is the traffic the SLO control loop is tested against
    /// (DESIGN.md §15).
    Diurnal {
        rate_per_s: f64,
        /// Relative swing of the sinusoid, in `[0, 1]`.
        amplitude: f64,
        period_ms: Ms,
        /// Flash-crowd windows `(start_ms, end_ms, rate multiplier)`;
        /// overlapping windows take the largest multiplier.
        bursts: Vec<(Ms, Ms, f64)>,
    },
    /// Closed loop: `clients` clients, each with one request outstanding,
    /// issuing the next one an exponential think time (mean
    /// `mean_think_ms`) after the previous completes.
    ClosedLoop { clients: usize, mean_think_ms: Ms },
}

impl ArrivalModel {
    /// A short human-ish recorded gap pattern (two bursts per cycle) for
    /// the `--arrival trace` demo; rescale with [`ArrivalModel::with_rate`].
    pub fn example_trace() -> Self {
        ArrivalModel::Trace {
            gaps_ms: vec![
                120.0, 40.0, 60.0, 30.0, 1800.0, 90.0, 50.0, 45.0, 70.0, 2400.0,
            ],
            scale: 1.0,
        }
    }

    /// The default diurnal swing: ±60% around `rate_per_s` over a
    /// one-minute virtual period, no bursts. Add flash crowds with
    /// [`ArrivalModel::with_burst`].
    pub fn diurnal(rate_per_s: f64) -> Self {
        ArrivalModel::Diurnal { rate_per_s, amplitude: 0.6, period_ms: 60_000.0, bursts: Vec::new() }
    }

    /// Add a flash-crowd window to a diurnal model: `mult`× the base
    /// rate over `[start_ms, end_ms)`. No-op on other models.
    pub fn with_burst(self, start_ms: Ms, end_ms: Ms, mult: f64) -> Self {
        assert!(start_ms < end_ms && mult >= 1.0, "bad burst window");
        match self {
            ArrivalModel::Diurnal { rate_per_s, amplitude, period_ms, mut bursts } => {
                bursts.push((start_ms, end_ms, mult));
                ArrivalModel::Diurnal { rate_per_s, amplitude, period_ms, bursts }
            }
            other => other,
        }
    }

    /// The instantaneous rate (req/s) of a [`ArrivalModel::Diurnal`]
    /// model at virtual time `t` — the intensity the thinning sampler
    /// realizes, exposed so tests can integrate it. Stationary models
    /// return their constant long-run rate; closed-loop returns 0 (it is
    /// self-clocked).
    pub fn rate_at(&self, t: Ms) -> f64 {
        match *self {
            ArrivalModel::Poisson { rate_per_s } => rate_per_s,
            ArrivalModel::Bursty { rate_per_s, burstiness, mean_on_ms, mean_off_ms } => {
                rate_per_s * burstiness * mean_on_ms / (mean_on_ms + mean_off_ms)
            }
            ArrivalModel::Trace { ref gaps_ms, scale } => {
                let mean = gaps_ms.iter().sum::<Ms>() / gaps_ms.len().max(1) as f64;
                if mean > 0.0 {
                    1000.0 / (mean * scale)
                } else {
                    0.0
                }
            }
            ArrivalModel::Diurnal { rate_per_s, amplitude, period_ms, ref bursts } => {
                let base = rate_per_s
                    * (1.0 + amplitude * (std::f64::consts::TAU * t / period_ms).sin());
                let mult = bursts
                    .iter()
                    .filter(|&&(s, e, _)| t >= s && t < e)
                    .map(|&(_, _, m)| m)
                    .fold(1.0, f64::max);
                base * mult
            }
            ArrivalModel::ClosedLoop { .. } => 0.0,
        }
    }

    /// Freeze an open-loop model into a replayable
    /// [`ArrivalModel::Trace`]: the exact gaps `seed` produces for `n`
    /// arrivals, so a diurnal/flash-crowd draw can ride the existing
    /// `--arrival trace` path. Closed-loop models are self-clocked and
    /// cannot be frozen.
    pub fn materialize(&self, seed: u64, n: usize) -> Result<Self> {
        if matches!(self, ArrivalModel::ClosedLoop { .. }) {
            bail!("closed-loop arrivals are self-clocked and cannot replay as a trace");
        }
        let mut rng = Rng::new(seed ^ 0xA117_11A1);
        let times = self.arrival_times(&mut rng, n);
        let mut gaps = Vec::with_capacity(times.len());
        let mut prev = 0.0;
        for t in times {
            gaps.push(t - prev);
            prev = t;
        }
        Ok(ArrivalModel::Trace { gaps_ms: gaps, scale: 1.0 })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ArrivalModel::Poisson { .. } => "poisson",
            ArrivalModel::Bursty { .. } => "bursty",
            ArrivalModel::Trace { .. } => "trace",
            ArrivalModel::Diurnal { .. } => "diurnal",
            ArrivalModel::ClosedLoop { .. } => "closed-loop",
        }
    }

    /// The same model at a different offered rate (the sweep driver's
    /// knob). Closed-loop workloads are self-clocked and unchanged.
    pub fn with_rate(&self, rate_per_s: f64) -> Self {
        assert!(rate_per_s > 0.0, "rate must be positive");
        match self {
            ArrivalModel::Poisson { .. } => ArrivalModel::Poisson { rate_per_s },
            ArrivalModel::Bursty { burstiness, mean_on_ms, mean_off_ms, .. } => {
                ArrivalModel::Bursty {
                    rate_per_s,
                    burstiness: *burstiness,
                    mean_on_ms: *mean_on_ms,
                    mean_off_ms: *mean_off_ms,
                }
            }
            ArrivalModel::Trace { gaps_ms, .. } => {
                let mean = gaps_ms.iter().sum::<Ms>() / gaps_ms.len().max(1) as f64;
                ArrivalModel::Trace {
                    gaps_ms: gaps_ms.clone(),
                    scale: if mean > 0.0 { 1000.0 / (rate_per_s * mean) } else { 1.0 },
                }
            }
            ArrivalModel::Diurnal { amplitude, period_ms, bursts, .. } => ArrivalModel::Diurnal {
                rate_per_s,
                amplitude: *amplitude,
                period_ms: *period_ms,
                bursts: bursts.clone(),
            },
            ArrivalModel::ClosedLoop { .. } => self.clone(),
        }
    }

    fn arrival_times(&self, rng: &mut Rng, n: usize) -> Vec<Ms> {
        let mut t: Ms = 0.0;
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalModel::Poisson { rate_per_s } => {
                let mean = 1000.0 / rate_per_s;
                for _ in 0..n {
                    t += exp_sample(rng, mean);
                    out.push(t);
                }
            }
            ArrivalModel::Bursty { rate_per_s, burstiness, mean_on_ms, mean_off_ms } => {
                let mean_gap = 1000.0 / (rate_per_s * burstiness);
                let mut on_left = exp_sample(rng, mean_on_ms);
                for _ in 0..n {
                    loop {
                        let g = exp_sample(rng, mean_gap);
                        if g <= on_left {
                            on_left -= g;
                            t += g;
                            out.push(t);
                            break;
                        }
                        t += on_left + exp_sample(rng, mean_off_ms);
                        on_left = exp_sample(rng, mean_on_ms);
                    }
                }
            }
            ArrivalModel::Trace { ref gaps_ms, scale } => {
                assert!(!gaps_ms.is_empty(), "empty trace");
                for i in 0..n {
                    t += gaps_ms[i % gaps_ms.len()] * scale;
                    out.push(t);
                }
            }
            ArrivalModel::Diurnal { rate_per_s, amplitude, period_ms, ref bursts } => {
                // Lewis–Shedler thinning: draw candidates at the peak
                // rate, keep each with probability rate(t)/rate_max.
                // Deterministic per seed like every other model.
                assert!(rate_per_s > 0.0, "rate must be positive");
                assert!((0.0..=1.0).contains(&amplitude), "amplitude must be in [0, 1]");
                assert!(period_ms > 0.0, "period must be positive");
                let max_mult = bursts.iter().map(|&(_, _, m)| m).fold(1.0, f64::max);
                let rate_max = rate_per_s * (1.0 + amplitude) * max_mult;
                let mean_gap = 1000.0 / rate_max;
                for _ in 0..n {
                    loop {
                        t += exp_sample(rng, mean_gap);
                        if rng.uniform() * rate_max <= self.rate_at(t) {
                            out.push(t);
                            break;
                        }
                    }
                }
            }
            ArrivalModel::ClosedLoop { .. } => out.resize(n, 0.0),
        }
        out
    }
}

/// Exponential sample with the given mean (inverse CDF; `1 - u` avoids
/// `ln(0)`).
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    -(1.0 - rng.uniform()).ln() * mean
}

/// One SLO class.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub slo: Slo,
}

impl TenantSpec {
    pub fn new(name: &str, slo: Slo) -> Self {
        Self { name: name.to_string(), slo }
    }

    /// Latency-sensitive class (budgets in raw 12-layer virtual ms; see
    /// `workload::speed::PAPER_LAYER_SCALE` for the 32-layer conversion).
    pub fn interactive() -> Self {
        Self::new("interactive", Slo::new(1000.0, 150.0))
    }

    /// Throughput class with no latency objective.
    pub fn batch() -> Self {
        Self::new("batch", Slo::relaxed())
    }
}

/// A complete workload description; [`WorkloadSpec::generate`] turns it
/// into a concrete request stream.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub model: ArrivalModel,
    pub n_requests: usize,
    pub prompt_len: LenDist,
    pub out_tokens: LenDist,
    /// Requests cycle round-robin over tenants.
    pub tenants: Vec<TenantSpec>,
    pub vocab: u32,
    /// Every request decodes the *same* prompt (one length draw, one
    /// corpus walk) — the shared-routing workload where batched decode's
    /// expert-load amortization is maximal and easiest to read off
    /// `BENCH_batch.json` (identical prompts route identically, so a
    /// batch of B needs the same distinct experts as a batch of 1).
    pub shared_prompt: bool,
}

impl WorkloadSpec {
    /// Poisson arrivals over the paper's bimodal 16/128 corpus shape,
    /// 16 output tokens, one relaxed tenant.
    pub fn poisson(rate_per_s: f64, n_requests: usize, vocab: u32) -> Self {
        Self {
            model: ArrivalModel::Poisson { rate_per_s },
            n_requests,
            prompt_len: LenDist::Bimodal { short: 16, long: 128, p_long: 0.5 },
            out_tokens: LenDist::Fixed(16),
            tenants: vec![TenantSpec::new("default", Slo::relaxed())],
            vocab,
            shared_prompt: false,
        }
    }

    /// Parse a CLI arrival-model name.
    pub fn parse_model(
        kind: &str,
        rate_per_s: f64,
        clients: usize,
        mean_think_ms: Ms,
    ) -> Result<ArrivalModel> {
        Ok(match kind {
            "poisson" => ArrivalModel::Poisson { rate_per_s },
            "bursty" => ArrivalModel::Bursty {
                rate_per_s,
                burstiness: 4.0,
                mean_on_ms: 2000.0,
                mean_off_ms: 6000.0,
            },
            "trace" => ArrivalModel::example_trace().with_rate(rate_per_s),
            "diurnal" => ArrivalModel::diurnal(rate_per_s),
            "closed" | "closed-loop" => ArrivalModel::ClosedLoop { clients, mean_think_ms },
            other => bail!("unknown arrival model {other:?} (poisson|bursty|trace|diurnal|closed)"),
        })
    }

    pub fn with_rate(&self, rate_per_s: f64) -> Self {
        Self { model: self.model.with_rate(rate_per_s), ..self.clone() }
    }

    /// Generate the request stream. Same seed → byte-identical stream;
    /// prompt `i` matches [`Corpus::generate`]'s prompt `i` whenever the
    /// lengths agree.
    pub fn generate(&self, seed: u64) -> Vec<Request> {
        assert!(!self.tenants.is_empty(), "need at least one tenant");
        let mut arr_rng = Rng::new(seed ^ 0xA117_11A1);
        let mut len_rng = Rng::new(seed ^ 0x1E45_D157);
        let arrivals = self.model.arrival_times(&mut arr_rng, self.n_requests);
        let lens: Vec<usize> = if self.shared_prompt {
            let len = self.prompt_len.sample(&mut len_rng);
            vec![len; self.n_requests]
        } else {
            (0..self.n_requests).map(|_| self.prompt_len.sample(&mut len_rng)).collect()
        };
        let outs: Vec<usize> =
            (0..self.n_requests).map(|_| self.out_tokens.sample(&mut len_rng).max(1)).collect();
        let mut corpus = Corpus::generate_mixed(seed, &lens, self.vocab);
        if self.shared_prompt && !corpus.prompts.is_empty() {
            let first = corpus.prompts[0].clone();
            corpus.prompts = vec![first; self.n_requests];
        }
        (0..self.n_requests)
            .map(|i| {
                let tenant = i % self.tenants.len();
                let (client, think_ms) = match self.model {
                    ArrivalModel::ClosedLoop { clients, mean_think_ms } => {
                        ((i % clients.max(1)) as u64, exp_sample(&mut arr_rng, mean_think_ms))
                    }
                    _ => (i as u64, 0.0),
                };
                Request {
                    id: i as u64,
                    tenant,
                    client,
                    prompt: corpus.prompts[i].clone(),
                    out_tokens: outs[i],
                    arrival_ms: arrivals[i],
                    think_ms,
                    slo: self.tenants[tenant].slo,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_increasing() {
        let spec = WorkloadSpec::poisson(2.0, 32, 256);
        let a = spec.generate(7);
        let b = spec.generate(7);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.prompt, y.prompt);
        }
        assert!(a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        // Mean gap should be in the ballpark of 500 ms at 2 req/s.
        let mean_gap = a.last().unwrap().arrival_ms / 32.0;
        assert!((150.0..1500.0).contains(&mean_gap), "mean gap {mean_gap}");
    }

    #[test]
    fn different_seeds_differ() {
        let spec = WorkloadSpec::poisson(2.0, 8, 256);
        let a = spec.generate(1);
        let b = spec.generate(2);
        assert_ne!(
            a.iter().map(|r| r.arrival_ms.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|r| r.arrival_ms.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn bimodal_prompts_use_both_lengths() {
        let spec = WorkloadSpec::poisson(1.0, 64, 256);
        let reqs = spec.generate(3);
        let shorts = reqs.iter().filter(|r| r.prompt.len() == 16).count();
        let longs = reqs.iter().filter(|r| r.prompt.len() == 128).count();
        assert_eq!(shorts + longs, 64);
        assert!(shorts > 0 && longs > 0);
    }

    #[test]
    fn bursty_clusters_arrivals() {
        let model = ArrivalModel::Bursty {
            rate_per_s: 1.0,
            burstiness: 8.0,
            mean_on_ms: 1000.0,
            mean_off_ms: 7000.0,
        };
        let mut rng = Rng::new(5);
        let times = model.arrival_times(&mut rng, 64);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let small = gaps.iter().filter(|&&g| g < 500.0).count();
        let big = gaps.iter().filter(|&&g| g > 2000.0).count();
        assert!(small > big, "bursty gaps should cluster: {small} small vs {big} big");
        assert!(big > 0, "there should be off-window gaps");
    }

    #[test]
    fn trace_replays_and_rescales() {
        let model = ArrivalModel::Trace { gaps_ms: vec![100.0, 300.0], scale: 1.0 };
        let mut rng = Rng::new(1);
        let t = model.arrival_times(&mut rng, 4);
        assert_eq!(t, vec![100.0, 400.0, 500.0, 800.0]);
        // Rescaled to 10 req/s: mean gap becomes 100 ms.
        let fast = model.with_rate(10.0);
        let mut rng = Rng::new(1);
        let t = fast.arrival_times(&mut rng, 2);
        assert!((t[0] - 50.0).abs() < 1e-9);
        assert!((t[1] - 200.0).abs() < 1e-9);
    }

    #[test]
    fn diurnal_counts_match_the_integrated_rate() {
        // Lewis–Shedler soundness: over the span the sampler actually
        // covered, the arrival count must track ∫ rate(t) dt — per seed,
        // within Poisson noise (n = 400 → ~5% sigma; we allow 20%).
        crate::util::prop::check("diurnal count ~ integrated rate", 16, 31, |rng| {
            let model = ArrivalModel::Diurnal {
                rate_per_s: 2.0 + rng.uniform() * 8.0,
                amplitude: rng.uniform() * 0.9,
                period_ms: 5_000.0 + rng.uniform() * 40_000.0,
                bursts: if rng.uniform() < 0.5 {
                    vec![(2_000.0, 6_000.0, 1.0 + rng.uniform() * 4.0)]
                } else {
                    Vec::new()
                },
            };
            let n = 400usize;
            let mut arr = Rng::new(rng.next_u64());
            let times = model.arrival_times(&mut arr, n);
            let span = *times.last().unwrap();
            // Trapezoid-free: fine midpoint Riemann sum over 1 ms steps.
            let steps = (span as usize).max(1);
            let dt = span / steps as f64;
            let integral: f64 = (0..steps)
                .map(|i| model.rate_at((i as f64 + 0.5) * dt) * dt / 1000.0)
                .sum();
            let ratio = n as f64 / integral;
            if !(0.8..1.2).contains(&ratio) {
                return Err(format!("count {n} vs integral {integral:.1} (ratio {ratio:.3})"));
            }
            Ok(())
        });
    }

    #[test]
    fn flash_crowd_windows_densify_arrivals() {
        // A 6x burst over [5s, 10s): the arrival rate inside the window
        // must clearly exceed the rate outside it.
        let model = ArrivalModel::diurnal(2.0).with_burst(5_000.0, 10_000.0, 6.0);
        let mut rng = Rng::new(9);
        let times = model.arrival_times(&mut rng, 300);
        let inside =
            times.iter().filter(|&&t| (5_000.0..10_000.0).contains(&t)).count() as f64 / 5.0;
        let before = times.iter().filter(|&&t| t < 5_000.0).count() as f64 / 5.0;
        assert!(
            inside > 2.0 * before.max(1.0),
            "burst density {inside}/s vs pre-burst {before}/s"
        );
        // rate_at reflects the window exactly.
        assert!(model.rate_at(7_500.0) > 4.0 * model.rate_at(1.0).max(0.1));
        assert_eq!(model.label(), "diurnal");
    }

    #[test]
    fn diurnal_materializes_into_an_identical_trace() {
        // Freezing a diurnal draw into a trace replays the exact same
        // arrival instants through the --arrival trace path.
        let model = ArrivalModel::diurnal(4.0).with_burst(1_000.0, 3_000.0, 3.0);
        let seed = 17;
        let trace = model.materialize(seed, 64).unwrap();
        let mut rng = Rng::new(seed ^ 0xA117_11A1);
        let direct = model.arrival_times(&mut rng, 64);
        let mut rng = Rng::new(999); // trace replay ignores the rng
        let replayed = trace.arrival_times(&mut rng, 64);
        for (a, b) in direct.iter().zip(&replayed) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!(ArrivalModel::ClosedLoop { clients: 2, mean_think_ms: 10.0 }
            .materialize(1, 4)
            .is_err());
        // The spec-level parse accepts the new name.
        assert_eq!(
            WorkloadSpec::parse_model("diurnal", 3.0, 0, 0.0).unwrap().label(),
            "diurnal"
        );
    }

    #[test]
    fn closed_loop_assigns_clients_and_think_times() {
        let spec = WorkloadSpec {
            model: ArrivalModel::ClosedLoop { clients: 3, mean_think_ms: 200.0 },
            ..WorkloadSpec::poisson(1.0, 9, 256)
        };
        let reqs = spec.generate(11);
        assert!(reqs.iter().all(|r| r.arrival_ms == 0.0));
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.client, (i % 3) as u64);
            assert!(r.think_ms > 0.0);
        }
    }

    #[test]
    fn shared_prompt_repeats_one_walk() {
        let spec = WorkloadSpec { shared_prompt: true, ..WorkloadSpec::poisson(1.0, 8, 256) };
        let reqs = spec.generate(4);
        assert_eq!(reqs.len(), 8);
        assert!(reqs.iter().all(|r| r.prompt == reqs[0].prompt), "one prompt for all");
        // Arrivals still spread out (the arrival stream is untouched).
        assert!(reqs.last().unwrap().arrival_ms > 0.0);
    }

    #[test]
    fn tenants_cycle_and_carry_slos() {
        let spec = WorkloadSpec {
            tenants: vec![TenantSpec::interactive(), TenantSpec::batch()],
            ..WorkloadSpec::poisson(1.0, 6, 256)
        };
        let reqs = spec.generate(1);
        assert_eq!(reqs[0].tenant, 0);
        assert_eq!(reqs[1].tenant, 1);
        assert_eq!(reqs[2].tenant, 0);
        assert!(reqs[0].slo.ttft_ms.is_finite());
        assert!(reqs[1].slo.ttft_ms.is_infinite());
    }
}
